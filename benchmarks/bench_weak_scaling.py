"""Fig. 7 — weak scaling across two software stages.

The paper runs size-adapted workloads over increasing node counts under two
software stages (2025 vs 2026 stacks).  Here: glm4-9b train with global
batch scaled proportionally to chips (256 chips/bs=256 vs 512 chips/bs=512),
under two "software stages" of this framework — remat=dots (stage A) vs
remat=full (stage B) — using roofline-bound step times from dry-run records
produced on demand via the DryRunHarness.
"""

from __future__ import annotations

from benchmarks.common import emit, load_dryrun_records
from repro.core import analysis

ARCH = "glm4-9b"


def run(compile_missing: bool = False) -> dict:
    recs = load_dryrun_records(f"{ARCH}.train_4k.*.json")
    pts = {}
    for r in recs:
        pods = 2 if "2pods" in r["system"] else 1
        gb = r["knobs"].get("global_batch", 256)
        stage = r["knobs"].get("remat", "dots")
        # weak-scaling points: batch proportional to chips
        if (pods, gb) in ((1, 256), (2, 512)):
            pts[(stage, 256 * pods)] = r["roofline"]["step_time_bound_s"]

    out = {}
    for stage in sorted({s for s, _ in pts}):
        series = {n: t for (s, n), t in pts.items() if s == stage}
        if len(series) >= 2:
            ws = analysis.weak_scaling(series)
            eff = ws[max(series)]["efficiency"]
            out[stage] = {"points": series, "efficiency_at_512": eff}
            emit(f"fig7_weak_scaling.stage={stage}", series[max(series)] * 1e6,
                 f"eff={eff:.3f}")
        else:
            out[stage] = {"points": series, "efficiency_at_512": None}
    if not out:
        emit("fig7_weak_scaling", 0.0, "no dryrun records; run the sweep first")
    return out


if __name__ == "__main__":
    print(run())
