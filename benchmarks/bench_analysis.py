"""Columnar analysis-plane benchmark: warm gate + time-series + campaign
analysis over a multi-thousand-report history, columnar vs. report-object.

The report-object path re-materializes ``Report`` objects via the (warm,
PR-1) query cache and walks Python dicts per metric — O(history) Python per
call.  The columnar plane keeps the same data as contiguous numpy columns
behind a fingerprint/watermark, so a warm call is a stat + mask (+ memo hit
for derived artifacts) regardless of history length.  Asserted here:

* warm ``RegressionGate.run`` (mad detector — the data-plane comparison;
  the statistical cost of bootstrap/CUSUM is identical on both paths and
  would only dilute the ratio) is **>= 10x** faster columnar;
* warm ``PostProcessingOrchestrator.time_series`` is **>= 10x** faster
  columnar;
* both paths produce **identical** outputs (gate verdict JSON and
  time-series/regression structures) before any timing starts.

Also measured (reported, not asserted): machine-comparison, campaign-frame
summary across prefixes, cold columnar build, and the incremental O(delta)
refresh after a single append.

    PYTHONPATH=src python -m benchmarks.bench_analysis
"""

from __future__ import annotations

import json
import tempfile
import time
from pathlib import Path
from typing import Callable, Dict

import numpy as np

from benchmarks.common import emit
from repro.core import analysis
from repro.core.orchestrator import PostProcessingOrchestrator
from repro.core.protocol import DataEntry, new_report
from repro.core.regression import GateSpec, MetricSpec, RegressionGate, json_safe
from repro.core.store import ResultStore

N_REPORTS = 6000
N_CAMPAIGN_PREFIXES = 12
CAMPAIGN_REPORTS_EACH = 200
WARM_REPEATS = 15
SPEEDUP_FLOOR = 10.0
PREFIX = "bench.analysis"


def _seed(store: ResultStore, prefix: str, n: int, *, seed: int = 0) -> None:
    rng = np.random.default_rng(seed)
    t0 = 1.7e9
    for i in range(n):
        v = float(1.0 + rng.normal(0, 0.02))
        r = new_report(system=f"sys{i % 3}", variant="v", usecase="u",
                       pipeline_id=f"p{i}")
        r.experiment.timestamp = t0 + i
        r.data.append(DataEntry(
            success=True, runtime=v, nodes=1 + i % 4,
            metrics={"step_time_s": v, "throughput_tok_s": 1.0 / v},
        ))
        store.append(prefix, r)


def _median_s(fn: Callable[[], object], repeats: int = WARM_REPEATS) -> float:
    fn()  # warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def bench_gate(store: ResultStore, out: Dict[str, float]) -> None:
    kw = dict(source_prefix=PREFIX, metrics=[MetricSpec("step_time_s")],
              history=N_REPORTS, window=64, candidate=8, min_points=3,
              update_baseline=False, record_prefix="none", detectors=("mad",))
    col = RegressionGate(GateSpec(**kw, use_columnar=True))
    obj = RegressionGate(GateSpec(**kw, use_columnar=False))
    # Parity first: identical verdict JSON, then race.
    a, b = json_safe(col.run(store)), json_safe(obj.run(store))
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True), \
        "columnar vs report-object gate verdicts diverged"
    col_s = _median_s(lambda: col.run(store))
    obj_s = _median_s(lambda: obj.run(store))
    speedup = obj_s / col_s
    emit("analysis.gate_warm.report_objects", obj_s * 1e6, f"{N_REPORTS}reports")
    emit("analysis.gate_warm.columnar", col_s * 1e6,
         f"speedup={speedup:.1f}x floor={SPEEDUP_FLOOR:.0f}x")
    out["gate_warm_obj_ms"] = obj_s * 1e3
    out["gate_warm_col_ms"] = col_s * 1e3
    out["gate_speedup"] = speedup
    assert speedup >= SPEEDUP_FLOOR, (
        f"warm columnar gate only {speedup:.1f}x faster "
        f"(need >= {SPEEDUP_FLOOR:.0f}x)")

    # Full default detector set, for context: bootstrap/CUSUM statistics
    # dominate both paths equally, so the ratio is smaller by construction.
    kwf = dict(kw, detectors=("mad", "bootstrap", "cusum"))
    colf = RegressionGate(GateSpec(**kwf, use_columnar=True))
    objf = RegressionGate(GateSpec(**kwf, use_columnar=False))
    colf_s = _median_s(lambda: colf.run(store), repeats=5)
    objf_s = _median_s(lambda: objf.run(store), repeats=5)
    emit("analysis.gate_warm_all_detectors.columnar", colf_s * 1e6,
         f"speedup={objf_s / colf_s:.1f}x (statistics-bound)")
    out["gate_all_detectors_speedup"] = objf_s / colf_s


def bench_time_series(store: ResultStore, out: Dict[str, float]) -> None:
    pp_col = PostProcessingOrchestrator(store=store, inputs={"record": False})
    pp_obj = PostProcessingOrchestrator(
        store=store, inputs={"record": False, "columnar": False})
    call_col = lambda: pp_col.time_series(  # noqa: E731
        source_prefix=PREFIX, data_labels=["step_time_s"])
    call_obj = lambda: pp_obj.time_series(  # noqa: E731
        source_prefix=PREFIX, data_labels=["step_time_s"])
    assert call_col() == call_obj(), \
        "columnar vs report-object time-series outputs diverged"
    col_s = _median_s(call_col)
    obj_s = _median_s(call_obj)
    speedup = obj_s / col_s
    emit("analysis.timeseries_warm.report_objects", obj_s * 1e6,
         f"{N_REPORTS}reports")
    emit("analysis.timeseries_warm.columnar", col_s * 1e6,
         f"speedup={speedup:.1f}x floor={SPEEDUP_FLOOR:.0f}x")
    out["timeseries_warm_obj_ms"] = obj_s * 1e3
    out["timeseries_warm_col_ms"] = col_s * 1e3
    out["timeseries_speedup"] = speedup
    assert speedup >= SPEEDUP_FLOOR, (
        f"warm columnar time-series only {speedup:.1f}x faster "
        f"(need >= {SPEEDUP_FLOOR:.0f}x)")

    mc_col = _median_s(lambda: pp_col.machine_comparison(
        selectors=[{"prefix": PREFIX}], metric="step_time_s"))
    mc_obj = _median_s(lambda: pp_obj.machine_comparison(
        selectors=[{"prefix": PREFIX}], metric="step_time_s"))
    emit("analysis.machine_comparison_warm.columnar", mc_col * 1e6,
         f"speedup={mc_obj / mc_col:.1f}x")
    out["machine_comparison_speedup"] = mc_obj / mc_col


def bench_campaign(tmp: Path, out: Dict[str, float]) -> None:
    """CampaignFrame: one metric across many prefixes in one scan."""
    store = ResultStore(tmp / "campaign", backend="jsonl")
    for p in range(N_CAMPAIGN_PREFIXES):
        _seed(store, f"app{p:02d}", CAMPAIGN_REPORTS_EACH, seed=p)
    frame = store.columnar.frame()

    def obj_summary():
        return {
            p: analysis.summary_stats([
                float(d.metrics["step_time_s"])
                for r in store.query(p) for d in r.data
                if d.success and "step_time_s" in d.metrics
            ])
            for p in store.prefixes()
        }

    assert frame.summary("step_time_s") == obj_summary(), \
        "campaign summary diverged from the report-object reduction"
    col_s = _median_s(lambda: frame.summary("step_time_s"))
    obj_s = _median_s(obj_summary)
    emit("analysis.campaign_summary.columnar", col_s * 1e6,
         f"{N_CAMPAIGN_PREFIXES}prefixes x {CAMPAIGN_REPORTS_EACH} "
         f"speedup={obj_s / col_s:.1f}x")
    out["campaign_prefixes"] = N_CAMPAIGN_PREFIXES
    out["campaign_summary_speedup"] = obj_s / col_s


def bench_incremental(store: ResultStore, out: Dict[str, float]) -> None:
    """Cold build vs. the O(delta) refresh after a single append."""
    stats0 = dict(store.columnar.stats)
    r = new_report(system="sys0", variant="v", usecase="u", pipeline_id="tail")
    r.data.append(DataEntry(success=True, runtime=1.0,
                            metrics={"step_time_s": 1.0}))
    store.append(PREFIX, r)
    t0 = time.perf_counter()
    store.columnar.table(PREFIX)
    delta_s = time.perf_counter() - t0
    stats1 = store.columnar.stats
    assert stats1["incremental"] == stats0["incremental"] + 1, (stats0, stats1)
    assert stats1["rebuilds"] == stats0["rebuilds"], "append forced a rebuild"
    emit("analysis.columnar_refresh_after_append", delta_s * 1e6,
         "1 new report (no rebuild)")
    out["incremental_refresh_ms"] = delta_s * 1e3


def run() -> Dict[str, float]:
    out: Dict[str, float] = {"n_reports": N_REPORTS}
    with tempfile.TemporaryDirectory(prefix="exacb_bench_analysis_") as tmp:
        tmp = Path(tmp)
        store = ResultStore(tmp / "store", backend="jsonl")
        t0 = time.perf_counter()
        _seed(store, PREFIX, N_REPORTS)
        emit("analysis.seed_store", (time.perf_counter() - t0) * 1e6,
             f"{N_REPORTS}reports jsonl")
        t0 = time.perf_counter()
        store.columnar.table(PREFIX)  # cold build (parses everything once)
        emit("analysis.columnar_cold_build", (time.perf_counter() - t0) * 1e6,
             f"{N_REPORTS}reports")
        bench_gate(store, out)
        bench_time_series(store, out)
        bench_incremental(store, out)
        bench_campaign(tmp, out)
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    print(json.dumps(run(), indent=2))
