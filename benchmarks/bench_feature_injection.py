"""Fig. 6 — feature injection: knob sweep without touching the benchmark.

The paper sweeps UCX_RNDV_THRESH through injected environment values and
plots OSU bandwidth per value.  Our fleet's "environment knobs" are compiler
and partitioning parameters; here the FeatureInjectionOrchestrator sweeps
the training microbatch count and remat policy over a frozen smoke
benchmark — each point is a real measured step time on this host.
"""

from __future__ import annotations

from benchmarks.common import BENCH_STORE, emit
from repro.core.harness import BenchmarkSpec, ExecHarness
from repro.core.orchestrator import ExecutionOrchestrator, FeatureInjectionOrchestrator
from repro.core.store import ResultStore
from repro.core import analysis


def run() -> dict:
    store = ResultStore(BENCH_STORE)
    ex = ExecutionOrchestrator(
        inputs={"prefix": "bench.injection", "record": True},
        harness=ExecHarness(steps=3, batch=4, seq=64),
        store=store,
    )
    fi = FeatureInjectionOrchestrator(execution=ex, inputs={"prefix": "bench.injection"})
    spec = BenchmarkSpec(arch="glm4-9b", shape="train_4k", system="cpu-smoke")

    # Knob 1: remat policy (compute/memory trade — the UCX-threshold analogue).
    res_remat = fi.sweep(spec, override_knob="remat", values=["none", "dots", "full"])
    # Reports were persisted; compare across the injected values.
    reports = store.query("bench.injection")
    curve = analysis.injection_comparison(reports, "step_time_s", "remat")

    out = {}
    for knob_value, t in sorted(curve.items()):
        emit(f"fig6_injection.remat={knob_value}", t * 1e6, "measured step time")
        out[knob_value] = t
    ok = all(r.readiness >= 1 for r in res_remat)
    return {"curve": out, "all_ran": ok}


if __name__ == "__main__":
    print(run())
