"""Benchmark harness entry point — one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus a summary).  Heavy
dry-run-derived benches read stored records under ``results/dryrun`` (the
sweep produces them); measured micro-benches run live on this host.

    PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        bench_energy,
        bench_feature_injection,
        bench_machine_comparison,
        bench_regression,
        bench_roofline,
        bench_scheduler,
        bench_timeseries,
        bench_weak_scaling,
    )

    benches = [
        ("fig3_4_timeseries", bench_timeseries.run),
        ("fig5_machine_comparison", bench_machine_comparison.run),
        ("fig6_feature_injection", bench_feature_injection.run),
        ("fig7_weak_scaling", bench_weak_scaling.run),
        ("fig8_9_energy", bench_energy.run),
        ("roofline_table", bench_roofline.run),
        ("scheduler_and_store", bench_scheduler.run),
        ("regression_gate", bench_regression.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        t0 = time.perf_counter()
        try:
            fn()
            print(f"{name}.total,{(time.perf_counter()-t0)*1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}.total,0,FAILED {type(e).__name__}: {e}")
            traceback.print_exc(limit=4, file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
