"""Benchmark harness entry point — one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus a summary), and appends
one machine-readable run entry to the ``BENCH_analysis.json`` trajectory
(``--out``; default at the repo root) so the repo's performance history —
per-bench wall-clock plus the derived numbers a bench reports, e.g. the
columnar-vs-report-object speedups from ``bench_analysis`` — is tracked
across PRs.  CI uploads the file as an artifact on every run.

Heavy dry-run-derived benches read stored records under ``results/dryrun``
(the sweep produces them); measured micro-benches run live on this host.

    PYTHONPATH=src python -m benchmarks.run [--out BENCH_analysis.json]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

TRAJECTORY_SCHEMA = 1


def _load_trajectory(path: Path) -> dict:
    if path.exists():
        try:
            doc = json.loads(path.read_text())
            if doc.get("schema") == TRAJECTORY_SCHEMA and isinstance(
                    doc.get("runs"), list):
                return doc
            print(f"warning: {path} has an unknown trajectory schema; "
                  "restarting the perf history", file=sys.stderr)
        except (json.JSONDecodeError, OSError) as e:
            print(f"warning: could not read trajectory {path} ({e}); "
                  "restarting the perf history", file=sys.stderr)
    return {"schema": TRAJECTORY_SCHEMA, "runs": []}


def main(argv=None) -> None:
    from benchmarks import (
        bench_analysis,
        bench_energy,
        bench_feature_injection,
        bench_harnesses,
        bench_machine_comparison,
        bench_regression,
        bench_roofline,
        bench_scheduler,
        bench_timeseries,
        bench_weak_scaling,
        bench_workers,
    )

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_analysis.json"),
                    help="benchmark trajectory JSON (appended per run)")
    ap.add_argument("--only", default=None,
                    help="run a single bench by name (substring match)")
    args = ap.parse_args(argv)

    benches = [
        ("fig3_4_timeseries", bench_timeseries.run),
        ("fig5_machine_comparison", bench_machine_comparison.run),
        ("fig6_feature_injection", bench_feature_injection.run),
        ("fig7_weak_scaling", bench_weak_scaling.run),
        ("fig8_9_energy", bench_energy.run),
        ("roofline_table", bench_roofline.run),
        ("scheduler_and_store", bench_scheduler.run),
        ("workers_plane", bench_workers.run),
        ("regression_gate", bench_regression.run),
        ("analysis_columnar", bench_analysis.run),
        ("harness_family", bench_harnesses.run),
    ]
    if args.only:
        known = [n for n, _ in benches]
        benches = [(n, f) for n, f in benches if args.only in n]
        if not benches:
            # An unmatched filter printing an empty (all-green) summary is a
            # silent CI hole — fail loudly instead.
            print(f"error: --only {args.only!r} matches no bench; "
                  "known: " + ", ".join(known), file=sys.stderr)
            sys.exit(2)

    print("name,us_per_call,derived")
    failures = 0
    rows = []
    for name, fn in benches:
        t0 = time.perf_counter()
        row = {"name": name, "ok": True, "derived": {}}
        try:
            result = fn()
            if isinstance(result, dict):
                # A bench may return structured numbers (speedups, detected
                # indices, ...) — they ride along in the trajectory.
                row["derived"] = result
            print(f"{name}.total,{(time.perf_counter()-t0)*1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            failures += 1
            row["ok"] = False
            row["error"] = f"{type(e).__name__}: {e}"
            print(f"{name}.total,0,FAILED {type(e).__name__}: {e}")
            traceback.print_exc(limit=4, file=sys.stderr)
        row["wall_s"] = round(time.perf_counter() - t0, 3)
        rows.append(row)

    # Atomic replace: the trajectory is the cross-PR perf history — a crash
    # mid-write (or a concurrent run) must never leave a truncated file the
    # next run's loader would reset.
    from repro.core import fingerprint
    from repro.core.store import _atomic_write

    # Each run entry carries the host's environment fingerprint: perf
    # history is only comparable across PRs when the runner conditions
    # (governor, cgroup limits, library set) are visible next to the data.
    fp = fingerprint.capture()
    out = Path(args.out)
    doc = _load_trajectory(out)
    doc["runs"].append({
        "timestamp": time.time(),
        "ok": failures == 0,
        "benches": rows,
        "env_fingerprint": fp,
        "env_key": fingerprint.key(fp),
    })
    _atomic_write(out, json.dumps(doc, indent=2, default=str) + "\n")
    print(f"trajectory: {out} ({len(doc['runs'])} runs)", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
