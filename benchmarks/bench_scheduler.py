"""Scheduler + indexed-store benchmark (the scaling claims of this repo's
concurrency PR):

1. **Parallel collection wall-clock** — the same 8-cell collection through
   ``ExecutionOrchestrator.run_collection`` serially vs. with a 4-worker
   scheduler pool.  Cells are stub workloads with a fixed service time, so
   the ratio isolates scheduler overhead from workload noise.
2. **Indexed query latency** — ``store.query()`` over 200+ stored reports:
   first (cold: manifest scan + parse) vs. repeated (warm: fingerprint hit,
   no re-parse), on both the ``dir`` and ``jsonl`` backends, asserting the
   two backends return byte-identical results.

    PYTHONPATH=src python -m benchmarks.bench_scheduler
"""

from __future__ import annotations

import tempfile
import time
from pathlib import Path

from benchmarks.common import emit
from repro.core.harness import BenchmarkSpec, Harness
from repro.core.orchestrator import ExecutionOrchestrator
from repro.core.protocol import DataEntry, new_report
from repro.core.store import ResultStore

N_CELLS = 8
WORKERS = 4
CELL_SECONDS = 0.05
N_REPORTS = 200
QUERY_REPEATS = 20


class FixedCostHarness(Harness):
    """Constant-service-time cell — models a benchmark run dominated by
    harness wall-clock, the paper's collection bottleneck."""

    name = "fixed-cost"

    def run(self, spec, injections=None):
        time.sleep(CELL_SECONDS)
        r = new_report(system=spec.system, variant=spec.effective_variant(),
                       usecase=spec.shape, pipeline_id="bench")
        r.data.append(DataEntry(success=True, runtime=CELL_SECONDS,
                                metrics={"step_time_s": CELL_SECONDS}))
        return r


def _specs(n):
    return [BenchmarkSpec(arch=f"arch{i}", shape="train_4k", system="bench")
            for i in range(n)]


def _mk_report(i):
    r = new_report(system="bench", variant=f"v{i % 4}", usecase="u",
                   pipeline_id=f"p{i}")
    r.experiment.timestamp = float(i)
    r.data.append(DataEntry(success=True, runtime=0.1,
                            metrics={"step_time_s": 1.0 + i * 1e-3}))
    return r


def bench_parallel_collection(tmp: Path) -> None:
    specs = _specs(N_CELLS)
    t0 = time.perf_counter()
    ExecutionOrchestrator(
        inputs={"prefix": "serial"}, harness=FixedCostHarness(),
        store=ResultStore(tmp / "serial"),
    ).run_collection(specs)
    serial_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    ExecutionOrchestrator(
        inputs={"prefix": "parallel"}, harness=FixedCostHarness(),
        store=ResultStore(tmp / "parallel"),
    ).run_collection(specs, parallelism=WORKERS)
    parallel_s = time.perf_counter() - t0

    emit("scheduler.collection_serial", serial_s * 1e6,
         f"{N_CELLS}cells x {CELL_SECONDS * 1e3:.0f}ms")
    emit("scheduler.collection_parallel", parallel_s * 1e6,
         f"workers={WORKERS} speedup={serial_s / parallel_s:.2f}x")
    assert parallel_s < serial_s, (
        f"parallel ({parallel_s:.3f}s) not faster than serial ({serial_s:.3f}s)"
    )


def bench_indexed_query(tmp: Path) -> None:
    stores = {
        "dir": ResultStore(tmp / "qdir", backend="dir"),
        "jsonl": ResultStore(tmp / "qjsonl", backend="jsonl"),
    }
    reports = [_mk_report(i) for i in range(N_REPORTS)]
    for store in stores.values():
        for r in reports:
            store.append("bench.query", r)

    # The dir backend re-stats every report file on a warm query (per-file
    # tamper detection), so its warm floor is one stat syscall per report;
    # the jsonl backend fingerprints one file, so its warm cost is O(1) in
    # collection size.  ≥10x is asserted where the design promises it.
    min_speedup = {"dir": 5.0, "jsonl": 10.0}
    results = {}
    for name, store in stores.items():
        t0 = time.perf_counter()
        cold = store.query("bench.query")
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(QUERY_REPEATS):
            warm = store.query("bench.query")
        warm_s = (time.perf_counter() - t0) / QUERY_REPEATS
        assert len(cold) == len(warm) == N_REPORTS
        results[name] = [r.to_json() for r in warm]
        speedup = cold_s / warm_s if warm_s > 0 else float("inf")
        emit(f"store.query_cold.{name}", cold_s * 1e6, f"{N_REPORTS}reports")
        emit(f"store.query_warm.{name}", warm_s * 1e6,
             f"cached speedup={speedup:.0f}x")
        assert speedup >= min_speedup[name], (
            f"{name}: warm query only {speedup:.1f}x faster than cold"
        )

    assert results["dir"] == results["jsonl"], "backends disagree on query results"
    emit("store.backend_equivalence", 0.0, "byte-identical")


def run() -> None:
    with tempfile.TemporaryDirectory(prefix="exacb_bench_sched_") as tmp:
        tmp = Path(tmp)
        bench_parallel_collection(tmp)
        bench_indexed_query(tmp)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
