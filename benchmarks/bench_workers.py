"""Distributed execution plane benchmark: process worker pool vs. thread pool.

The claim under test is the reason ``CampaignBroker`` exists: a CPU-bound,
pure-Python harness serializes on the GIL under the thread scheduler, while
N spawned worker processes run it truly in parallel.  The bench

1. calibrates :class:`~repro.core.synthetic.SpinHarness` so one cell costs a
   fixed wall-clock slice on this host (workload noise out, architecture in),
2. runs the same collection through the thread pool and through the broker +
   4 process workers,
3. asserts **result parity first** — the two stores must be byte-identical
   modulo timestamps and execution-plane provenance (``strip_volatile``),
   so the timing comparison is between provably equal work,
4. then asserts the speedup budget, gated on the host's usable CPUs:
   ``>= 2.5x`` with 4+ CPUs (the CI budget), ``>= 1.2x`` with 2-3, and
   report-only on a single-CPU host (process workers cannot beat the GIL
   without a second core — the numbers are still emitted and tracked).

    PYTHONPATH=src python -m benchmarks.bench_workers
"""

from __future__ import annotations

import os
import tempfile
import time
from pathlib import Path

from benchmarks.common import emit
from repro.core import accounting
from repro.core.harness import BenchmarkSpec
from repro.core.orchestrator import ExecutionOrchestrator
from repro.core.store import ResultStore
from repro.core.synthetic import SpinHarness

WORKERS = 4
FULL_CELLS = 12
FULL_CELL_SECONDS = 0.6   # per-cell target on multi-core hosts
SMALL_CELLS = 4
SMALL_CELL_SECONDS = 0.1  # single-CPU hosts: parity + reporting only
CALIBRATION_ITERS = 60_000


def _usable_cpus() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:  # non-Linux
        return os.cpu_count() or 1


def _specs(n):
    return [BenchmarkSpec(arch=f"arch{i}", shape="train_4k", system="bench")
            for i in range(n)]


def _calibrate(target_s: float) -> int:
    """Iteration count for which one SpinHarness cell costs ~``target_s``."""
    probe = SpinHarness(iters=CALIBRATION_ITERS)
    spec = _specs(1)[0]
    probe.run(spec)  # warm the interpreter
    t0 = time.perf_counter()
    probe.run(spec)
    per_iter = (time.perf_counter() - t0) / CALIBRATION_ITERS
    return max(10_000, int(target_s / per_iter))


def _canon(store: ResultStore, prefix: str):
    import json

    return sorted(json.dumps(accounting.strip_volatile(r.to_dict()),
                             sort_keys=True)
                  for r in store.query(prefix))


def _run(tmp: Path, label: str, specs, harness, **collection_kwargs):
    store = ResultStore(tmp / label)
    ex = ExecutionOrchestrator(inputs={"prefix": "bench"}, harness=harness,
                               store=store)
    t0 = time.perf_counter()
    results = ex.run_collection(specs, **collection_kwargs)
    wall = time.perf_counter() - t0
    assert all(r.readiness > 0 for r in results), (
        f"{label}: {[r.error for r in results if r.readiness == 0]}")
    return store, wall


def run() -> dict:
    cpus = _usable_cpus()
    if cpus >= 2:
        n_cells, cell_s = FULL_CELLS, FULL_CELL_SECONDS
    else:
        n_cells, cell_s = SMALL_CELLS, SMALL_CELL_SECONDS
    iters = _calibrate(cell_s)
    specs = _specs(n_cells)
    harness = SpinHarness(iters=iters)

    with tempfile.TemporaryDirectory(prefix="exacb_bench_workers_") as tmp:
        tmp = Path(tmp)
        t_store, thread_s = _run(tmp, "thread", specs, harness,
                                 parallelism=WORKERS)
        p_store, process_s = _run(tmp, "process", specs, harness,
                                  workers=WORKERS, worker_mode="process")

        # Parity BEFORE timing claims: identical campaigns modulo timestamps
        # and resource accounting, or the speedup below compares unequal work.
        assert _canon(t_store, "bench") == _canon(p_store, "bench"), (
            "thread- and process-mode stores diverge (beyond volatile fields)")
        emit("workers.store_parity", 0.0, "byte-identical modulo volatile")

        # The accounting that makes `campaign-report` answer "what did this
        # campaign cost": every process-mode cell carries its resources.
        cpu_total = 0.0
        for report in p_store.query("bench"):
            res = report.parameter["resources"]
            assert res["worker_mode"] == "process"
            cpu_total += res["res_cpu_s"]
        emit("workers.campaign_cpu_s", cpu_total * 1e6,
             f"{n_cells}cells process-mode attributed CPU")

    speedup = thread_s / process_s if process_s > 0 else float("inf")
    emit("workers.collection_thread", thread_s * 1e6,
         f"{n_cells}cells x {cell_s * 1e3:.0f}ms GIL-bound")
    emit("workers.collection_process", process_s * 1e6,
         f"workers={WORKERS} speedup={speedup:.2f}x cpus={cpus}")

    # The perf budget, CPU-gated: spawned interpreters cannot outrun the GIL
    # without cores to run on.
    if cpus >= WORKERS:
        budget = 2.5
    elif cpus >= 2:
        budget = 1.2
    else:
        budget = None
    if budget is not None:
        assert speedup >= budget, (
            f"process pool only {speedup:.2f}x faster than threads "
            f"(budget {budget}x at {cpus} CPUs)")
    return {
        "speedup_process_vs_thread": round(speedup, 3),
        "thread_s": round(thread_s, 3),
        "process_s": round(process_s, 3),
        "cells": n_cells,
        "workers": WORKERS,
        "cpus": cpus,
        "enforced_budget": budget,
    }


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
