"""Regression-gate benchmark: detector + gate cost over a 1k-report history.

Two measurements:

1. **Per-detector cost** on in-memory arrays sized like a 1k-point history —
   the pure statistical cost (MAD, 400-replicate bootstrap, 128-permutation
   CUSUM), independent of storage.
2. **Warm gate evaluation** — a full ``RegressionGate.run`` over a 1k-report
   jsonl store after one cold run has primed the caches.  The gate now
   judges from the incremental columnar plane (``store.columnar``), so the
   warm path is a fingerprint stat + numpy masks instead of a Python walk
   over parsed reports; the PR-2 **50 ms budget** is asserted on this
   columnar path (see ``bench_analysis.py`` for the columnar-vs-report-path
   speedup race).

    PYTHONPATH=src python -m benchmarks.bench_regression
"""

from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit
from repro.core.protocol import DataEntry, new_report
from repro.core.regression import GateSpec, MetricSpec, RegressionGate, get_detector
from repro.core.store import ResultStore

N_REPORTS = 1000
WARM_REPEATS = 10
BUDGET_S = 0.050


def _seed(store: ResultStore) -> None:
    rng = np.random.default_rng(0)
    for i in range(N_REPORTS):
        v = float(1.0 + rng.normal(0, 0.02))
        r = new_report(system="bench", variant="v", usecase="u",
                       pipeline_id=f"p{i}")
        r.data.append(DataEntry(success=True, runtime=v,
                                metrics={"step_time_s": v}))
        store.append("bench.gate", r)


def bench_detectors() -> None:
    rng = np.random.default_rng(1)
    hist = list(1.0 + rng.normal(0, 0.02, N_REPORTS - 8))
    cand = list(1.0 + rng.normal(0, 0.02, 8))
    spec = MetricSpec("step_time_s")
    seqs = list(range(N_REPORTS))
    for name in ("mad", "bootstrap", "cusum"):
        det = get_detector(name)
        det.verdict(hist, cand, spec, baseline_seqs=seqs[:-8],
                    candidate_seqs=seqs[-8:])  # warmup
        t0 = time.perf_counter()
        for _ in range(WARM_REPEATS):
            det.verdict(hist, cand, spec, baseline_seqs=seqs[:-8],
                        candidate_seqs=seqs[-8:])
        per_call = (time.perf_counter() - t0) / WARM_REPEATS
        emit(f"regression.detector.{name}", per_call * 1e6,
             f"{N_REPORTS}pt history")


def bench_warm_gate(tmp: Path) -> None:
    store = ResultStore(tmp / "store", backend="jsonl")
    _seed(store)
    # No baseline promotion / verdict recording: those are appends, and this
    # measures the read+judge hot path a gate adds to every pipeline run.
    gate = RegressionGate(GateSpec(
        source_prefix="bench.gate",
        metrics=[MetricSpec("step_time_s")],
        history=N_REPORTS, window=64, candidate=8,
        update_baseline=False, record_prefix="none",
    ))
    t0 = time.perf_counter()
    cold = gate.run(store)  # parses all 1k reports, primes the query cache
    cold_s = time.perf_counter() - t0
    assert cold["status"] == "pass", cold

    t0 = time.perf_counter()
    for _ in range(WARM_REPEATS):
        warm = gate.run(store)
    warm_s = (time.perf_counter() - t0) / WARM_REPEATS
    assert warm["status"] == "pass", warm

    emit("regression.gate_cold", cold_s * 1e6, f"{N_REPORTS}reports jsonl")
    emit("regression.gate_warm", warm_s * 1e6,
         f"budget={BUDGET_S * 1e3:.0f}ms speedup={cold_s / warm_s:.1f}x "
         f"(columnar path)")
    assert warm_s < BUDGET_S, (
        f"warm columnar gate {warm_s * 1e3:.1f}ms over the "
        f"{BUDGET_S * 1e3:.0f}ms budget"
    )


def run() -> None:
    bench_detectors()
    with tempfile.TemporaryDirectory(prefix="exacb_bench_gate_") as tmp:
        bench_warm_gate(Path(tmp))


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
