"""Roofline table generator (deliverable g) — per (arch × shape × mesh):
the three terms, dominant bottleneck, MODEL_FLOPS/HLO ratio, HBM fit, and
the one-line improvement suggestion.  Emits the markdown table consumed by
EXPERIMENTS.md §Roofline."""

from __future__ import annotations

from pathlib import Path
from typing import Dict, List

from benchmarks.common import RESULTS, emit, load_dryrun_records

COLUMNS = (
    "arch", "shape", "mesh", "strat", "t_comp_ms", "t_mem_ms", "t_coll_ms",
    "dominant", "useful", "mem_useful", "rf", "hbm_gb", "fits",
)


def table_rows(records: List[dict]) -> List[Dict]:
    rows = []
    for r in sorted(records, key=lambda x: (x["arch"], x["shape"], x["system"])):
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "mesh": "2pod" if "2pods" in r["system"] else "1pod",
            "strat": r["strategy"],
            "t_comp_ms": rl["t_compute"] * 1e3,
            "t_mem_ms": rl["t_memory"] * 1e3,
            "t_coll_ms": rl["t_collective"] * 1e3,
            "dominant": rl["dominant"],
            "useful": rl["useful_ratio"],
            "mem_useful": rl.get("memory_useful_ratio", 0.0),
            "rf": rl["roofline_fraction"],
            "hbm_gb": rl["hbm_required"] / 1e9,
            "fits": rl["fits"],
            "suggestion": r.get("suggestion", ""),
        })
    return rows


def to_markdown(rows: List[Dict]) -> str:
    lines = ["| " + " | ".join(COLUMNS) + " |", "|" + "---|" * len(COLUMNS)]
    for row in rows:
        cells = []
        for c in COLUMNS:
            v = row[c]
            cells.append(f"{v:.3f}" if isinstance(v, float) else str(v))
        lines.append("| " + " | ".join(cells) + " |")
    return "\n".join(lines) + "\n"


def _load_dir(dirname: str):
    import json
    d = RESULTS / dirname
    out = []
    if not d.exists():
        return out
    for p in sorted(d.glob("*.json")):
        try:
            rec = json.loads(p.read_text())
        except json.JSONDecodeError:
            continue
        if rec.get("status") == "ok":
            from benchmarks.common import is_baseline_record

            if is_baseline_record(rec):
                out.append(rec)
    return out


def run() -> dict:
    sections = []
    counts = {}
    for title, dirname in (
        ("Baseline (paper-faithful first compile)", "dryrun_baseline_v0"),
        ("Optimized (post §Perf framework defaults)", "dryrun"),
    ):
        recs = _load_dir(dirname)
        rows = table_rows(recs)
        dom = {}
        for row in rows:
            dom[row["dominant"]] = dom.get(row["dominant"], 0) + 1
        counts[dirname] = {"n": len(rows), "dominant": dom}
        sections.append(f"## {title} — {len(rows)} cells\n\n" + to_markdown(rows))
    out = RESULTS / "roofline_table.md"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text("\n\n".join(sections))
    emit("roofline_table", float(sum(v["n"] for v in counts.values())),
         f"{counts} -> {out}")
    return {"counts": counts, "path": str(out)}


if __name__ == "__main__":
    print(run())
