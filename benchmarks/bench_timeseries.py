"""Figs. 3 & 4 — continuous time-series benchmarking with regression flags.

BabelStream analogue (Fig. 3): a memory-bandwidth triad microbenchmark run
as N scheduled "pipelines"; the series stays flat and no regression fires.

GRAPH500 analogue (Fig. 4): a gather/scatter irregular-access benchmark
whose implementation is switched mid-series by a *feature injection*
(sorted -> shuffled indices — a real performance change, like the system
update in the paper's figure); the post-processing orchestrator detects it.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_STORE, emit, timeit
from repro.core.orchestrator import PostProcessingOrchestrator
from repro.core.protocol import DataEntry, new_report
from repro.core.store import ResultStore

N_RUNS = 24
SWITCH_AT = 16
SIZE = 1 << 21


def _triad():
    b = jnp.arange(SIZE, dtype=jnp.float32)
    c = jnp.ones(SIZE, jnp.float32)

    @jax.jit
    def step(b, c):
        return b + 0.3 * c

    return lambda: step(b, c)


def _gather(sorted_idx: bool):
    rng = np.random.default_rng(0)
    idx = np.arange(SIZE) if sorted_idx else rng.permutation(SIZE)
    idx_j = jnp.asarray(idx, jnp.int32)
    src = jnp.arange(SIZE, dtype=jnp.float32)

    @jax.jit
    def step(src, idx_j):
        return jnp.take(src, idx_j).sum()

    return lambda: step(src, idx_j)


def run() -> dict:
    store = ResultStore(BENCH_STORE)
    t0 = time.time()
    triad = _triad()
    for i in range(N_RUNS):
        dt = timeit(lambda: triad(), iters=3)
        bw = SIZE * 4 * 3 / dt / 1e9  # read b, read c, write out
        r = new_report(system="cpu-smoke", variant="stream.triad",
                       usecase="bandwidth", pipeline_id=f"pl-{i}")
        r.experiment.timestamp = t0 + i
        r.data.append(DataEntry(success=True, runtime=dt,
                                metrics={"triad_bw_gbs": bw, "step_time_s": dt}))
        store.append("bench.stream", r)

    for i in range(N_RUNS):
        g = _gather(sorted_idx=i < SWITCH_AT)
        dt = timeit(lambda: g(), iters=3)
        r = new_report(system="cpu-smoke", variant="graph.gather",
                       usecase="irregular", pipeline_id=f"pl-{i}")
        r.experiment.timestamp = t0 + i
        r.data.append(DataEntry(success=True, runtime=dt,
                                metrics={"gather_time_s": dt, "step_time_s": dt}))
        store.append("bench.graph", r)

    pp = PostProcessingOrchestrator(store=store, inputs={"prefix": "evaluation.ts"})
    # Virtualized single-core host: wall-time noise is 10-25%, so the gate is
    # widened accordingly (a quiet TPU pod would run the 5% default).
    det = {"min_rel": 0.3, "z_threshold": 6.0}
    stream = pp.time_series(source_prefix="bench.stream",
                            data_labels=["triad_bw_gbs"], detector=det)
    graph = pp.time_series(source_prefix="bench.graph",
                           data_labels=["gather_time_s"], detector=det)
    n_stream_regs = len(stream["regressions"]["triad_bw_gbs"])
    graph_regs = graph["regressions"]["gather_time_s"]
    detected = graph_regs[0]["index"] if graph_regs else -1

    med_triad = float(np.median([v for _, v in stream["series"]["triad_bw_gbs"]]))
    emit("fig3_stream_triad", timeit(lambda: triad(), iters=3) * 1e6,
         f"bw={med_triad:.2f}GB/s regressions={n_stream_regs}")
    emit("fig4_graph_regression", timeit(lambda: _gather(False)(), iters=3) * 1e6,
         f"switch_at={SWITCH_AT} detected_at={detected}")
    return {
        "stream_regressions": n_stream_regs,
        "graph_detected_at": detected,
        "expected_at": SWITCH_AT,
    }


if __name__ == "__main__":
    print(run())
