"""Fig. 5 — machine comparison / strong scaling across systems.

The paper compares time-to-solution across JEDI / JUWELS-Booster / JURECA.
Our "machines" are the production meshes: per architecture we compare the
roofline-bound step time on v5e-pod-16x16 (256 chips) vs v5e-2pods (512
chips), computed from the stored dry-run records — a strong-scaling check
(same global problem, 2x chips) with the paper's 80%-efficiency band.
"""

from __future__ import annotations

from collections import defaultdict

from benchmarks.common import emit, is_baseline_record, load_dryrun_records
from repro.core import analysis


def run() -> dict:
    recs = load_dryrun_records()
    by_cell = defaultdict(dict)
    for r in recs:
        # Strong scaling needs the SAME global problem AND knobs on both
        # meshes — exclude hillclimb/weak-scaling variants.
        if not is_baseline_record(r):
            continue
        key = (r["arch"], r["shape"])
        pods = 2 if "2pods" in r["system"] else 1
        t = r["roofline"]["step_time_bound_s"]
        cur = by_cell[key].get(pods)
        by_cell[key][pods] = min(cur, t) if cur else t

    table = {}
    for (arch, shape), times in sorted(by_cell.items()):
        if 1 in times and 2 in times and shape == "train_4k":
            sc = analysis.strong_scaling({256: times[1], 512: times[2]})
            eff = sc[512]["efficiency"]
            table[f"{arch}.{shape}"] = {
                "t_256": times[1],
                "t_512": times[2],
                "efficiency": eff,
                "within_80pct_band": sc[512]["within_band"],
            }
    n_in_band = sum(1 for v in table.values() if v["within_80pct_band"])
    for k, v in table.items():
        emit(f"fig5_strong_scaling.{k}", v["t_512"] * 1e6,
             f"eff={v['efficiency']:.3f} band={v['within_80pct_band']}")
    return {"cells": table, "in_band": n_in_band, "total": len(table)}


if __name__ == "__main__":
    print(run())
