"""Shared helpers for the benchmark harnesses (one per paper figure)."""

from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results"
DRYRUN_DIR = RESULTS / "dryrun"
BENCH_STORE = RESULTS / "bench_store"


def timeit(fn: Callable, *args, iters: int = 5, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def load_dryrun_records(pattern: str = "*.json") -> List[Dict]:
    if not DRYRUN_DIR.exists():
        return []
    out = []
    for p in sorted(DRYRUN_DIR.glob(pattern)):
        try:
            rec = json.loads(p.read_text())
        except json.JSONDecodeError:
            continue
        if rec.get("status") == "ok":
            out.append(rec)
    return out


def is_baseline_record(rec: Dict) -> bool:
    """True for records produced with the sweep's default knobs (excludes
    hillclimb/weak-scaling variants that share the directory)."""
    from repro.configs import shapes as SH

    knobs = rec.get("knobs", {})
    default_gb = SH.SHAPES[rec["shape"]].global_batch
    if knobs.get("global_batch") not in (None, default_gb):
        return False
    if knobs.get("remat", "dots") != "dots":
        return False
    return True


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The run.py CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.3f},{derived}")
