"""Harness-family + autotune-plane benchmark.

Measures the pieces the autotuning loop pays for on every sweep point:

* a ``KernelHarness`` flash_attention cell at two block configs (interpret
  mode — relative, not absolute, numbers on CPU), reporting per-call
  latency and which config wins at this tiny shape;
* the ``AutotuneCache`` lookup path (what every ``ops.py`` call with
  unresolved blocks pays when ``EXACB_AUTOTUNE_CACHE`` is set) — must stay
  in the microsecond range since it sits in front of kernel dispatch;
* Poisson arrival generation for the serve load path.

    PYTHONPATH=src python -m benchmarks.bench_harnesses
"""

from __future__ import annotations

import tempfile
import time
from pathlib import Path
from typing import Dict

from benchmarks.common import emit

CACHE_LOOKUPS = 2000
ARRIVAL_CALLS = 200


def run() -> Dict[str, float]:
    from repro.core import fingerprint
    from repro.core.autotune import AutotuneCache, cached_blocks, reset_runtime_caches
    from repro.core.harness import BenchmarkSpec, Injections
    from repro.harnesses.kernel import KernelHarness
    from repro.harnesses.serve import poisson_arrivals

    derived: Dict[str, float] = {}

    harness = KernelHarness(
        kernel="flash_attention", batch=1, heads=2, seq=64, head_dim=8,
        calls=2, warmup=1, interpret=True, use_cache=False)
    spec = BenchmarkSpec(arch="kernel", shape="fa_bench", system="local")
    latencies: Dict[int, float] = {}
    for bq in (16, 64):
        rep = harness.run(spec, Injections(overrides={"block_q": bq, "block_k": bq}))
        lat = float(rep.data[-1].metrics["kernel_latency_s"])
        latencies[bq] = lat
        emit(f"harness.fa_block{bq}", lat * 1e6, "kernel_latency")
        derived[f"fa_block{bq}_us"] = round(lat * 1e6, 1)
    derived["winner_block"] = min(latencies, key=latencies.get)

    with tempfile.TemporaryDirectory() as tmp:
        path = Path(tmp) / "autotune_cache.json"
        fp_key = fingerprint.key(fingerprint.capture())
        AutotuneCache(path).put(
            "flash_attention", "B1.H2.T64.D8", "float32", fp_key,
            {"block_q": 16, "block_k": 16})
        reset_runtime_caches()
        assert cached_blocks("flash_attention", "B1.H2.T64.D8", "float32",
                             path=path) is not None
        t0 = time.perf_counter()
        for _ in range(CACHE_LOOKUPS):
            cached_blocks("flash_attention", "B1.H2.T64.D8", "float32", path=path)
        per = (time.perf_counter() - t0) / CACHE_LOOKUPS
        emit("harness.cache_lookup", per * 1e6, f"{CACHE_LOOKUPS} warm lookups")
        derived["cache_lookup_us"] = round(per * 1e6, 2)

    t0 = time.perf_counter()
    for i in range(ARRIVAL_CALLS):
        poisson_arrivals(64, 50.0, seed=i)
    per = (time.perf_counter() - t0) / ARRIVAL_CALLS
    emit("harness.poisson_64", per * 1e6, "64-request arrival schedule")
    derived["poisson_64_us"] = round(per * 1e6, 2)

    return derived


if __name__ == "__main__":
    print("name,us_per_call,derived")
    print(run())
