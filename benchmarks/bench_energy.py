"""Figs. 8 & 9 — energy-aware benchmarking via launcher injection.

Fig. 8: power-trace scope trimming (start-up/wind-down excluded by the
semi-automatic black bars) — demonstrated on a synthesized v5e trace and on
a real measured smoke run through the injected energy launcher.

Fig. 9: energy-to-solution vs processor frequency for two contrast
workloads drawn from the stored dry-run rooflines (one compute-bound, one
memory-bound), locating the energy sweet spot per workload.
"""

from __future__ import annotations

from benchmarks.common import BENCH_STORE, emit, load_dryrun_records
from repro.core import energy
from repro.core.harness import BenchmarkSpec, ExecHarness, Injections
from repro.hardware import TPU_V5E, SINGLE_POD


def run() -> dict:
    # --- Fig. 8: scope-trimmed energy on a synthesized trace ---
    trace = energy.synth_power_trace(TPU_V5E, steady_power=250.0, n_samples=96, ramp=12)
    scoped = energy.scoped_energy(trace, dt_s=0.5)
    full = sum(trace) * 0.5
    underestimate = 1.0 - scoped["scoped_energy_j"] / full

    # Fig. 8 live variant: inject the energy launcher into a real smoke run.
    h = ExecHarness(steps=2, batch=2, seq=32)
    rep = h.run(
        BenchmarkSpec(arch="gemma3-4b", shape="train_4k", system="cpu-smoke"),
        Injections(launcher=energy.energy_launcher(TPU_V5E, n_chips=1)),
    )
    measured = rep.data[0].metrics.get("energy_to_solution_j", 0.0)

    # --- Fig. 9: frequency sweep per workload from dry-run rooflines ---
    recs = load_dryrun_records("*.1pod.json")
    sweet = {}
    for r in recs:
        rl = r["roofline"]
        sweep = energy.frequency_sweep(
            TPU_V5E,
            t_compute=rl["t_compute"],
            t_memory=rl["t_memory"],
            t_collective=rl["t_collective"],
            n_chips=SINGLE_POD.n_chips,
        )
        sweet[f'{r["arch"]}.{r["shape"]}'] = energy.sweet_spot(sweep)

    emit("fig8_scope_trim", scoped["scope_end"] - scoped["scope_start"],
         f"underestimate={underestimate:.3f} live_energy_j={measured:.1f}")
    if sweet:
        lo = min(sweet, key=sweet.get)
        hi = max(sweet, key=sweet.get)
        emit("fig9_freq_sweep", len(sweet), f"lowest_sweet={lo}@{sweet[lo]} "
             f"highest_sweet={hi}@{sweet[hi]}")
    return {
        "scope": scoped,
        "underestimate": underestimate,
        "live_energy_j": measured,
        "sweet_spots": sweet,
    }


if __name__ == "__main__":
    print(run())
