"""Append synthetic slowed-down reports to a result-store prefix.

CI helper for exercising the regression gate's failure path: after the smoke
pipeline has built up healthy history, this makes the guarded metric jump by
``--factor``, so the next ``python -m repro.core.cicd ... --gate`` run must
exit 3 and name the injected sequence as the change point.

    PYTHONPATH=src python scripts/ci_inject_slowdown.py \
        --store gate_store --prefix ci.smoke --metric step_time_s \
        --factor 20 --count 6

``--duet`` switches to the paired failure path: instead of absolute slow
reports it appends ``--count`` complete duet *rounds* under one fresh
``duet_id`` — baseline at the historical median, candidate ``--factor``×
slower, both sides of each round scaled by the same per-round jitter
(``--noise``) so only the *paired* detector can see through the noise.
Every injected report carries this host's real environment fingerprint, so
the resulting ``gate_report.json`` proves fingerprints flow end to end.
"""

from __future__ import annotations

import argparse
import hashlib
import statistics
import uuid

from repro.core import duet, fingerprint
from repro.core.protocol import DataEntry, new_report
from repro.core.store import ResultStore


def _inject_absolute(store, args, base: float) -> None:
    slow = base * args.factor
    for i in range(args.count):
        rep = new_report(system="synthetic-slowdown", variant="injected",
                         usecase=args.prefix, pipeline_id=f"inject-{i}")
        rep.data.append(DataEntry(success=True, runtime=slow,
                                  metrics={args.metric: slow}))
        store.append(args.prefix, rep)
    print(f"appended {args.count} reports with {args.metric}={slow:.6g} "
          f"to {args.prefix} (median was {base:.6g})")


def _inject_duet(store, args, base: float) -> None:
    fp = fingerprint.capture()
    duet_id = uuid.uuid4().hex[:12]
    for i in range(args.count):
        # One jitter per round, shared by both roles — the environmental
        # noise model the paired gate exists to divide out.
        h = int(hashlib.sha256(f"inject.{i}".encode()).hexdigest()[:8], 16)
        jitter = 1.0 + args.noise * (h / 0xFFFFFFFF)
        for role, factor in ((duet.ROLE_BASELINE, 1.0),
                             (duet.ROLE_CANDIDATE, args.factor)):
            val = base * jitter * factor
            rep = new_report(system="synthetic-slowdown", variant="injected",
                             usecase=args.prefix,
                             pipeline_id=f"inject-duet-{i}-{role}")
            rep.parameter[duet.PARAMETER] = duet.tag(duet_id, role, i, args.count)
            fingerprint.stamp(rep, fp)
            rep.data.append(DataEntry(success=True, runtime=val,
                                      metrics={args.metric: val}))
            store.append(args.prefix, rep)
    print(f"appended {args.count} duet rounds ({duet_id}) with candidate "
          f"{args.metric} at {args.factor}x baseline {base:.6g} "
          f"(noise {args.noise})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--store", required=True)
    ap.add_argument("--store-backend", default="dir", choices=("dir", "jsonl"))
    ap.add_argument("--prefix", default="ci.smoke")
    ap.add_argument("--metric", default="step_time_s")
    ap.add_argument("--factor", type=float, default=20.0)
    ap.add_argument("--count", type=int, default=6)
    ap.add_argument("--duet", action="store_true",
                    help="inject paired duet rounds (candidate slowed) "
                         "instead of absolute slow reports")
    ap.add_argument("--noise", type=float, default=0.3,
                    help="per-round shared jitter amplitude for --duet")
    args = ap.parse_args(argv)

    store = ResultStore(args.store, backend=args.store_backend)
    vals = [
        float(d.metrics[args.metric])
        for r in store.query(args.prefix)
        for d in r.data
        if args.metric in d.metrics
    ]
    if not vals:
        raise SystemExit(f"no {args.metric!r} history under {args.prefix!r} "
                         f"in {args.store}")
    base = statistics.median(vals)
    if args.duet:
        _inject_duet(store, args, base)
    else:
        _inject_absolute(store, args, base)
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
