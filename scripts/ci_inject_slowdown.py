"""Append synthetic slowed-down reports to a result-store prefix.

CI helper for exercising the regression gate's failure path: after the smoke
pipeline has built up healthy history, this makes the guarded metric jump by
``--factor``, so the next ``python -m repro.core.cicd ... --gate`` run must
exit 3 and name the injected sequence as the change point.

    PYTHONPATH=src python scripts/ci_inject_slowdown.py \
        --store gate_store --prefix ci.smoke --metric step_time_s \
        --factor 20 --count 6
"""

from __future__ import annotations

import argparse
import statistics

from repro.core.protocol import DataEntry, new_report
from repro.core.store import ResultStore


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--store", required=True)
    ap.add_argument("--store-backend", default="dir", choices=("dir", "jsonl"))
    ap.add_argument("--prefix", default="ci.smoke")
    ap.add_argument("--metric", default="step_time_s")
    ap.add_argument("--factor", type=float, default=20.0)
    ap.add_argument("--count", type=int, default=6)
    args = ap.parse_args(argv)

    store = ResultStore(args.store, backend=args.store_backend)
    vals = [
        float(d.metrics[args.metric])
        for r in store.query(args.prefix)
        for d in r.data
        if args.metric in d.metrics
    ]
    if not vals:
        raise SystemExit(f"no {args.metric!r} history under {args.prefix!r} "
                         f"in {args.store}")
    slow = statistics.median(vals) * args.factor
    for i in range(args.count):
        rep = new_report(system="synthetic-slowdown", variant="injected",
                         usecase=args.prefix, pipeline_id=f"inject-{i}")
        rep.data.append(DataEntry(success=True, runtime=slow,
                                  metrics={args.metric: slow}))
        store.append(args.prefix, rep)
    print(f"appended {args.count} reports with {args.metric}={slow:.6g} "
          f"to {args.prefix} (median was {statistics.median(vals):.6g})")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
