"""Chaos CI: replay fixed seeded fault scenarios over the reference
collection pipeline and assert store parity with a fault-free run.

Each scenario drains the producer cells of
``examples/pipelines/collection.yml`` through the real broker + spawned
process workers while ``EXACB_CHAOS`` injects a scripted fault sequence
(see ``repro.core.chaos`` and ``docs/failure_model.md``):

* ``kill-mid-append``      — SIGKILL the worker at its 3rd store append;
  the reclaimed retry must re-execute without duplicating any record.
* ``stall-past-lease``     — every worker's first claim stalls past the
  lease timeout; the fencing token must drop the stale attempt's appends.
* ``enospc-on-claim``      — the first ``claim_next`` per worker raises
  ``ENOSPC``; the bounded retry must absorb it transparently.
* ``skewed-clock-reclaim`` — one reclaim pass per process runs with a
  clock +1h fast and steals every live lease; adoption + fencing must
  still converge on exactly one record per cell.

After every scenario the store canon (``strip_volatile``) must be
byte-identical to the fault-free baseline — the exactly-once guarantee,
checked under injected faults instead of the happy path.  On failure the
scenario's full spec (seed included) is printed for local replay:

    EXACB_CHAOS='<spec>' PYTHONPATH=src python scripts/ci_chaos.py --only <name>
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from pathlib import Path

from repro.core import accounting, chaos
from repro.core.cicd import parse_pipeline_text
from repro.core.store import ResultStore
from repro.core.synthetic import SpinHarness
from repro.core.workers import CampaignBroker, pipeline_payloads

PIPELINE = Path("examples/pipelines/collection.yml")

#: (name, chaos spec, broker overrides).  Seeds are FIXED: a red run is
#: replayable bit-for-bit by exporting the printed spec locally.
SCENARIOS = [
    ("kill-mid-append",
     "seed=9001;site=store.append:kind=kill:at=3:times=1",
     {"workers": 1, "lease_timeout": 1.0}),
    ("stall-past-lease",
     "seed=9002;site=worker.claimed:kind=stall:at=1:dur=2.5",
     {"workers": 2, "lease_timeout": 1.0}),
    ("enospc-on-claim",
     "seed=9003;site=queue.claim:kind=enospc:at=1",
     {"workers": 2}),
    ("skewed-clock-reclaim",
     "seed=9004;site=queue.reclaim:kind=skew:skew=3600:times=1",
     {"workers": 2, "max_attempts": 5}),
]


def _producer_payloads():
    calls = parse_pipeline_text(PIPELINE.read_text())
    payloads, _owners = pipeline_payloads(calls)
    if not payloads:
        raise SystemExit(f"no producer cells in {PIPELINE}")
    return payloads


def _drain(store_root: Path, payloads, name: str, overrides) -> dict:
    store = ResultStore(store_root)
    broker = CampaignBroker(store, name=name, **overrides)
    results = broker.run(payloads, harness=SpinHarness(iters=2000))
    return {"store": store, "results": results}


def _canon(store: ResultStore, prefix: str):
    return sorted(json.dumps(accounting.strip_volatile(r.to_dict()),
                             sort_keys=True)
                  for r in store.query(prefix))


def _prefixes(payloads):
    return sorted({p.get("prefix", "default") for p in payloads})


def run_scenario(name: str, spec: str, overrides, payloads, baseline,
                 work: Path) -> None:
    # Export the scenario and re-initialize THIS process's engine from it;
    # spawned workers pick it up lazily from the inherited environment.
    os.environ[chaos.ENV_VAR] = spec
    chaos.reset()
    try:
        out = _drain(work / f"store_{name}", payloads, f"chaos-{name}",
                     dict(overrides))
    finally:
        os.environ.pop(chaos.ENV_VAR, None)
        chaos.install(None)

    failures = [
        (idx, r.get("error"))
        for idx, r in sorted(out["results"].items())
        if r.get("error") or int(r.get("readiness", 0)) < 1
    ]
    assert not failures, f"cells failed under chaos: {failures}"
    for prefix in _prefixes(payloads):
        got = _canon(out["store"], prefix)
        want = _canon(baseline["store"], prefix)
        assert len(got) == len(want), (
            f"prefix {prefix!r}: {len(got)} records vs {len(want)} fault-free "
            "(duplicate or lost append)")
        assert got == want, f"prefix {prefix!r}: store canon diverged"
    attempts = [int(r.get("attempts", 1)) for r in out["results"].values()]
    print(f"  ok: {len(out['results'])} cells, "
          f"attempts per cell {sorted(attempts)}, parity holds")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--only", default=None,
                    help="run a single scenario by name")
    ap.add_argument("--workdir", default="chaos_ci",
                    help="scratch directory for the per-scenario stores")
    args = ap.parse_args(argv)

    work = Path(args.workdir)
    if work.exists():
        shutil.rmtree(work)
    work.mkdir(parents=True)

    payloads = _producer_payloads()
    print(f"fault-free baseline: {len(payloads)} producer cells "
          f"from {PIPELINE}")
    chaos.install(None)  # the baseline must see zero injection
    baseline = _drain(work / "store_baseline", payloads, "chaos-baseline",
                      {"workers": 2})
    base_failures = [(i, r.get("error"))
                     for i, r in sorted(baseline["results"].items())
                     if r.get("error")]
    if base_failures:
        print(f"baseline itself failed: {base_failures}", file=sys.stderr)
        return 1

    selected = [s for s in SCENARIOS
                if args.only is None or s[0] == args.only]
    if not selected:
        print(f"unknown scenario {args.only!r}; have "
              f"{[s[0] for s in SCENARIOS]}", file=sys.stderr)
        return 2
    failed = []
    for name, spec, overrides in selected:
        print(f"scenario {name}: EXACB_CHAOS='{spec}'")
        try:
            run_scenario(name, spec, overrides, payloads, baseline, work)
        except AssertionError as e:
            failed.append(name)
            print(f"  FAILED: {e}\n"
                  f"  replay locally with:\n"
                  f"    EXACB_CHAOS='{spec}' PYTHONPATH=src "
                  f"python scripts/ci_chaos.py --only {name}",
                  file=sys.stderr)
    if failed:
        print(f"chaos scenarios failed: {failed}", file=sys.stderr)
        return 1
    print(f"all {len(selected)} chaos scenario(s) parity-equal to fault-free")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
