"""Capture this host's environment fingerprint to JSON.

CI observability helper: every workflow job runs this once and uploads the
file as an artifact, so when a benchmark or gate result looks suspicious the
first question — *what machine state produced it?* — is answerable from the
run page without re-running anything.

    PYTHONPATH=src python scripts/ci_fingerprint.py --out env_fingerprint.json
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.core import fingerprint


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="env_fingerprint.json")
    args = ap.parse_args(argv)

    fp = fingerprint.capture()
    doc = {"fingerprint": fp, "key": fingerprint.key(fp)}
    out = Path(args.out)
    out.write_text(json.dumps(doc, indent=2, default=str) + "\n")
    stable = {k: fp.get(k) for k in fingerprint.KEY_FIELDS if fp.get(k) is not None}
    print(f"fingerprint -> {out}")
    print(json.dumps(stable, indent=2, default=str))
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
