#!/usr/bin/env python
"""Baseline sweep: every (arch × shape) cell on both production meshes,
orchestrated exactly the way exaCB prescribes — ExecutionOrchestrator +
DryRunHarness, results persisted per-cell into the protocol store (so a
crash mid-sweep loses nothing) plus raw dry-run JSON for EXPERIMENTS.md.

    PYTHONPATH=src python scripts/run_baseline_sweep.py [--systems 1pod 2pod]
        [--archs a b ...] [--shapes s ...] [--store exacb_data]
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.dryrun_harness import DryRunHarness
from repro.core.harness import BenchmarkSpec, Injections
from repro.core.orchestrator import ExecutionOrchestrator
from repro.core.registry import collection
from repro.core.store import ResultStore
from repro.configs import shapes as SH
from repro.hardware import MULTI_POD, SINGLE_POD


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--systems", nargs="*", default=["1pod", "2pod"])
    ap.add_argument("--archs", nargs="*", default=None)
    ap.add_argument("--shapes", nargs="*", default=None)
    ap.add_argument("--store", default="exacb_data")
    ap.add_argument("--raw", default="results/dryrun")
    ap.add_argument("--train-microbatches", type=int, default=8)
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()

    store = ResultStore(args.store)
    harness = DryRunHarness(raw_dir=Path(args.raw), timeout_s=args.timeout)
    sysmap = {"1pod": SINGLE_POD.name, "2pod": MULTI_POD.name}

    t0 = time.time()
    n_ok = n_fail = 0
    for skey in args.systems:
        system = sysmap[skey]
        specs = collection(system, archs=args.archs, shapes=args.shapes)
        ex = ExecutionOrchestrator(
            inputs={"prefix": f"baseline.{skey}", "system": system, "record": True},
            harness=harness,
            store=store,
            max_retries=1,
        )
        for spec in specs:
            shape = SH.SHAPES[spec.shape]
            inj = None
            if shape.kind == SH.TRAIN and args.train_microbatches > 1:
                inj = Injections(overrides={"microbatches": args.train_microbatches})
            t = time.time()
            res = ex.run_cell(spec, inj)
            dt = time.time() - t
            if res.report is not None and res.report.data and res.report.data[0].success:
                m = res.report.data[0].metrics
                print(
                    f"OK   {spec.cell:55s} {dt:6.1f}s dominant={m['dominant']:10s} "
                    f"rf={m['roofline_fraction']:.3f} fits={m['fits']}",
                    flush=True,
                )
                n_ok += 1
            elif res.report is not None and res.report.parameter.get("skipped"):
                print(f"SKIP {spec.cell:55s} (inapplicable)", flush=True)
            else:
                print(f"FAIL {spec.cell:55s} {dt:6.1f}s\n{(res.error or '')[:600]}", flush=True)
                n_fail += 1
    print(f"done: {n_ok} ok, {n_fail} failed in {(time.time()-t0)/60:.1f} min")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
