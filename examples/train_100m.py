"""End-to-end training driver: a ~100M-parameter GLM4-family model trained
for a few hundred steps on the synthetic pipeline, with checkpoint/restart,
exaCB telemetry recording, and post-hoc regression analysis.

    PYTHONPATH=src python examples/train_100m.py [--steps 200] [--resume]

Interrupt it (Ctrl-C) and re-run with --resume: training continues
bit-identically from the last checkpoint (test_substrate proves this at
small scale).
"""

import argparse
import dataclasses
import time

from repro import configs
from repro.checkpoint.checkpoint import CheckpointManager
from repro.core.orchestrator import PostProcessingOrchestrator
from repro.core.protocol import DataEntry, new_report
from repro.core.store import ResultStore
from repro.data.pipeline import DataConfig
from repro.models import params as P
from repro.train import optimizer as O
from repro.train.trainer import TrainConfig, detect_stragglers, train


def build_cfg():
    # ~100M params: glm4 family scaled down (12L x 768, GQA 12/2, vocab 32k).
    return dataclasses.replace(
        configs.get_config("glm4-9b"),
        name="glm4-100m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=2,
        head_dim=64,
        d_ff=2048,
        vocab_size=32768,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="results/ckpt_100m")
    ap.add_argument("--store", default="results/bench_store")
    args = ap.parse_args()

    cfg = build_cfg()
    n = P.count_params_cfg(cfg)
    print(f"{cfg.name}: {n/1e6:.1f}M params, {args.steps} steps, "
          f"batch {args.batch} x seq {args.seq}")

    store = ResultStore(args.store)
    run_report = new_report(system="cpu-smoke", variant="train_100m",
                            usecase="train", pipeline_id=f"run-{int(time.time())}")

    def on_step(step, metrics):
        if step % 10 == 0:
            print(f"step {step:4d}  loss={metrics['loss']:.4f}  "
                  f"{metrics['step_time_s']*1e3:.0f} ms  "
                  f"gnorm={metrics['grad_norm']:.3f}", flush=True)
        run_report.data.append(DataEntry(
            success=True, runtime=metrics["step_time_s"],
            metrics={"loss": metrics["loss"], "step_time_s": metrics["step_time_s"],
                     "step": step},
        ))

    tc = TrainConfig(
        steps=args.steps,
        ckpt_every=50,
        data=DataConfig(seq_len=args.seq, global_batch=args.batch, seed=0),
        opt=O.OptConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps),
        remat="none",
    )
    res = train(cfg, tc, ckpt=CheckpointManager(args.ckpt), on_step=on_step)
    print(f"final loss {res.final_loss:.4f} "
          f"(resumed from {res.restored_from})" if res.restored_from
          else f"final loss {res.final_loss:.4f}")

    stragglers = detect_stragglers(res.step_times)
    print(f"straggler steps flagged: {stragglers[:10]}")
    store.append("train.100m", run_report)
    pp = PostProcessingOrchestrator(store=store, inputs={"prefix": "evaluation.100m"})
    ts = pp.time_series(source_prefix="train.100m", data_labels=["step_time_s"])
    print(f"recorded {len(ts['series']['step_time_s'])} telemetry points, "
          f"{len(ts['regressions']['step_time_s'])} step-time regressions flagged")


if __name__ == "__main__":
    main()
