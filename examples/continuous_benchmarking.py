"""The paper's workflow end-to-end (the JUREAP-mini demo):

1. assemble a benchmark collection (3 architectures x 2 shapes),
2. run it through the Execution Orchestrator (ExecHarness, smoke scale)
   on a 2-worker scheduler pool, with per-cell failure isolation and
   immediate persistence,
3. classify every report on the incremental readiness ladder,
4. feature-inject an energy launcher (jpwr analogue) without touching any
   benchmark definition,
5. post-process: machine comparison + time-series with regression flags,
6. render the paper's Table-I CSV.

    PYTHONPATH=src python examples/continuous_benchmarking.py
"""

import tempfile

from repro.core import analysis
from repro.core.energy import energy_launcher
from repro.core.harness import BenchmarkSpec, ExecHarness, Injections
from repro.core.orchestrator import (
    ExecutionOrchestrator,
    FeatureInjectionOrchestrator,
    PostProcessingOrchestrator,
)
from repro.core.readiness import Readiness
from repro.core.store import ResultStore
from repro.hardware import TPU_V5E


def main():
    tmp = tempfile.mkdtemp(prefix="exacb_demo_")
    store = ResultStore(tmp)
    harness = ExecHarness(steps=2, batch=2, seq=32)

    # 1. collection: heterogeneous families, like JUREAP's portfolio.
    cells = [
        BenchmarkSpec(arch="glm4-9b", shape="train_4k", system="cpu-smoke"),
        BenchmarkSpec(arch="mamba2-1.3b", shape="train_4k", system="cpu-smoke"),
        BenchmarkSpec(arch="recurrentgemma-2b", shape="decode_32k", system="cpu-smoke"),
        BenchmarkSpec(arch="qwen3-moe-235b-a22b", shape="prefill_32k", system="cpu-smoke"),
    ]

    # 2. execution orchestrator (component: execution@v4) on a worker pool —
    #    cells run concurrently, each report persists the moment it lands.
    ex = ExecutionOrchestrator(
        inputs={"prefix": "jureap.mini", "system": "cpu-smoke", "record": True,
                "parallelism": 2},
        harness=harness,
        store=store,
    )
    results = ex.run_collection(cells)

    # 3. readiness ladder.
    print("== collection readiness ==")
    for r in results:
        print(f"  {r.spec.cell:50s} {Readiness(r.readiness).name}")

    # 4. feature injection: energy launcher, benchmark untouched.
    fi = FeatureInjectionOrchestrator(execution=ex, inputs={"prefix": "jureap.mini"})
    res = fi.run(cells[0], Injections(launcher=energy_launcher(TPU_V5E, n_chips=1)))
    e = res.report.data[0].metrics["energy_to_solution_j"]
    print(f"== injected energy measurement: {e:.1f} J (modeled v5e) ==")

    # 5. post-processing orchestrator (decoupled; store-only).
    pp = PostProcessingOrchestrator(store=store, inputs={"prefix": "evaluation.mini"})
    ts = pp.time_series(source_prefix="jureap.mini", data_labels=["step_time_s"])
    print(f"== time-series: {len(ts['series']['step_time_s'])} points, "
          f"{sum(len(v) for v in ts['regressions'].values())} regressions ==")
    from repro.core import export
    print(export.ascii_timeseries(ts["series"]["step_time_s"],
                                  title="step_time_s (Fig. 3 as text)"))
    paths = export.write_exports(store, "jureap.mini", "step_time_s", tmp + "/export")
    print(f"== monitoring exports (Grafana/LLview, paper §IV-F): {paths} ==")

    # 6. Table-I CSV.
    csv = analysis.to_csv(store.query("jureap.mini"))
    print("== results.csv (first lines) ==")
    print("\n".join(csv.splitlines()[:4]))
    print(f"(store at {tmp})")


if __name__ == "__main__":
    main()
