"""Quickstart: train a tiny model, decode from it, and produce an exaCB
protocol report — the whole stack in under a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import numpy as np

from repro import configs
from repro.core.harness import BenchmarkSpec, ExecHarness
from repro.core.readiness import classify
from repro.data.pipeline import DataConfig
from repro.models import params as P
from repro.serve.engine import Engine, Request
from repro.train import optimizer as O
from repro.train.trainer import TrainConfig, train


def main():
    cfg = dataclasses.replace(
        configs.get_smoke("glm4-9b"), d_model=128, n_layers=2, d_ff=256,
        vocab_size=512, dtype="float32",
    )
    print(f"model: {cfg.name}  params={P.count_params_cfg(cfg):,}")

    # 1. Train briefly on the synthetic packed LM stream.
    tc = TrainConfig(
        steps=30,
        data=DataConfig(seq_len=128, global_batch=4, seed=0),
        opt=O.OptConfig(lr=3e-3, warmup_steps=5, total_steps=30, weight_decay=0.0),
        remat="none",
    )
    res = train(cfg, tc)
    print(f"loss: {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
          f"({np.mean(res.step_times)*1e3:.0f} ms/step)")

    # 2. Serve a couple of batched requests.
    params = P.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, batch=2, max_len=64)
    outs = eng.generate([
        Request(uid=0, prompt=np.asarray([5, 6, 7], np.int32), max_new_tokens=6),
        Request(uid=1, prompt=np.asarray([9, 8], np.int32), max_new_tokens=6),
    ])
    for c in outs:
        print(f"request {c.uid}: generated {c.tokens}")

    # 3. One exaCB benchmark report for this cell + its readiness level.
    report = ExecHarness(steps=2, batch=2, seq=32).run(
        BenchmarkSpec(arch="glm4-9b", shape="train_4k", system="cpu-smoke")
    )
    level, gaps = classify(report)
    print(f"exaCB readiness: {level.name}; metrics: "
          f"{sorted(report.data[0].metrics)[:6]} ...")
    print(report.to_json(indent=2)[:400], "...")


if __name__ == "__main__":
    main()
