"""Serve a small model with batched requests (greedy + sampled), reporting
per-request latency and tokens/s through the exaCB protocol.

    PYTHONPATH=src python examples/serve_batched.py
"""

import dataclasses
import time

import jax
import numpy as np

from repro import configs
from repro.core.protocol import DataEntry, new_report
from repro.models import params as P
from repro.serve.engine import Engine, Request


def main():
    cfg = dataclasses.replace(
        configs.get_smoke("qwen3-32b"), d_model=128, n_layers=4, d_ff=256,
        vocab_size=1024, dtype="float32",
    )
    params = P.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, batch=4, max_len=128, seed=0)

    rng = np.random.default_rng(0)
    reqs = [
        Request(
            uid=i,
            prompt=rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 20))).astype(np.int32),
            max_new_tokens=24,
            temperature=0.0 if i % 2 == 0 else 0.8,
        )
        for i in range(8)
    ]
    t0 = time.perf_counter()
    outs = eng.generate(reqs)
    dt = time.perf_counter() - t0
    total_toks = sum(len(c.tokens) for c in outs)
    print(f"served {len(reqs)} requests, {total_toks} tokens in {dt:.2f}s "
          f"({total_toks/dt:.1f} tok/s on CPU)")
    for c in outs[:4]:
        print(f"  uid={c.uid} prompt_len={c.prompt_len} out={c.tokens[:10]}...")

    rep = new_report(system="cpu-smoke", variant="serve", usecase="batched")
    rep.data.append(DataEntry(success=True, runtime=dt,
                              metrics={"tokens_per_s": total_toks / dt,
                                       "n_requests": len(reqs)}))
    print(rep.to_json()[:220], "...")


if __name__ == "__main__":
    main()
