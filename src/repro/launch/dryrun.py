import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input-shape) cell against the
production meshes — 16×16 single-pod and 2×16×16 multi-pod — on 512
placeholder host devices, prints ``memory_analysis``/``cost_analysis``, and
derives the roofline terms from the compiled artifact via the loop-aware
HLO cost model.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
Knobs (feature-injection surface): --strategy, --remat, --microbatches,
--opt-state {float32,q8}.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    strategy: str = "",
    remat: str = "dots",
    microbatches: int = 1,
    opt_state_dtype: str = "float32",
    global_batch: int = 0,
    moe_dispatch: str = "",
    verbose: bool = True,
):
    """Lower + compile one cell; returns a JSON-able record."""
    import jax

    from repro import configs
    from repro.configs import shapes as SH
    from repro.core import roofline
    from repro.distributed import hlo
    from repro.distributed import sharding as S
    from repro.distributed import steps as ST
    from repro.hardware import MULTI_POD, SINGLE_POD
    from repro.launch.mesh import make_production_mesh
    from repro.train.optimizer import OptConfig

    import dataclasses as _dc

    cfg = configs.get_config(arch)
    if moe_dispatch and cfg.moe:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, dispatch=moe_dispatch))
    shape = SH.SHAPES[shape_name]
    if global_batch:
        shape = _dc.replace(shape, global_batch=global_batch)
    if not SH.applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch; long_500k inapplicable (DESIGN.md)"}
    system = MULTI_POD if multi_pod else SINGLE_POD
    strategy_name = strategy or S.default_strategy(cfg, shape.kind)
    strat = S.STRATEGIES[strategy_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    t0 = time.time()
    kw = {}
    if shape.kind == SH.TRAIN:
        kw = {
            "opt_cfg": OptConfig(state_dtype=opt_state_dtype),
            "remat": remat,
            "microbatches": microbatches,
        }
    elif shape.kind == SH.PREFILL:
        kw = {"remat": remat}
    bundle = ST.build_step(cfg, shape, mesh, strat, **kw)
    with mesh:
        lowered = bundle.lower()
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    text = compiled.as_text()
    cost = hlo.analyze(text, n_devices=system.n_chips)

    def _tree_bytes(tree):
        import numpy as np
        return float(sum(
            np.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(tree)
        ))

    # The CPU backend cannot alias donated buffers (alias_size==0 here); on
    # the TPU target the declared donations (params/opt-state/decode-state)
    # WOULD alias, so subtract them for the steady-state HBM estimate.
    donated = sum(
        _tree_bytes(bundle.abstract_args[i]) for i in bundle.donate_argnums
    ) / system.n_chips  # args are global; memory_analysis is per-device
    raw_required = float(
        mem.argument_size_in_bytes + mem.temp_size_in_bytes + mem.output_size_in_bytes
        - mem.alias_size_in_bytes
    )
    hbm_required = max(
        float(mem.argument_size_in_bytes + mem.temp_size_in_bytes),
        raw_required - donated,
    )
    # Decode/prefill state traffic for the memory-usefulness floor.
    state_bytes = 0.0
    if shape.kind == SH.DECODE:
        state_bytes = _tree_bytes(bundle.abstract_args[1])
    elif shape.kind == SH.PREFILL:
        from repro.models import transformer as TMod

        state_bytes = _tree_bytes(
            jax.eval_shape(lambda: TMod.init_decode_state(cfg, shape.global_batch, shape.seq_len))
        ) / 2.0  # written once, not re-read
    rl = roofline.compute(
        cfg=cfg,
        arch=arch,
        shape_name=shape_name,
        shape_kind=shape.kind,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        system=system,
        strategy=strategy_name,
        cost=cost,
        hbm_required=hbm_required,
        state_bytes=state_bytes,
    )
    record = {
        "arch": arch,
        "shape": shape_name,
        "system": system.name,
        "strategy": strategy_name,
        "status": "ok",
        "compile_s": t_compile,
        "knobs": {
            "remat": remat, "microbatches": microbatches,
            "opt_state_dtype": opt_state_dtype,
            "global_batch": shape.global_batch,
            "moe_dispatch": (cfg.moe.dispatch if cfg.moe else ""),
        },
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "hbm_required": hbm_required,
        },
        "xla_cost_analysis": {
            k: float(v) for k, v in (ca or {}).items()
            if isinstance(v, (int, float)) and ("flops" in k or "bytes access" in k)
        },
        "roofline": rl.metrics(),
        "collectives": rl.collectives,
        "loops": cost.loops,
        "dominant": rl.dominant,
        "suggestion": rl.suggestion(),
    }
    if verbose:
        print(f"== {arch} × {shape_name} on {system.name} [{strategy_name}] ==")
        print(f"  compile: {t_compile:.1f}s   HLO instrs≈{len(text.splitlines())}")
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/1e9:.3f}GB "
              f"temp={mem.temp_size_in_bytes/1e9:.3f}GB "
              f"out={mem.output_size_in_bytes/1e9:.3f}GB "
              f"-> {hbm_required/1e9:.3f}GB/device "
              f"({'FITS' if rl.fits else 'OVER'} {system.chip.hbm_bytes/1e9:.0f}GB HBM)")
        print(f"  cost_analysis(XLA, loop-unaware): {record['xla_cost_analysis']}")
        print(f"  loop-aware/device: flops={cost.flops:.3e} bytes={cost.bytes:.3e} "
              f"coll={cost.collective_bytes:.3e}")
        print(f"  terms: compute={rl.t_compute*1e3:.3f}ms memory={rl.t_memory*1e3:.3f}ms "
              f"collective={rl.t_collective*1e3:.3f}ms -> dominant={rl.dominant}")
        print(f"  MODEL_FLOPS={rl.model_flops:.3e} useful_ratio={rl.useful_ratio:.3f} "
              f"mem_useful={rl.memory_useful_ratio:.3f} mfu={rl.mfu:.3f} "
              f"roofline_fraction={rl.roofline_fraction:.3f}")
        print(f"  -> {rl.suggestion()}")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="every applicable cell")
    ap.add_argument("--strategy", default="")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--opt-state", default="float32", choices=["float32", "q8"])
    ap.add_argument("--global-batch", type=int, default=0)
    ap.add_argument("--moe-dispatch", default="", choices=["", "row", "global"])
    ap.add_argument("--out", default="", help="directory for JSON records")
    args = ap.parse_args(argv)

    from repro import configs
    from repro.configs import shapes as SH

    cells = []
    if args.all:
        for a in configs.ARCH_IDS:
            cfg = configs.get_config(a)
            for s in SH.SHAPES.values():
                if SH.applicable(cfg, s):
                    cells.append((a, s.name))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required (or --all)")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    outdir = Path(args.out) if args.out else None
    if outdir:
        outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            try:
                rec = run_cell(
                    arch, shape, multi_pod=mp, strategy=args.strategy,
                    remat=args.remat, microbatches=args.microbatches,
                    opt_state_dtype=args.opt_state,
                    global_batch=args.global_batch,
                    moe_dispatch=args.moe_dispatch,
                )
            except Exception as e:  # noqa: BLE001
                failures += 1
                rec = {"arch": arch, "shape": shape, "status": "error",
                       "multi_pod": mp, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc(limit=8)}
                print(f"!! {arch} × {shape} multi_pod={mp} FAILED: {e}",
                      file=sys.stderr)
            if outdir:
                tag = "2pod" if mp else "1pod"
                path = outdir / f"{arch}.{shape}.{tag}.json"
                path.write_text(json.dumps(rec, indent=2))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
