"""Serving launcher.

Local mode boots the slot-based engine on this host's devices and serves a
batch of synthetic requests; ``--dry-run`` lowers the full-config
prefill/decode steps for the production mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --requests 8
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --dry-run
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    if args.dry_run:
        from repro.launch import dryrun

        ok = True
        for shape in ("prefill_32k", "decode_32k"):
            rec = dryrun.run_cell(args.arch, shape, multi_pod=args.multi_pod)
            ok = ok and rec.get("status") in ("ok", "skipped")
        return 0 if ok else 1

    import jax
    import numpy as np

    from repro import configs
    from repro.models import params as P
    from repro.serve.engine import Engine, Request

    cfg = configs.get_smoke(args.arch)
    if cfg.input_mode != "tokens":
        print(f"{args.arch} has a stub modality frontend; serving demo uses "
              "token LMs — running the dry-run path instead")
        return main(["--arch", args.arch, "--dry-run"])
    params = P.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, batch=args.batch, max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i,
                prompt=rng.integers(1, cfg.vocab_size,
                                    size=int(rng.integers(4, 24))).astype(np.int32),
                max_new_tokens=args.max_new_tokens)
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    outs = eng.generate(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(c.tokens) for c in outs)
    print(f"{len(reqs)} requests, {total} tokens, {dt:.2f}s -> {total/dt:.1f} tok/s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
