"""Training launcher (the Jacamar-runner analogue).

Local execution trains the selected architecture's smoke/custom config on
this host's devices with checkpoint/restart; ``--dry-run`` lowers the FULL
config against the production mesh instead (use ``repro.launch.dryrun``
directly for the full matrix).

    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --dry-run
"""

from __future__ import annotations

import argparse
import dataclasses
import sys


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--opt-state", default="float32", choices=["float32", "q8"])
    ap.add_argument("--stochastic-rounding", action="store_true")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower the FULL config on the production mesh instead")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    if args.dry_run:
        from repro.launch import dryrun

        rec = dryrun.run_cell(args.arch, "train_4k", multi_pod=args.multi_pod,
                              opt_state_dtype=args.opt_state, microbatches=8)
        return 0 if rec.get("status") == "ok" else 1

    from repro import configs
    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.data.pipeline import DataConfig
    from repro.train import optimizer as O
    from repro.train.trainer import TrainConfig, train

    cfg = configs.get_smoke(args.arch)
    tc = TrainConfig(
        steps=args.steps,
        data=DataConfig(seq_len=args.seq, global_batch=args.batch),
        opt=O.OptConfig(
            lr=args.lr, total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
            state_dtype=args.opt_state, stochastic_rounding=args.stochastic_rounding,
        ),
        remat="none",
    )
    ckpt = CheckpointManager(args.ckpt) if args.ckpt else None
    res = train(cfg, tc, ckpt=ckpt,
                on_step=lambda s, m: print(f"step {s}: loss={m['loss']:.4f}")
                if s % 10 == 0 else None)
    print(f"final loss: {res.final_loss:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
