"""Mesh construction for the production systems.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run must set XLA_FLAGS before the first jax call, while smoke tests must
see the single real CPU device.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

from repro.hardware import SYSTEMS, SystemSpec


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(system: SystemSpec) -> Mesh:
    return jax.make_mesh(system.mesh_shape, system.mesh_axes)


def make_smoke_mesh() -> Mesh:
    """1x1 mesh over the single local device (tests, CPU benches)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def system_for(name: str) -> SystemSpec:
    return SYSTEMS[name]


def required_devices(system: SystemSpec) -> int:
    return system.n_chips
