"""``python -m repro`` — the campaign CLI over the ``Campaign`` facade.

Subcommands::

    python -m repro run examples/pipelines/smoke.yml --store S [--gate]
    python -m repro validate examples/pipelines/smoke.yml
    python -m repro components
    python -m repro daemon examples/pipelines/continuous.yml --store S
    python -m repro daemon-status examples/pipelines/continuous.yml --store S
"""

import sys

from repro.core.api import main

if __name__ == "__main__":
    sys.exit(main())
