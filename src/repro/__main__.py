"""``python -m repro`` — the campaign CLI over the ``Campaign`` facade.

Subcommands::

    python -m repro run examples/pipelines/smoke.yml --store S [--gate]
    python -m repro validate examples/pipelines/smoke.yml
    python -m repro components
"""

import sys

from repro.core.api import main

if __name__ == "__main__":
    sys.exit(main())
