"""Loop-aware cost model over optimized (post-SPMD) HLO text.

Why not ``compiled.cost_analysis()``?  Measured empirically (see DESIGN.md):
XLA's cost analysis counts a ``while`` body ONCE, but our stacks scan over
layers (and microbatches, and KV chunks), so it undercounts a 94-layer model
by ~94x.  This module parses the per-device HLO module, builds the
computation call graph, deduces loop trip counts from the loop-condition
constants, and accumulates:

* ``flops``            — dot FLOPs (+ cheap elementwise/reduce estimates),
* ``bytes``            — HBM traffic proxy: operand+result bytes of top-level
                         (post-fusion) instructions; fusion internals are
                         considered register/VMEM-resident,
* ``collective_bytes`` — per-collective wire bytes under a ring cost model,
                         multiplied by loop trips.

All numbers are PER DEVICE (the SPMD module is per-partition); multiply by
chip count for global figures.  Validated against analytic 6·N·D model FLOPs
in tests (the "useful ratio" must land near 1 for dense models).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([a-z][\w\-]*)\(", re.M
)
# Computation headers may have tuple-typed params (nested parens) — match
# greedily up to the '->' return-type arrow.
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*\S.*\{")
_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: List[str]
    attrs: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, str]  # instr name -> result type string


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: int = 0
    loops: Dict[str, int] = dataclasses.field(default_factory=dict)
    bytes_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add_bytes(self, op: str, n: float) -> None:
        self.bytes += n
        self.bytes_by_op[op] = self.bytes_by_op.get(op, 0.0) + n

    def merge_scaled(self, other: "HloCost", m: float) -> None:
        self.flops += other.flops * m
        self.bytes += other.bytes * m
        self.collective_bytes += other.collective_bytes * m
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v * m
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + v * m
        self.collective_count += int(other.collective_count * m)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and "=" not in line.split("(")[0]:
            cur = Computation(hdr.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        # Operand list: substring between the op's '(' and its matching ')'.
        start = line.find(op + "(", m.start(3)) + len(op) + 1
        depth, i = 1, start
        while i < len(line) and depth:
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
            i += 1
        inner = line[start : i - 1]
        attrs = line[i:]
        operands = re.findall(r"%([\w.\-]+)", inner)
        cur.instrs.append(Instr(name, type_str, op, operands, attrs, line))
        cur.symbols[name] = type_str
    return comps


def _group_size(attrs: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", attrs)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip() != ""]))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return max(1, int(m.group(2)))
    return default


def _trip_count(while_instr: Instr, cond: Optional[Computation]) -> int:
    """Trip count of a while op.  Primary: XLA's ``known_trip_count``
    backend_config (authoritative on optimized HLO).  Fallback: max int
    constant in the loop condition (jax scans lower to lt(i, constant(N)))."""
    m = _TRIP_RE.search(while_instr.attrs) or _TRIP_RE.search(while_instr.line)
    if m:
        return max(1, int(m.group(1)))
    best = 1
    if cond is not None:
        for ins in cond.instrs:
            if ins.op == "constant":
                mm = re.search(r"constant\((-?\d+)\)", ins.line)
                if mm:
                    best = max(best, int(mm.group(1)))
    return best


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_dims = _shape_dims(ins.type_str)
    out_n = 1
    for d in out_dims:
        out_n *= d
    lhs_type = comp.symbols.get(ins.operands[0], "") if ins.operands else ""
    lhs_dims = _shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    k = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx.strip() != "" and int(idx) < len(lhs_dims):
                k *= lhs_dims[int(idx)]
    return 2.0 * out_n * k


_ELTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential",
    "tanh", "rsqrt", "sqrt", "log", "power", "negate", "abs", "compare", "select",
    "convert", "floor", "ceil", "cosine", "sine", "logistic", "erf",
}


def cost_of_computation(
    comp: Computation,
    comps: Dict[str, Computation],
    *,
    n_devices: int,
    top_level: bool,
    _memo: Optional[Dict[Tuple[str, bool], HloCost]] = None,
) -> HloCost:
    if _memo is None:
        _memo = {}
    key = (comp.name, top_level)
    if key in _memo:
        return _memo[key]
    cost = HloCost()
    for ins in comp.instrs:
        if ins.op == "while":
            cond_name = _attr_ref(ins.attrs, "condition")
            body_name = _attr_ref(ins.attrs, "body")
            trips = _trip_count(ins, comps.get(cond_name))
            cost.loops[body_name or ins.name] = trips
            if body_name in comps:
                body_cost = cost_of_computation(
                    comps[body_name], comps, n_devices=n_devices,
                    top_level=top_level, _memo=_memo,
                )
                cost.merge_scaled(body_cost, trips)
                cost.loops.update(body_cost.loops)
            continue
        if ins.op == "conditional":
            for br in re.findall(r"%([\w.\-]+)", ins.attrs):
                if br in comps:
                    cost.merge_scaled(
                        cost_of_computation(comps[br], comps, n_devices=n_devices,
                                            top_level=top_level, _memo=_memo), 1.0
                    )
            continue
        if ins.op == "fusion":
            callee = _attr_ref(ins.attrs, "calls")
            if callee in comps:
                # Fusion internals: dots count as flops, bytes stay on-chip.
                inner = cost_of_computation(
                    comps[callee], comps, n_devices=n_devices,
                    top_level=False, _memo=_memo,
                )
                cost.flops += inner.flops
                cost.collective_bytes += inner.collective_bytes
            if top_level:
                cost.add_bytes("fusion", _fusion_bytes(ins, comp, comps))
            continue
        if ins.op == "dynamic-slice" and top_level:
            # Reads only the slice, not the operand.
            cost.add_bytes("dynamic-slice", 2.0 * _shape_bytes(ins.type_str))
            continue
        if ins.op == "dynamic-update-slice" and top_level:
            # In-place on real backends: read+write the update slice only.
            upd = comp.symbols.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
            cost.add_bytes("dynamic-update-slice", 2.0 * _shape_bytes(upd))
            continue
        if any(ins.op.startswith(c) for c in COLLECTIVES):
            if ins.op.endswith("-done"):
                continue  # count the -start half only
            wire = _collective_bytes(ins, comp, n_devices)
            kind = ins.op.replace("-start", "")
            cost.collective_bytes += wire
            cost.collectives[kind] = cost.collectives.get(kind, 0.0) + wire
            cost.collective_count += 1
            if top_level:
                cost.add_bytes("collective", _instr_bytes(ins, comp))
            continue
        if ins.op == "dot":
            cost.flops += _dot_flops(ins, comp)
        elif ins.op == "convolution":
            # Approximate: output elems x window size (rare in this codebase).
            out = 1
            for d in _shape_dims(ins.type_str):
                out *= d
            cost.flops += 2.0 * out
        elif ins.op in _ELTWISE_FLOP_OPS:
            out = 1
            for d in _shape_dims(ins.type_str):
                out *= d
            cost.flops += float(out)
        elif ins.op == "reduce":
            inp = _shape_dims(comp.symbols.get(ins.operands[0], "")) if ins.operands else []
            n = 1
            for d in inp:
                n *= d
            cost.flops += float(n)
        if top_level and ins.op not in ("parameter", "constant", "tuple", "get-tuple-element"):
            cost.add_bytes(ins.op, _instr_bytes(ins, comp))
    _memo[key] = cost
    return cost


def _attr_ref(attrs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _instr_bytes(ins: Instr, comp: Computation) -> float:
    total = _shape_bytes(ins.type_str)
    for o in ins.operands:
        total += _shape_bytes(comp.symbols.get(o, ""))
    return float(total)


def _fusion_bytes(ins: Instr, comp: Computation, comps: Dict[str, Computation]) -> float:
    """HBM bytes for a fusion call, refined for scan access patterns.

    Scans read per-iteration slices of stacked arrays and write results via
    dynamic-update-slice — both aliased/in-place on real backends.  Billing a
    67 MB slice read as the full 2.7 GB stacked operand inflated train cells
    ~8x (measured on glm4-9b).  Refinements:

    * a fusion parameter whose only uses inside the fused computation are
      ``dynamic-slice`` is billed at the slice sizes;
    * a fusion whose root is ``dynamic-update-slice`` is billed at the update
      size, and the updated operand (aliased) is not billed at all.
    """
    callee = _attr_ref(ins.attrs, "calls")
    inner = comps.get(callee)
    if inner is None:
        return _instr_bytes(ins, comp)
    # Map parameter index -> inner instruction name.
    param_names: Dict[int, str] = {}
    for i_ins in inner.instrs:
        if i_ins.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", i_ins.line)
            if m:
                param_names[int(m.group(1))] = i_ins.name
    root = inner.instrs[-1] if inner.instrs else None
    dus_target: Optional[str] = None
    if root is not None and root.op == "dynamic-update-slice" and root.operands:
        dus_target = root.operands[0]

    total = 0.0
    # Result bytes: in-place DUS writes only the update slice.
    if root is not None and root.op == "dynamic-update-slice" and len(root.operands) > 1:
        total += _shape_bytes(inner.symbols.get(root.operands[1], ""))
    else:
        total += _shape_bytes(ins.type_str)

    for idx, oname in enumerate(ins.operands):
        pname = param_names.get(idx)
        full = _shape_bytes(comp.symbols.get(oname, ""))
        if pname is None:
            total += full
            continue
        if pname == dus_target:
            continue  # aliased in-place destination
        sliced = _slice_only_bytes(pname, inner, depth=0)
        if sliced is not None:
            total += sliced
        else:
            total += full
    return float(total)


# Ops that only remap indices (free on TPU; backward scans read xs through
# reverse(dynamic-slice(...)) chains).
_TRANSPARENT = ("reverse", "bitcast", "copy")


def _slice_only_bytes(name: str, comp: Computation, depth: int) -> Optional[float]:
    """If every use of ``name`` bottoms out in dynamic-slice (possibly through
    index-remap ops), return the total sliced bytes; else None."""
    if depth > 3:
        return None
    uses = [u for u in comp.instrs if name in u.operands]
    if not uses:
        return None
    total = 0.0
    for u in uses:
        if u.op in ("dynamic-slice", "slice"):
            total += _shape_bytes(u.type_str)
        elif u.op in _TRANSPARENT:
            sub = _slice_only_bytes(u.name, comp, depth + 1)
            if sub is None:
                return None
            total += sub
        else:
            return None
    return total


def _collective_bytes(ins: Instr, comp: Computation, n_devices: int) -> float:
    """Ring-model wire bytes per device for one collective execution."""
    g = _group_size(ins.attrs, n_devices)
    result_b = _shape_bytes(ins.type_str)
    operand_b = sum(_shape_bytes(comp.symbols.get(o, "")) for o in ins.operands)
    frac = (g - 1) / g if g > 1 else 0.0
    op = ins.op.replace("-start", "")
    if op == "all-reduce":
        return 2.0 * operand_b * frac
    if op == "all-gather":
        return result_b * frac
    if op == "reduce-scatter":
        return operand_b * frac
    if op in ("all-to-all", "ragged-all-to-all"):
        return operand_b * frac
    if op == "collective-permute":
        return float(operand_b)
    return float(operand_b)


def analyze(text: str, *, n_devices: int) -> HloCost:
    """Full-module per-device cost (entry computation + reachable loops)."""
    comps = parse_module(text)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # Fallback: the computation with the most instructions.
        entry = max(comps, key=lambda c: len(comps[c].instrs))
    return cost_of_computation(
        comps[entry], comps, n_devices=n_devices, top_level=True
    )
