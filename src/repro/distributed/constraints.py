"""Activation sharding constraints via a trace-time context.

Relying on GSPMD propagation alone lets ambiguous points (the microbatch
reshape, embedding gathers) re-shard activations badly — measured on
starcoder2 train_4k: attention ran with an 8x-replicated batch until the
batch dim was pinned.  Model code calls ``constrain(x, logical_axes)`` at
block boundaries; outside any context this is a no-op (smoke tests,
single-device runs), inside ``activation_rules`` it becomes
``with_sharding_constraint`` under the active strategy — the MaxText
pattern, without threading a mesh through every layer signature.
"""

from __future__ import annotations

import contextlib
from typing import Any, Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import spec_for

_CTX: dict = {"mesh": None, "rules": None}


@contextlib.contextmanager
def activation_rules(mesh: Mesh, rules: Mapping[str, Any]):
    old = (_CTX["mesh"], _CTX["rules"])
    _CTX["mesh"], _CTX["rules"] = mesh, rules
    try:
        yield
    finally:
        _CTX["mesh"], _CTX["rules"] = old


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    mesh, rules = _CTX["mesh"], _CTX["rules"]
    if mesh is None or not hasattr(x, "ndim"):
        return x
    axes: Tuple[Optional[str], ...] = tuple(logical_axes)
    if len(axes) != x.ndim:
        return x
    spec = spec_for(x.shape, axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def wrap(fn, mesh: Mesh, rules: Mapping[str, Any]):
    """Make ``fn`` trace under the given activation rules."""

    def wrapped(*a, **kw):
        with activation_rules(mesh, rules):
            return fn(*a, **kw)

    return wrapped
