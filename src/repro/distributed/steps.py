"""pjit-ready step functions (train / prefill / decode) with sharding trees.

``build_*`` returns ``(fn, in_shardings, out_shardings, abstract_args)`` so
callers can either execute (``jax.jit(fn, ...)(...)``) or dry-run
(``.lower(*abstract).compile()``) against any mesh.  Donation is enabled for
params/optimizer/decode-state so ``memory_analysis`` reflects steady-state
HBM, not doubled buffers.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import shapes as SH
from repro.distributed import constraints as C
from repro.distributed import sharding as S
from repro.models import params as MP
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.train import optimizer as O

Pytree = Any


@dataclasses.dataclass(frozen=True)
class StepBundle:
    fn: Callable
    in_shardings: Tuple
    out_shardings: Tuple
    abstract_args: Tuple
    donate_argnums: Tuple[int, ...] = ()

    def jit(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        return self.jit().lower(*self.abstract_args)


# ---------------------------------------------------------------------------
# Optimizer-state shardings (mirrors optimizer.init structure)
# ---------------------------------------------------------------------------

def opt_state_shardings(
    cfg: ModelConfig, mesh: Mesh, strategy: S.Strategy, opt_cfg: O.OptConfig
) -> Pytree:
    specs = MP.param_specs(cfg)

    def moment_m(spec: MP.ParamSpec):
        base = S.opt_state_sharding_for(spec, mesh, strategy)
        if opt_cfg.state_dtype == "q8":
            _, sshape = O._q8_shapes(spec.shape)
            scale = NamedSharding(
                mesh,
                S.spec_for(
                    sshape, spec.logical_axes,
                    {**strategy.param_rules, **strategy.opt_rules}, mesh,
                ),
            )
            return {"q": base, "scale": scale}
        return base

    def moment_v(spec: MP.ParamSpec):
        return S.opt_state_sharding_for(spec, mesh, strategy)

    is_spec = lambda x: isinstance(x, MP.ParamSpec)
    return {
        "m": jax.tree.map(moment_m, specs, is_leaf=is_spec),
        "v": jax.tree.map(moment_v, specs, is_leaf=is_spec),
        "count": S.replicated(mesh),
    }


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def make_train_step(
    cfg: ModelConfig,
    opt_cfg: O.OptConfig,
    *,
    remat: str = "dots",
    microbatches: int = 1,
) -> Callable:
    def loss_fn(p, b):
        loss, metrics = T.train_loss(p, cfg, b, remat=remat)
        return loss, metrics

    def train_step(params, opt_state, batch, seed):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:]),
                batch,
            )

            def acc(carry, b):
                g_acc, l_acc = carry
                b = jax.tree.map(
                    lambda x: C.constrain(
                        x, ("batch",) + (None,) * (x.ndim - 1)
                    ),
                    b,
                )
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(acc, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = {"loss": loss}
        rng = jax.random.PRNGKey(seed)
        params, opt_state, om = O.apply(grads, params, opt_state, opt_cfg, rng)
        out_metrics = {"loss": metrics.get("loss", loss), **om}
        return params, opt_state, out_metrics

    return train_step


def build_train_step(
    cfg: ModelConfig,
    shape: SH.ShapeSpec,
    mesh: Mesh,
    strategy: S.Strategy,
    opt_cfg: Optional[O.OptConfig] = None,
    *,
    remat: str = "dots",
    microbatches: int = 1,
) -> StepBundle:
    opt_cfg = opt_cfg or O.OptConfig()
    fn = make_train_step(cfg, opt_cfg, remat=remat, microbatches=microbatches)

    abstract_params = MP.abstract_params(cfg)
    abstract_opt = O.abstract_state(abstract_params, opt_cfg)
    bspecs = SH.batch_specs(cfg, shape)
    seed_spec = jax.ShapeDtypeStruct((), jnp.int32)

    p_shard = S.param_shardings(cfg, mesh, strategy)
    o_shard = opt_state_shardings(cfg, mesh, strategy, opt_cfg)
    b_shard = S.batch_shardings(cfg, bspecs, mesh, strategy)
    rep = S.replicated(mesh)

    # Metrics tree: loss/lr/grad_norm scalars -> replicated.
    return StepBundle(
        fn=C.wrap(fn, mesh, strategy.act_rules),
        in_shardings=(p_shard, o_shard, b_shard, rep),
        out_shardings=(p_shard, o_shard, rep),
        abstract_args=(abstract_params, abstract_opt, bspecs, seed_spec),
        donate_argnums=(0, 1),
    )


# ---------------------------------------------------------------------------
# Prefill / decode (serving)
# ---------------------------------------------------------------------------

def build_prefill_step(
    cfg: ModelConfig,
    shape: SH.ShapeSpec,
    mesh: Mesh,
    strategy: S.Strategy,
    *,
    remat: str = "dots",
) -> StepBundle:
    max_len = shape.seq_len

    def prefill_step(params, batch):
        return T.prefill(params, cfg, batch, max_len=max_len, remat=remat)

    abstract_params = MP.abstract_params(cfg)
    bspecs = SH.batch_specs(cfg, shape)
    p_shard = S.param_shardings(cfg, mesh, strategy)
    b_shard = S.batch_shardings(cfg, bspecs, mesh, strategy)
    state_specs = jax.eval_shape(
        lambda: T.init_decode_state(cfg, shape.global_batch, max_len)
    )
    st_shard = S.decode_state_shardings(cfg, state_specs, mesh, strategy)
    logits_shard = NamedSharding(mesh, P(("pod", "data") if "pod" in mesh.axis_names else "data"))

    return StepBundle(
        fn=C.wrap(prefill_step, mesh, strategy.act_rules),
        in_shardings=(p_shard, b_shard),
        out_shardings=(logits_shard, st_shard),
        abstract_args=(abstract_params, bspecs),
    )


def build_decode_step(
    cfg: ModelConfig,
    shape: SH.ShapeSpec,
    mesh: Mesh,
    strategy: S.Strategy,
) -> StepBundle:
    def decode_fn(params, state, batch, idx):
        return T.decode_step(params, cfg, state, batch, idx)

    abstract_params = MP.abstract_params(cfg)
    bspecs = SH.batch_specs(cfg, shape)
    state_specs = SH.decode_state_specs(cfg, shape)
    idx_spec = jax.ShapeDtypeStruct((), jnp.int32)

    p_shard = S.param_shardings(cfg, mesh, strategy)
    b_shard = S.batch_shardings(cfg, bspecs, mesh, strategy)
    st_shard = S.decode_state_shardings(cfg, state_specs, mesh, strategy)
    rep = S.replicated(mesh)
    batch_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    bsz = shape.global_batch
    import math

    n_dp = math.prod(
        dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1) for a in batch_axes
    )
    logits_shard = (
        NamedSharding(mesh, P(batch_axes)) if bsz % n_dp == 0 else rep
    )

    return StepBundle(
        fn=C.wrap(decode_fn, mesh, strategy.act_rules),
        in_shardings=(p_shard, st_shard, b_shard, rep),
        out_shardings=(logits_shard, st_shard),
        abstract_args=(abstract_params, state_specs, bspecs, idx_spec),
        donate_argnums=(1,),
    )


def build_step(
    cfg: ModelConfig,
    shape: SH.ShapeSpec,
    mesh: Mesh,
    strategy: S.Strategy,
    **kw,
) -> StepBundle:
    if shape.kind == SH.TRAIN:
        return build_train_step(cfg, shape, mesh, strategy, **kw)
    if shape.kind == SH.PREFILL:
        kw.pop("opt_cfg", None), kw.pop("microbatches", None)
        return build_prefill_step(cfg, shape, mesh, strategy, **{k: v for k, v in kw.items() if k == "remat"})
    if shape.kind == SH.DECODE:
        return build_decode_step(cfg, shape, mesh, strategy)
    raise ValueError(shape.kind)
