"""Logical-axis sharding rules (MaxText-style).

Model code annotates every parameter with *logical* axis names
("embed", "q_heads", "experts", ...).  A ``Strategy`` maps logical axes to
mesh axes; this module turns that mapping into ``NamedSharding`` trees for
params, optimizer state, batches and decode state.

Divisibility fallback: if a tensor dimension is not divisible by the mesh
axes assigned to it (e.g. gemma3's 8 query heads on a 16-way model axis),
the dimension falls back to replication and the decision is recorded — the
dry-run stays green and the roofline report shows the cost, which is exactly
the incremental-onboarding behaviour the paper prescribes (runnable first,
optimal later).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import params as MP
from repro.models.config import ATTN, MLA, RGLRU, SSD, ModelConfig

AxisSpec = Union[None, str, Tuple[str, ...]]

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Strategy:
    """A named sharding strategy = two logical->mesh rule tables."""

    name: str
    param_rules: Mapping[str, AxisSpec]
    act_rules: Mapping[str, AxisSpec]
    # Extra rules applied to optimizer state only (ZeRO-1 style sharding).
    opt_rules: Mapping[str, AxisSpec] = dataclasses.field(default_factory=dict)
    description: str = ""


# Baseline: tensor parallel on "model", (pod+)data parallel on batch.
TP_DP = Strategy(
    name="tp_dp",
    param_rules={
        "vocab": "model",
        "q_heads": "model",
        "ffn": "model",
        "experts": "model",
        "lru": "model",
        "lru_heads": "model",
    },
    act_rules={
        "batch": ("pod", "data"),
        "q_heads": "model",
        "kv_heads": "model",
        # Decode caches: no assigned arch has kv_heads divisible by the
        # 16-way model axis, so "kv_heads" always falls back — the cache
        # SEQUENCE dim shards over "model" instead (flash-decoding layout:
        # each rank holds a KV slice and the softmax combines via psum).
        # Measured 11x memory-term cut on musicgen decode (§Perf cell C).
        "seq": "model",
        "ffn": "model",
        "experts": "model",
        "vocab": "model",
        "lru": "model",
    },
    # ZeRO-1: optimizer moments additionally sharded over the data axis
    # along the embed dimension when free.
    opt_rules={"embed": "data"},
    description="TP over 'model', DP over 'pod'+'data', ZeRO-1 moments",
)

# Fully-sharded params over the data axis (needed for the 235B/671B cells).
FSDP_TP = Strategy(
    name="fsdp_tp",
    param_rules={
        **TP_DP.param_rules,
        "embed": "data",  # FSDP shard along d_model
    },
    act_rules=TP_DP.act_rules,
    opt_rules={},  # moments inherit the fully-sharded param layout
    description="FSDP over 'data' (embed dim) + TP over 'model'",
)

# Pure FSDP + DP over BOTH axes — no tensor parallelism at all.  For models
# whose per-layer weights fit one chip after 256-way sharding, this removes
# the Megatron per-layer activation all-reduces entirely (measured 8x less
# collective volume on glm4-9b train; EXPERIMENTS.md §Perf) and gives each
# device full-channel activation locality.  Requires microbatching such that
# global_batch % (all axes) == 0 or falls back to partial batch sharding.
FSDP_DP = Strategy(
    name="fsdp_dp",
    param_rules={
        "vocab": ("data", "model"),
        "embed": ("data", "model"),
        "ffn": None,
        "q_heads": None,
        "experts": ("data", "model"),
        "lru": None,
    },
    act_rules={
        "batch": ("pod", "data", "model"),
        "experts": ("data", "model"),
        "vocab": None,
    },
    opt_rules={},
    description="ZeRO-3-style: params fully sharded over data+model, no TP",
)

STRATEGIES: Dict[str, Strategy] = {s.name: s for s in (TP_DP, FSDP_TP, FSDP_DP)}


def default_strategy(cfg: ModelConfig, step_kind: str = "") -> str:
    """Strategy selection policy (measured, EXPERIMENTS §Perf cell A):

    * >30 B params: fsdp_tp — params cannot replicate within a 16 GB chip.
    * training a dense <30 B model: fsdp_dp — removes the Megatron per-layer
      activation all-reduces (3x step-bound win on glm4-9b) and weights are
      re-gathered per layer anyway under grad recompute.
    * serving (prefill/decode): tp_dp — weights stay resident; FSDP would
      re-gather the full model every decoded token.
    """
    n = MP.count_params_cfg(cfg)
    if n > 30_000_000_000:
        return "fsdp_tp"
    # NOTE: fsdp_dp beats tp_dp 3x for dense-<30B TRAIN *when the
    # per-microbatch batch covers every device* (glm4 mb=1 on 256 chips,
    # §Perf cell A).  With the sweep's mb=8 x 512 chips the batch falls back
    # to 32-way sharding and 16 model-ranks duplicate work (measured rf
    # regression 0.021->0.003 on mamba2) — so it stays an explicit opt-in
    # (--strategy fsdp_dp) rather than the default.
    return "tp_dp"


# ---------------------------------------------------------------------------
# Rule resolution
# ---------------------------------------------------------------------------

def _mesh_axes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    rules: Mapping[str, AxisSpec],
    mesh: Mesh,
    fallbacks: Optional[List[str]] = None,
) -> P:
    """PartitionSpec for one tensor, honouring divisibility + no-reuse."""
    sizes = _mesh_axes(mesh)
    used: set = set()
    parts: List[AxisSpec] = []
    for dim, ax in zip(shape, logical_axes):
        r = rules.get(ax) if ax is not None else None
        if r is None:
            parts.append(None)
            continue
        cand = (r,) if isinstance(r, str) else tuple(r)
        cand = tuple(a for a in cand if a in sizes and a not in used)
        # Progressive fallback: drop trailing axes until divisible.
        while cand and dim % math.prod(sizes[a] for a in cand) != 0:
            if fallbacks is not None:
                fallbacks.append(f"{ax}[{dim}] !% {cand}")
            cand = cand[:-1]
        if not cand:
            parts.append(None)
            continue
        used.update(cand)
        parts.append(cand[0] if len(cand) == 1 else cand)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_shardings(
    cfg: ModelConfig, mesh: Mesh, strategy: Strategy, fallbacks: Optional[List[str]] = None
) -> Pytree:
    specs = MP.param_specs(cfg)
    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, spec_for(s.shape, s.logical_axes, strategy.param_rules, mesh, fallbacks)
        ),
        specs,
        is_leaf=lambda x: isinstance(x, MP.ParamSpec),
    )


def opt_state_sharding_for(
    spec: MP.ParamSpec, mesh: Mesh, strategy: Strategy
) -> NamedSharding:
    """Moment tensors: param rules + opt extras (ZeRO-1)."""
    rules = dict(strategy.param_rules)
    rules.update(strategy.opt_rules)
    return NamedSharding(mesh, spec_for(spec.shape, spec.logical_axes, rules, mesh))


def batch_shardings(
    cfg: ModelConfig, batch_specs: Dict[str, jax.ShapeDtypeStruct], mesh: Mesh, strategy: Strategy
) -> Dict[str, NamedSharding]:
    """Input batch: leading dim is always the (pod+)data-parallel batch."""
    out = {}
    for k, s in batch_specs.items():
        axes: Tuple[Optional[str], ...] = ("batch",) + (None,) * (len(s.shape) - 1)
        out[k] = NamedSharding(mesh, spec_for(s.shape, axes, strategy.act_rules, mesh))
    return out


# ---------------------------------------------------------------------------
# Decode-state logical axes (mirrors transformer.init_decode_state)
# ---------------------------------------------------------------------------

def _state_axes_for_kind(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    if kind == ATTN:
        ax = ("batch", "kv_heads", "seq", "head_dim")
        return {"cache": {"k": ax, "v": ax}}
    if kind == MLA:
        return {"cache": {
            "ckv": ("batch", "seq", "kv_lora"),
            "krope": ("batch", "seq", "head_dim"),
        }}
    if kind == RGLRU:
        return {"state": {
            "h": ("batch", "lru"),
            "conv": ("batch", None, "lru"),
        }}
    if kind == SSD:
        return {"state": {
            "S": ("batch", "q_heads", "state", "head_dim"),
            "conv_x": ("batch", None, "q_heads", "head_dim"),
            "conv_BC": ("batch", None, None, None, "state"),
        }}
    raise ValueError(kind)


def decode_state_logical(cfg: ModelConfig) -> Pytree:
    """Logical-axes tree mirroring ``init_decode_state`` (incl. layer stack)."""
    n_full, rem = MP.block_layout(cfg)
    out: Dict[str, Any] = {}
    if n_full:
        out["period"] = {}
        for i, spec in enumerate(cfg.block_pattern):
            axes = _state_axes_for_kind(cfg, spec.kind)
            out["period"][f"p{i}"] = jax.tree.map(
                lambda a: ("layers",) + a, axes, is_leaf=lambda x: isinstance(x, tuple)
            )
    if rem:
        out["rem"] = {
            f"r{i}": _state_axes_for_kind(cfg, cfg.block_pattern[i].kind)
            for i in range(rem)
        }
    return out


def decode_state_shardings(
    cfg: ModelConfig, state_specs: Pytree, mesh: Mesh, strategy: Strategy,
    fallbacks: Optional[List[str]] = None,
) -> Pytree:
    logical = decode_state_logical(cfg)

    def walk(spec_node, ax_node):
        if isinstance(spec_node, dict):
            return {k: walk(spec_node[k], ax_node[k]) for k in spec_node}
        return NamedSharding(
            mesh, spec_for(spec_node.shape, ax_node, strategy.act_rules, mesh, fallbacks)
        )

    return walk(state_specs, logical)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
