"""TrainHarness: short real trainer runs reporting step time and loss.

Unlike ``ExecHarness`` (which times a single hand-built fwd+bwd step),
this drives ``repro.train.trainer.train`` itself — optimizer update,
data pipeline, remat/microbatch plumbing included — so a cell measures
what a training job actually pays per step.  Remat and microbatch
feature-injections map onto the corresponding ``TrainConfig`` fields.
"""

from __future__ import annotations

import os
import time
from typing import Optional

import numpy as np

from repro.core import protocol
from repro.core.harness import (
    BenchmarkSpec,
    Harness,
    HarnessCapabilities,
    Injections,
    injected_env,
)
from repro.core.readiness import Readiness


class TrainHarness(Harness):
    """Runs a short smoke-scale training loop per model config."""

    name = "train"

    def __init__(self, *, steps: int = 3, seq_len: int = 32, global_batch: int = 2):
        self.steps = int(steps)
        self.seq_len = int(seq_len)
        self.global_batch = int(global_batch)

    def capabilities(self) -> HarnessCapabilities:
        # Trainer steps only; prefill/decode cells fail negotiation.  The
        # launcher contract wraps a bare step callable, which the trainer
        # does not expose — wrapping train() would time the whole run.
        return HarnessCapabilities(
            max_readiness=Readiness.REPRODUCIBLE,
            step_kinds=frozenset({"train"}),
            launcher_injection=False,
        )

    def spawn_spec(self):
        return "repro.harnesses.train:TrainHarness", {
            "steps": self.steps, "seq_len": self.seq_len,
            "global_batch": self.global_batch,
        }

    def run(self, spec: BenchmarkSpec, injections: Optional[Injections] = None) -> protocol.Report:
        import jax

        from repro import configs
        from repro.data.pipeline import DataConfig
        from repro.train.trainer import TrainConfig, train

        inj = injections or Injections()
        ov = inj.overrides
        steps = int(ov.get("steps", self.steps))

        report = protocol.new_report(
            system=spec.system,
            variant=spec.effective_variant(),
            usecase=spec.shape,
            software_version=jax.__version__,
            parameter={
                "arch": spec.arch,
                "injections": inj.describe(),
                "scale": "train",
                "steps": steps,
            },
        )

        cfg = configs.get_smoke(spec.arch)
        tc = TrainConfig(
            steps=steps,
            log_every=10 ** 9,
            ckpt_every=10 ** 9,
            seed=spec.seed,
            remat=str(ov.get("remat", "none")),
            microbatches=int(ov.get("microbatches", 1)),
            data=DataConfig(
                seq_len=int(ov.get("seq_len", self.seq_len)),
                global_batch=int(ov.get("global_batch", self.global_batch)),
                seed=spec.seed,
            ),
        )

        with injected_env(inj.env):
            t0 = time.perf_counter()
            result = train(cfg, tc)
            runtime = time.perf_counter() - t0

        # Step 0 pays compilation; median over the remaining steps is the
        # steady-state figure (falls back to all steps for 1-step runs).
        steady = result.step_times[1:] or result.step_times
        entry = protocol.DataEntry(
            success=bool(np.isfinite(result.final_loss)),
            runtime=runtime,
            nodes=1,
            tasks_per_node=jax.device_count(),
            job_id=f"local-{os.getpid()}",
            queue="cpu",
            metrics={
                "step_time_s": float(np.median(steady)),
                "step_time_min_s": float(np.min(steady)),
                "final_loss": float(result.final_loss),
                "steps": float(result.final_step),
                "seed": spec.seed,
            },
        )
        report.data.append(entry)
        return report
