"""ServeHarness: drive the batched serve engine under Poisson load.

Heavy traffic, measured instead of imagined: requests arrive on a seeded
Poisson process at ``rate_rps``, the engine admits them greedily in waves
of ``batch`` (the engine's own scheduling policy), and each wave's
*measured* wall time advances a virtual clock.  Per-request latency is
wave-completion minus arrival, so queueing delay is part of the number —
a saturated engine shows it in P95/P99, not just in throughput.

The arrival process is pure numpy (`poisson_arrivals`) and deterministic
under a fixed seed; only the service times are measured.
"""

from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import protocol
from repro.core.harness import (
    BenchmarkSpec,
    Harness,
    HarnessCapabilities,
    Injections,
    artifact_digest,
    injected_env,
)
from repro.core.readiness import Readiness


def poisson_arrivals(n: int, rate_rps: float, seed: int) -> np.ndarray:
    """Arrival times (seconds from t=0) of ``n`` requests at ``rate_rps``."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be positive, got {rate_rps}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=max(0, int(n)))
    return np.cumsum(gaps)


class ServeHarness(Harness):
    """Poisson load generator over ``serve.engine.Engine``."""

    name = "serve"

    def __init__(
        self,
        *,
        batch: int = 2,
        max_len: int = 48,
        requests: int = 6,
        prompt_len: int = 4,
        max_new_tokens: int = 4,
        rate_rps: float = 50.0,
        temperature: float = 0.0,
    ):
        self.batch = int(batch)
        self.max_len = int(max_len)
        self.requests = int(requests)
        self.prompt_len = int(prompt_len)
        self.max_new_tokens = int(max_new_tokens)
        self.rate_rps = float(rate_rps)
        self.temperature = float(temperature)

    def capabilities(self) -> HarnessCapabilities:
        # Serving is decode-bound; train/prefill cells fail negotiation.
        # No launcher wrapping — the unit of work is an engine wave, not a
        # step callable the injection contract could wrap.
        return HarnessCapabilities(
            max_readiness=Readiness.REPRODUCIBLE,
            step_kinds=frozenset({"decode", "serve"}),
            launcher_injection=False,
        )

    def spawn_spec(self):
        return "repro.harnesses.serve:ServeHarness", {
            "batch": self.batch, "max_len": self.max_len,
            "requests": self.requests, "prompt_len": self.prompt_len,
            "max_new_tokens": self.max_new_tokens, "rate_rps": self.rate_rps,
            "temperature": self.temperature,
        }

    def run(self, spec: BenchmarkSpec, injections: Optional[Injections] = None) -> protocol.Report:
        import jax

        from repro import configs
        from repro.models import params as P
        from repro.serve.engine import Engine, Request

        inj = injections or Injections()
        ov = inj.overrides
        batch = int(ov.get("batch", self.batch))
        n_req = int(ov.get("requests", self.requests))
        rate = float(ov.get("rate_rps", self.rate_rps))
        new_tokens = int(ov.get("max_new_tokens", self.max_new_tokens))

        cfg = configs.get_smoke(spec.arch)
        if cfg.input_mode != "tokens":
            raise ValueError(
                f"ServeHarness needs a token-LM arch; {spec.arch!r} uses "
                f"input_mode={cfg.input_mode!r}")

        report = protocol.new_report(
            system=spec.system,
            variant=spec.effective_variant(),
            usecase=spec.shape,
            software_version=jax.__version__,
            parameter={
                "arch": spec.arch,
                "injections": inj.describe(),
                "scale": "serve",
                "batch": batch,
                "requests": n_req,
                "rate_rps": rate,
            },
        )

        rng = np.random.default_rng(spec.seed)
        arrivals = poisson_arrivals(n_req, rate, spec.seed)
        reqs = [
            Request(
                uid=i,
                prompt=rng.integers(0, cfg.vocab_size, self.prompt_len).astype(np.int32),
                max_new_tokens=new_tokens,
                temperature=self.temperature,
            )
            for i in range(n_req)
        ]

        with injected_env(inj.env):
            t_build = time.perf_counter()
            params = P.init_params(cfg, jax.random.key(spec.seed))
            engine = Engine(cfg, params, batch=batch, max_len=self.max_len,
                            seed=spec.seed)
            # Warm the prefill/decode compilations out of the measured path.
            engine.generate([reqs[0]])

            latencies: List[float] = []
            all_tokens: List[int] = []
            tokens_out = 0
            clock = 0.0  # virtual time: arrivals are simulated, service is real
            i = 0
            while i < n_req:
                # Admit everything that has arrived by `clock`, up to `batch`;
                # if the queue is empty, jump to the next arrival.
                clock = max(clock, float(arrivals[i]))
                wave = []
                while i < n_req and float(arrivals[i]) <= clock and len(wave) < batch:
                    wave.append(reqs[i])
                    i += 1
                t0 = time.perf_counter()
                completions = engine.generate(wave)
                service = time.perf_counter() - t0
                clock += service
                for r, c in zip(wave, completions):
                    latencies.append(clock - float(arrivals[r.uid]))
                    tokens_out += len(c.tokens)
                    all_tokens.extend(c.tokens)
            runtime = time.perf_counter() - t_build

        lat = np.asarray(latencies)
        makespan = clock - float(arrivals[0]) if n_req else 0.0
        entry = protocol.DataEntry(
            success=bool(n_req > 0 and tokens_out > 0),
            runtime=runtime,
            nodes=1,
            tasks_per_node=jax.device_count(),
            job_id=f"local-{os.getpid()}",
            queue="cpu",
            metrics={
                "p50_latency_s": float(np.percentile(lat, 50)),
                "p95_latency_s": float(np.percentile(lat, 95)),
                "p99_latency_s": float(np.percentile(lat, 99)),
                "tokens_per_s": tokens_out / makespan if makespan > 0 else 0.0,
                "requests_per_s": n_req / makespan if makespan > 0 else 0.0,
                "step_time_s": float(np.percentile(lat, 50)),
                "artifact_digest": artifact_digest(np.asarray(all_tokens, np.int32)),
                "seed": spec.seed,
            },
        )
        report.data.append(entry)
        return report
