"""Real-workload harness family (kernel / serve / train).

Pipeline documents pick a harness *by name* — a scalar ``harness`` input
plus ``harness.<kwarg>`` inputs in the open ``harness`` namespace:

.. code-block:: yaml

    - component: execution@v4
      inputs:
        harness: "kernel"
        harness.kernel: "flash_attention"
        harness.seq: 128

Names map to spawn-safe factories, so a document-declared harness works
identically in thread mode and under process workers: the orchestrator
resolves it in-process, the worker resolves the same (name, kwargs) pair
from the payload it received.
"""

from __future__ import annotations

import importlib
from typing import Any, Dict, Mapping, Optional

from repro.core.component import PipelineError
from repro.core.harness import Harness

#: name -> "module:factory"; every factory accepts only plain-data kwargs.
FACTORIES: Dict[str, str] = {
    "exec": "repro.core.harness:ExecHarness",
    "dryrun": "repro.core.dryrun_harness:DryRunHarness",
    "kernel": "repro.harnesses.kernel:KernelHarness",
    "serve": "repro.harnesses.serve:ServeHarness",
    "train": "repro.harnesses.train:TrainHarness",
}

NAMESPACE = "harness"


def resolve(name: str, **kwargs: Any) -> Harness:
    """Build the named harness; unknown names and kwargs fail loudly."""
    ref = FACTORIES.get(name)
    if ref is None:
        raise PipelineError(
            f"unknown harness {name!r}; known: {', '.join(sorted(FACTORIES))}")
    module, _, attr = ref.partition(":")
    factory = getattr(importlib.import_module(module), attr)
    try:
        return factory(**kwargs)
    except TypeError as e:
        raise PipelineError(f"harness {name!r}: {e}") from e


def harness_kwargs(inputs: Mapping[str, Any]) -> Dict[str, Any]:
    """Extract ``harness.<kwarg>`` open-namespace inputs as ctor kwargs."""
    prefix = NAMESPACE + "."
    return {
        k[len(prefix):]: v
        for k, v in dict(inputs).items()
        if isinstance(k, str) and k.startswith(prefix)
    }


def from_inputs(inputs: Mapping[str, Any]) -> Optional[Harness]:
    """Harness declared by a component's inputs, or None.

    Works on both validated ``ComponentInputs`` (orchestrators) and the
    plain payload dicts process workers receive.
    """
    d = dict(inputs)
    name = d.get("harness")
    if not name:
        return None
    return resolve(str(name), **harness_kwargs(d))
