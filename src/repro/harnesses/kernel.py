"""KernelHarness: run a named pallas kernel at a fixed shape/dtype/blocks.

The harness is the measurement primitive of the autotuning plane
(``repro.core.autotune``): a cell is one (kernel, shape, dtype, block
config) point, and the block config arrives through feature-injection
``overrides`` — the same channel every other knob sweep uses.  Block
resolution order:

1. ``Injections.overrides`` (the sweep point),
2. the persistent autotune cache, when ``use_cache`` is on and an entry
   matches (kernel, shape key, dtype, hardware fingerprint),
3. the kernel's shipped defaults.

On CPU the kernels execute in pallas interpret mode, so absolute
latencies are *not* hardware numbers — they are still monotone in the
amount of blocking overhead, which is what the sweep ranks.  Achieved
FLOP/s and bytes/s come from analytic per-kernel counts, not HLO cost
analysis: interpret mode lowers to scalar loops whose HLO costs say
nothing about the kernel's arithmetic.
"""

from __future__ import annotations

import os
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core import protocol
from repro.core.harness import (
    BenchmarkSpec,
    Harness,
    HarnessCapabilities,
    Injections,
    artifact_digest,
    injected_env,
)
from repro.core.readiness import Readiness

#: Tunable block knobs per kernel — also the sweep axes autotune accepts.
KERNEL_KNOBS: Dict[str, Tuple[str, ...]] = {
    "flash_attention": ("block_q", "block_k"),
    "rglru": ("chunk", "block_w"),
    "ssd": ("chunk",),
}

#: Shipped defaults (must mirror the ops.py signatures) — the fallback when
#: neither the sweep nor the cache names a config.
KERNEL_DEFAULTS: Dict[str, Dict[str, int]] = {
    "flash_attention": {"block_q": 512, "block_k": 512},
    "rglru": {"chunk": 256, "block_w": 512},
    "ssd": {"chunk": 256},
}

#: Problem-size dims each kernel consumes (overridable via injections too).
KERNEL_DIMS: Dict[str, Tuple[str, ...]] = {
    "flash_attention": ("batch", "heads", "seq", "head_dim"),
    "rglru": ("batch", "seq", "width"),
    "ssd": ("batch", "seq", "heads", "head_dim", "state"),
}


def shape_key(kernel: str, dims: Dict[str, int]) -> str:
    """Canonical shape key for the autotune cache, e.g. ``B1.H2.T128.D16``."""
    if kernel == "flash_attention":
        return "B{batch}.H{heads}.T{seq}.D{head_dim}".format(**dims)
    if kernel == "rglru":
        return "B{batch}.T{seq}.W{width}".format(**dims)
    if kernel == "ssd":
        return "B{batch}.T{seq}.H{heads}.P{head_dim}.N{state}".format(**dims)
    raise ValueError(f"unknown kernel {kernel!r}")


class KernelHarness(Harness):
    """Runs one pallas kernel point; reports latency + achieved roofline."""

    name = "kernel"

    def __init__(
        self,
        *,
        kernel: str = "flash_attention",
        batch: int = 1,
        heads: int = 2,
        seq: int = 128,
        head_dim: int = 16,
        width: int = 64,
        state: int = 16,
        dtype: str = "float32",
        calls: int = 3,
        warmup: int = 1,
        causal: bool = True,
        interpret: Optional[bool] = None,
        use_cache: bool = True,
        cache_path: str = "",
    ):
        if kernel not in KERNEL_KNOBS:
            raise ValueError(
                f"unknown kernel {kernel!r}; known: {sorted(KERNEL_KNOBS)}")
        self.kernel = kernel
        self.batch = int(batch)
        self.heads = int(heads)
        self.seq = int(seq)
        self.head_dim = int(head_dim)
        self.width = int(width)
        self.state = int(state)
        self.dtype = str(dtype)
        self.calls = int(calls)
        self.warmup = int(warmup)
        self.causal = bool(causal)
        self.interpret = interpret
        self.use_cache = bool(use_cache)
        self.cache_path = str(cache_path)

    def capabilities(self) -> HarnessCapabilities:
        # A kernel point is a "kernel" step — any cell naming a model shape
        # from configs.shapes (train/prefill/decode kinds) fails negotiation
        # before dispatch.  No launcher wrapping: the step callable is a
        # jitted kernel whose wrapping would measure dispatch, not compute.
        return HarnessCapabilities(
            max_readiness=Readiness.REPRODUCIBLE,
            step_kinds=frozenset({"kernel"}),
            launcher_injection=False,
        )

    def spawn_spec(self):
        return "repro.harnesses.kernel:KernelHarness", {
            "kernel": self.kernel, "batch": self.batch, "heads": self.heads,
            "seq": self.seq, "head_dim": self.head_dim, "width": self.width,
            "state": self.state, "dtype": self.dtype, "calls": self.calls,
            "warmup": self.warmup, "causal": self.causal,
            "interpret": self.interpret, "use_cache": self.use_cache,
            "cache_path": self.cache_path,
        }

    # -- shape/dims -------------------------------------------------------
    def dims(self, overrides: Optional[Dict[str, Any]] = None) -> Dict[str, int]:
        base = {"batch": self.batch, "heads": self.heads, "seq": self.seq,
                "head_dim": self.head_dim, "width": self.width, "state": self.state}
        for k, v in (overrides or {}).items():
            if k in base:
                base[k] = int(v)
        return {k: base[k] for k in KERNEL_DIMS[self.kernel]}

    def shape_key(self, overrides: Optional[Dict[str, Any]] = None) -> str:
        return shape_key(self.kernel, self.dims(overrides))

    def _resolve_blocks(self, overrides: Dict[str, Any]) -> Tuple[Dict[str, int], str]:
        knobs = KERNEL_KNOBS[self.kernel]
        injected = {k: int(overrides[k]) for k in knobs if k in overrides}
        if injected:
            blocks = dict(KERNEL_DEFAULTS[self.kernel])
            blocks.update(injected)
            return blocks, "injections"
        if self.use_cache:
            from repro.core import autotune

            cached = autotune.cached_blocks(
                self.kernel, self.shape_key(overrides), self.dtype,
                path=self.cache_path or None)
            if cached:
                blocks = dict(KERNEL_DEFAULTS[self.kernel])
                blocks.update({k: int(v) for k, v in cached.items() if k in knobs})
                return blocks, "cache"
        return dict(KERNEL_DEFAULTS[self.kernel]), "default"

    # -- execution --------------------------------------------------------
    def run(self, spec: BenchmarkSpec, injections: Optional[Injections] = None) -> protocol.Report:
        import jax

        inj = injections or Injections()
        overrides = dict(inj.overrides)
        dims = self.dims(overrides)
        blocks, blocks_source = self._resolve_blocks(overrides)
        skey = shape_key(self.kernel, dims)

        report = protocol.new_report(
            system=spec.system,
            variant=spec.effective_variant(),
            usecase=spec.shape,
            software_version=jax.__version__,
            parameter={
                "arch": spec.arch,
                "injections": inj.describe(),
                "scale": "kernel",
                "kernel": self.kernel,
                "kernel_shape": skey,
                "kernel_dtype": self.dtype,
                "blocks": dict(blocks),
                "blocks_source": blocks_source,
            },
        )

        with injected_env(inj.env):
            fn, args, flops, bytes_moved = self._build(dims, blocks, spec.seed)
            out = jax.block_until_ready(fn(*args))
            for _ in range(max(0, self.warmup - 1)):
                out = jax.block_until_ready(fn(*args))
            times = []
            t_total = time.perf_counter()
            for _ in range(max(1, self.calls)):
                t0 = time.perf_counter()
                out = jax.block_until_ready(fn(*args))
                times.append(time.perf_counter() - t0)
            runtime = time.perf_counter() - t_total

        lat = float(np.median(times))
        entry = protocol.DataEntry(
            success=bool(np.all(np.isfinite(np.asarray(out, dtype=np.float32)))),
            runtime=runtime,
            nodes=1,
            tasks_per_node=jax.device_count(),
            job_id=f"local-{os.getpid()}",
            queue="cpu",
            metrics={
                "kernel_latency_s": lat,
                "kernel_latency_min_s": float(np.min(times)),
                # step_time_s aliases the latency so every generic consumer
                # (gate defaults, columnar analyses, reports) sees the kernel
                # series without a special case.
                "step_time_s": lat,
                "step_time_min_s": float(np.min(times)),
                "hlo_flops": float(flops),
                "hlo_bytes": float(bytes_moved),
                "achieved_flops": float(flops) / lat if lat > 0 else 0.0,
                "achieved_bytes_per_s": float(bytes_moved) / lat if lat > 0 else 0.0,
                "artifact_digest": artifact_digest(out),
                "seed": spec.seed,
            },
        )
        report.data.append(entry)
        return report

    def _build(self, dims: Dict[str, int], blocks: Dict[str, int], seed: int):
        """Return (callable, args, analytic_flops, analytic_bytes)."""
        import jax.numpy as jnp

        rng = np.random.default_rng(seed)
        dt = np.dtype(self.dtype)
        itemsize = dt.itemsize
        interp = self.interpret

        if self.kernel == "flash_attention":
            from repro.kernels.flash_attention.ops import flash_attention

            B, H, T, D = dims["batch"], dims["heads"], dims["seq"], dims["head_dim"]
            q = jnp.asarray(rng.standard_normal((B, H, T, D)), dtype=dt)
            k = jnp.asarray(rng.standard_normal((B, H, T, D)), dtype=dt)
            v = jnp.asarray(rng.standard_normal((B, H, T, D)), dtype=dt)
            causal = self.causal
            work = 0.5 if causal else 1.0
            flops = 4.0 * B * H * T * T * D * work
            nbytes = 4 * B * H * T * D * itemsize  # q, k, v, out

            def fn(q, k, v):
                return flash_attention(
                    q, k, v, causal=causal, interpret=interp,
                    block_q=blocks["block_q"], block_k=blocks["block_k"])

            return fn, (q, k, v), flops, nbytes

        if self.kernel == "rglru":
            from repro.kernels.rglru.ops import rglru_scan

            B, T, W = dims["batch"], dims["seq"], dims["width"]
            a = jnp.asarray(rng.uniform(0.5, 0.999, (B, T, W)), dtype=dt)
            g = jnp.asarray(rng.standard_normal((B, T, W)), dtype=dt)
            flops = 8.0 * B * T * W
            nbytes = 3 * B * T * W * itemsize + B * W * itemsize

            def fn(a, g):
                return rglru_scan(
                    a, g, interpret=interp,
                    chunk=blocks["chunk"], block_w=blocks["block_w"])

            return fn, (a, g), flops, nbytes

        # ssd
        from repro.kernels.ssd.ops import ssd_scan

        B, T = dims["batch"], dims["seq"]
        H, P, N = dims["heads"], dims["head_dim"], dims["state"]
        x = jnp.asarray(rng.standard_normal((B, T, H, P)), dtype=dt)
        dtm = jnp.asarray(rng.uniform(0.01, 0.1, (B, T, H)), dtype=np.float32)
        A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), dtype=np.float32)
        Bm = jnp.asarray(rng.standard_normal((B, T, 1, N)), dtype=dt)
        Cm = jnp.asarray(rng.standard_normal((B, T, 1, N)), dtype=dt)
        flops = 4.0 * B * T * H * P * N
        nbytes = (2 * B * T * H * P + 2 * B * T * N + B * T * H) * itemsize

        def fn(x, dtm, A, Bm, Cm):
            return ssd_scan(x, dtm, A, Bm, Cm, interpret=interp, chunk=blocks["chunk"])

        return fn, (x, dtm, A, Bm, Cm), flops, nbytes
