"""Hardware ("system") descriptors for the exaCB-JAX fleet.

A *system* in the paper's sense (``jedi``, ``jureca``, ``jupiter``) maps to a
mesh topology plus per-chip roofline constants here.  The dry-run harness and
the roofline analysis consume these constants; the CPU container never
executes at these speeds — it only compiles against the topology.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip roofline constants."""

    name: str
    peak_flops_bf16: float   # FLOP/s
    hbm_bytes: float         # HBM capacity per chip
    hbm_bw: float            # bytes/s
    ici_bw_per_link: float   # bytes/s, one direction, one link
    ici_links: int           # ICI links per chip (torus degree)
    # Power model for the energy-injection feature (paper §VI-B, jpwr
    # analogue).  Simple affine model: P = idle + util_compute * c + util_mem * m.
    power_idle_w: float = 90.0
    power_peak_compute_w: float = 170.0   # additional W at 100% MXU util
    power_peak_hbm_w: float = 60.0        # additional W at 100% HBM util


# Target system for the assigned meshes (numbers from the task brief).
TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bytes=16e9,
    hbm_bw=819e9,
    ici_bw_per_link=50e9,
    ici_links=4,
)


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """A named system = chip model + mesh topology (the paper's 'machine')."""

    name: str
    chip: ChipSpec
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    # Cross-pod (data-center interconnect) bandwidth per chip, bytes/s.  Only
    # meaningful when a "pod" axis exists.
    dci_bw_per_chip: float = 6.25e9

    @property
    def n_chips(self) -> int:
        n = 1
        for s in self.mesh_shape:
            n *= s
        return n


SINGLE_POD = SystemSpec(
    name="v5e-pod-16x16",
    chip=TPU_V5E,
    mesh_shape=(16, 16),
    mesh_axes=("data", "model"),
)

MULTI_POD = SystemSpec(
    name="v5e-2pods-2x16x16",
    chip=TPU_V5E,
    mesh_shape=(2, 16, 16),
    mesh_axes=("pod", "data", "model"),
)

# Reduced-scale system used by smoke tests and CPU execution benchmarks.
CPU_SMOKE = SystemSpec(
    name="cpu-smoke",
    chip=dataclasses.replace(TPU_V5E, name="cpu-host"),
    mesh_shape=(1, 1),
    mesh_axes=("data", "model"),
)

SYSTEMS = {s.name: s for s in (SINGLE_POD, MULTI_POD, CPU_SMOKE)}
