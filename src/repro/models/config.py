"""Model configuration for the unified decoder substrate.

One ``ModelConfig`` dataclass describes every architecture in the assigned
collection: dense GQA transformers (glm4, qwen3, starcoder2), mixed
local/global attention (gemma3), hybrid RG-LRU (recurrentgemma), audio
decoders (musicgen), prefix-LM VLMs (paligemma), MoE (qwen3-moe), MLA+MoE
(deepseek-v3) and attention-free SSD models (mamba2).

The depth structure is expressed as a *block pattern*: a period of
``LayerSpec`` entries that repeats through the network (with a possibly
partial final period).  Dense models have a period of one.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Temporal-mixer kinds.
ATTN = "attn"      # (possibly windowed) softmax attention, GQA/MHA/MQA
MLA = "mla"        # DeepSeek multi-head latent attention
RGLRU = "rglru"    # Griffin real-gated linear recurrent unit block
SSD = "ssd"        # Mamba-2 state-space duality block

# Channel-mixer kinds.
MLP_DENSE = "dense"    # SwiGLU MLP
MLP_MOE = "moe"        # routed mixture-of-experts (+ optional shared expert)
MLP_NONE = "none"      # mixer-less block (SSD blocks fuse channel mixing)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer within the repeating block pattern."""

    kind: str = ATTN            # temporal mixer
    window: Optional[int] = None  # sliding window; None = global attention
    mlp: str = MLP_DENSE        # channel mixer
    rope_theta: Optional[float] = None  # per-layer override (gemma3 local/global)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0           # routed experts
    top_k: int = 0
    d_ff: int = 0                # per-expert hidden width
    n_shared_experts: int = 0    # always-on experts (DeepSeek style)
    shared_d_ff: int = 0         # hidden width of the fused shared expert
    capacity_factor: float = 1.25
    dispatch: str = "row"        # "row" (sharded, default) | "global" (naive)
    router_fn: str = "softmax"   # "softmax" (qwen3) | "sigmoid" (deepseek-v3)
    routed_scale: float = 1.0    # deepseek-v3 routed-expert scaling factor
    router_noise: float = 0.0    # jitter used during training
    aux_loss_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0           # 0 -> d_model
    conv_width: int = 4
    block_width_mult: int = 3    # Griffin: MLP expansion in recurrent block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    block_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)

    # Attention details.
    rope_theta: float = 10000.0
    use_qk_norm: bool = False          # qwen3
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scale_embeddings: bool = False     # gemma family: x *= sqrt(d_model)
    logits_softcap: Optional[float] = None

    # Prefix-LM (paligemma): bidirectional attention over the first
    # ``prefix_len`` positions.  0 disables.
    prefix_len: int = 0

    # Input modality: "tokens" (LM), "embeddings" (stub frontend supplies
    # frame/patch embeddings directly).
    input_mode: str = "tokens"
    # Multi-codebook output heads (musicgen): number of parallel codebooks.
    n_codebooks: int = 1

    # Optional sub-configs; present iff the pattern references them.
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssd: Optional[SSDConfig] = None
    rglru: Optional[RGLRUConfig] = None

    # DeepSeek multi-token prediction depth (training-time auxiliary head).
    mtp_depth: int = 0
    mtp_loss_weight: float = 0.1

    # Numerics.
    dtype: str = "bfloat16"            # activations/params
    # Family tag for readiness/reporting ("dense", "moe", "ssm", ...).
    family: str = "dense"
    # Eligible for the long_500k cell (bounded state / mostly-local attention).
    long_context: bool = False

    # ----- derived -----
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        """The full, depth-expanded layer list (period repeated + truncated)."""
        p = self.block_pattern
        reps = (self.n_layers + len(p) - 1) // len(p)
        return tuple((p * reps)[: self.n_layers])

    @property
    def is_attention_free(self) -> bool:
        return all(s.kind in (SSD, RGLRU) for s in self.layer_specs())

    @property
    def is_subquadratic(self) -> bool:
        """True if no layer attends globally over unbounded context."""
        return all(
            s.kind in (SSD, RGLRU) or (s.kind in (ATTN,) and s.window is not None)
            for s in self.layer_specs()
        )

    def validate(self) -> None:
        assert self.n_layers > 0 and self.d_model > 0
        for s in self.block_pattern:
            if s.kind == MLA:
                assert self.mla is not None, f"{self.name}: MLA pattern needs mla config"
            if s.kind == SSD:
                assert self.ssd is not None, f"{self.name}: SSD pattern needs ssd config"
            if s.kind == RGLRU:
                assert self.rglru is not None, f"{self.name}: RG-LRU pattern needs rglru config"
            if s.mlp == MLP_MOE:
                assert self.moe is not None and self.moe.n_experts > 0
        if self.input_mode not in ("tokens", "embeddings"):
            raise ValueError(self.input_mode)

    def param_count(self) -> int:
        """Total parameter count (exact, mirrors the param tree)."""
        from repro.models import params as P  # local import to avoid cycle

        return P.count_params(P.param_specs(self))

    def active_param_count(self) -> int:
        """Parameters active per token (MoE counts top_k + shared experts)."""
        from repro.models import params as P

        return P.count_params(P.param_specs(self), active_only=True)
