"""Unified decoder: block dispatch, scan-over-layers stacking, heads, losses.

Depth layout (see ``params.block_layout``): the repeating block pattern is
scanned ``n_full`` times (weights stacked on a leading "layers" axis), and a
possibly-partial final period is applied unrolled.  This keeps HLO size O(1)
in depth — required to compile 94-layer models against 512 devices — and is
what production JAX LLM stacks (MaxText et al.) do.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import constraints as C
from repro.models import layers as L
from repro.models import params as P
from repro.models.config import (
    ATTN,
    MLA,
    MLP_DENSE,
    MLP_MOE,
    MLP_NONE,
    RGLRU,
    SSD,
    LayerSpec,
    ModelConfig,
)

Pytree = Any

REMAT_POLICIES = {
    "none": None,  # no remat
    "full": "full",  # remat everything
    "dots": "dots",  # save matmul outputs with no batch dims
    "minimal": "minimal",  # save nothing except inputs
}


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    if policy == "minimal":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.everything_saveable)
    raise ValueError(policy)


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------

def block_fullseq(
    p: Pytree,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    spec: LayerSpec,
    prefix_len: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """Residual block; returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    if spec.kind == ATTN:
        mix = L.attn_fullseq(p["attn"], h, cfg=cfg, spec=spec, prefix_len=prefix_len)
    elif spec.kind == MLA:
        mix = L.mla_fullseq(p["attn"], h, cfg=cfg, spec=spec)
    elif spec.kind == RGLRU:
        mix = L.rglru_fullseq(p["rglru"], h, cfg=cfg)
    elif spec.kind == SSD:
        mix = L.ssd_fullseq(p["ssd"], h, cfg=cfg)
    else:
        raise ValueError(spec.kind)
    x = x + mix
    if spec.mlp != MLP_NONE:
        h = L.rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        if spec.mlp == MLP_DENSE:
            y = L.swiglu(h, p["mlp"]["wi"], p["mlp"]["wo"])
        else:
            y, aux = L.moe_forward(p["moe"], h, cfg=cfg)
        x = x + y
    return x, aux


def block_init_state(
    cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int
) -> Pytree:
    if spec.kind == ATTN:
        return {"cache": L.attn_init_cache(cfg, spec, batch, max_len)}
    if spec.kind == MLA:
        return {"cache": L.mla_init_cache(cfg, batch, max_len)}
    if spec.kind == RGLRU:
        return {"state": L.rglru_init_state(cfg, batch)}
    if spec.kind == SSD:
        return {"state": L.ssd_init_state(cfg, batch)}
    raise ValueError(spec.kind)


def block_prefill(
    p: Pytree,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    spec: LayerSpec,
    max_len: int,
    prefix_len: int = 0,
) -> Tuple[jax.Array, Pytree]:
    """Full-seq forward that also returns the decode state."""
    aux_state: Pytree
    h = L.rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    if spec.kind == ATTN:
        mix = L.attn_fullseq(p["attn"], h, cfg=cfg, spec=spec, prefix_len=prefix_len)
        cache = L.attn_init_cache(cfg, spec, x.shape[0], max_len)
        cache = L.attn_prefill_cache(p["attn"], h, cfg=cfg, spec=spec, cache=cache)
        aux_state = {"cache": cache}
    elif spec.kind == MLA:
        mix = L.mla_fullseq(p["attn"], h, cfg=cfg, spec=spec)
        cache = L.mla_init_cache(cfg, x.shape[0], max_len)
        cache = L.mla_prefill_cache(p["attn"], h, cfg=cfg, cache=cache)
        aux_state = {"cache": cache}
    elif spec.kind == RGLRU:
        mix, st = _rglru_fullseq_with_state(p["rglru"], h, cfg)
        aux_state = {"state": st}
    elif spec.kind == SSD:
        mix, st = _ssd_fullseq_with_state(p["ssd"], h, cfg)
        aux_state = {"state": st}
    else:
        raise ValueError(spec.kind)
    x = x + mix
    if spec.mlp != MLP_NONE:
        h = L.rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        if spec.mlp == MLP_DENSE:
            y = L.swiglu(h, p["mlp"]["wi"], p["mlp"]["wo"])
        else:
            y, _ = L.moe_forward(p["moe"], h, cfg=cfg)
        x = x + y
    return x, aux_state


def _rglru_fullseq_with_state(p, h, cfg):
    """Full-seq RG-LRU returning final recurrent + conv state."""
    y = L.rglru_fullseq(p, h, cfg=cfg)
    # Recompute final hidden state cheaply: rerun the scan's last step values.
    # The associative scan already produced h_T inside rglru_fullseq; to avoid
    # replumbing we recompute the input branch and take the final state from a
    # second (cheap, memory-light) pass over the last conv_width tokens is NOT
    # possible for the recurrence (depends on full history), so we rerun the
    # recurrence here.  XLA CSEs the shared projections.
    r = cfg.rglru
    w = r.lru_width or cfg.d_model
    B, T, _ = h.shape
    H = cfg.n_heads
    bw = w // H
    xb = jnp.einsum("btd,dw->btw", h, p["wx"])
    xc = L._causal_conv_fullseq(xb, p["conv_w"], p["conv_b"])
    xh = xc.reshape(B, T, H, bw)
    gi = L._block_diag_gate(xh, p["gate_w"][0], p["gate_b"][0])
    gr = L._block_diag_gate(xh, p["gate_w"][1], p["gate_b"][1])
    log_a = -8.0 * gr * jax.nn.softplus(p["a_param"].astype(jnp.float32)).reshape(H, bw)
    a = jnp.exp(log_a).reshape(B, T, w)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b_seq = (xh.astype(jnp.float32) * gi * mult).reshape(B, T, w)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    af, bf = jax.lax.associative_scan(combine, (a, b_seq), axis=1)
    h_last = bf[:, -1]
    conv_state = xb[:, -(r.conv_width - 1):].astype(jnp.dtype(cfg.dtype))
    # Pad if T < conv_width-1 (tiny smoke shapes).
    need = r.conv_width - 1
    if conv_state.shape[1] < need:
        conv_state = jnp.pad(conv_state, ((0, 0), (need - conv_state.shape[1], 0), (0, 0)))
    return y, {"h": h_last, "conv": conv_state}


def _ssd_fullseq_with_state(p, h, cfg):
    s = cfg.ssd
    y = L.ssd_fullseq(p, h, cfg=cfg)
    # Final SSM state: rerun the (cheap) state recurrence over chunk summaries.
    z, xi, bc, dt = L._ssd_project(p, h, cfg)
    xi_c, bc_c = L._ssd_conv_fullseq(xi, bc, p, cfg)
    Bm = bc_c[:, :, 0]
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    Bsz, T, H, Pd = xi_c.shape
    G, N = Bm.shape[2], Bm.shape[3]
    B_h = jnp.repeat(Bm, H // G, axis=2)
    dA = dtv * A[None, None, :]
    cum = jnp.cumsum(dA, axis=1)
    seg = jnp.exp(cum[:, -1:, :] - cum)
    S = jnp.einsum(
        "bthn,bthp->bhnp",
        B_h.astype(jnp.float32) * (seg * dtv)[..., None],
        xi_c.astype(jnp.float32),
    )
    conv_x = xi[:, -(s.conv_width - 1):].astype(jnp.dtype(cfg.dtype))
    conv_BC = bc[:, -(s.conv_width - 1):].astype(jnp.dtype(cfg.dtype))
    need = s.conv_width - 1
    if conv_x.shape[1] < need:
        conv_x = jnp.pad(conv_x, ((0, 0), (need - conv_x.shape[1], 0), (0, 0), (0, 0)))
        conv_BC = jnp.pad(
            conv_BC, ((0, 0), (need - conv_BC.shape[1], 0), (0, 0), (0, 0), (0, 0))
        )
    return y, {"S": S, "conv_x": conv_x, "conv_BC": conv_BC}


def block_decode(
    p: Pytree,
    x: jax.Array,
    state: Pytree,
    idx: jax.Array,
    *,
    cfg: ModelConfig,
    spec: LayerSpec,
) -> Tuple[jax.Array, Pytree]:
    h = L.rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    if spec.kind == ATTN:
        mix, cache = L.attn_decode(p["attn"], h, state["cache"], idx, cfg=cfg, spec=spec)
        state = {"cache": cache}
    elif spec.kind == MLA:
        mix, cache = L.mla_decode(p["attn"], h, state["cache"], idx, cfg=cfg)
        state = {"cache": cache}
    elif spec.kind == RGLRU:
        mix, st = L.rglru_decode(p["rglru"], h, state["state"], cfg=cfg)
        state = {"state": st}
    elif spec.kind == SSD:
        mix, st = L.ssd_decode(p["ssd"], h, state["state"], cfg=cfg)
        state = {"state": st}
    else:
        raise ValueError(spec.kind)
    x = x + mix
    if spec.mlp != MLP_NONE:
        h = L.rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        if spec.mlp == MLP_DENSE:
            y = L.swiglu(h, p["mlp"]["wi"], p["mlp"]["wo"])
        else:
            y, _ = L.moe_forward(p["moe"], h, cfg=cfg)
        x = x + y
    return x, state


# ---------------------------------------------------------------------------
# Stack (scan over periods)
# ---------------------------------------------------------------------------

def stack_fullseq(
    blocks: Pytree,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    prefix_len: int = 0,
    remat: str = "dots",
) -> Tuple[jax.Array, jax.Array]:
    n_full, rem = P.block_layout(cfg)
    aux = jnp.zeros((), jnp.float32)
    if n_full:
        def period_body(carry, xs):
            x, aux = carry
            x = C.constrain(x, ("batch", None, None))
            for i, spec in enumerate(cfg.block_pattern):
                x, a = block_fullseq(xs[f"p{i}"], x, cfg=cfg, spec=spec, prefix_len=prefix_len)
                x = C.constrain(x, ("batch", None, None))
                aux = aux + a
            return (x, aux), None

        body = _remat(period_body, remat)
        (x, aux), _ = jax.lax.scan(body, (x, aux), blocks["period"])
    for i in range(rem):
        x, a = block_fullseq(
            blocks["rem"][f"r{i}"], x, cfg=cfg, spec=cfg.block_pattern[i], prefix_len=prefix_len
        )
        aux = aux + a
    return x, aux


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> Pytree:
    """Zeroed decode state, stacked to mirror the block scan layout."""
    n_full, rem = P.block_layout(cfg)
    out: Dict[str, Any] = {}
    if n_full:
        out["period"] = {}
        for i, spec in enumerate(cfg.block_pattern):
            single = block_init_state(cfg, spec, batch, max_len)
            out["period"][f"p{i}"] = jax.tree.map(
                lambda a: jnp.zeros((n_full,) + a.shape, a.dtype), single
            )
    if rem:
        out["rem"] = {
            f"r{i}": block_init_state(cfg, cfg.block_pattern[i], batch, max_len)
            for i in range(rem)
        }
    return out


def stack_prefill(
    blocks: Pytree,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    max_len: int,
    prefix_len: int = 0,
    remat: str = "dots",
) -> Tuple[jax.Array, Pytree]:
    n_full, rem = P.block_layout(cfg)
    state: Dict[str, Any] = {}
    if n_full:
        def body(x, xs):
            st = {}
            x = C.constrain(x, ("batch", None, None))
            for i, spec in enumerate(cfg.block_pattern):
                x, s = block_prefill(
                    xs[f"p{i}"], x, cfg=cfg, spec=spec, max_len=max_len, prefix_len=prefix_len
                )
                x = C.constrain(x, ("batch", None, None))
                st[f"p{i}"] = s
            return x, st

        body = _remat(body, remat) if remat != "none" else body
        x, state_p = jax.lax.scan(body, x, blocks["period"])
        state["period"] = state_p
    if rem:
        state["rem"] = {}
        for i in range(rem):
            x, s = block_prefill(
                blocks["rem"][f"r{i}"], x, cfg=cfg, spec=cfg.block_pattern[i],
                max_len=max_len, prefix_len=prefix_len,
            )
            state["rem"][f"r{i}"] = s
    return x, state


def stack_decode(
    blocks: Pytree,
    state: Pytree,
    x: jax.Array,
    idx: jax.Array,
    *,
    cfg: ModelConfig,
) -> Tuple[jax.Array, Pytree]:
    n_full, rem = P.block_layout(cfg)
    new_state: Dict[str, Any] = {}
    if n_full:
        # The stacked decode state rides in the scan CARRY and is updated
        # with dynamic-update-slice at the layer index.  Passing it as scan
        # xs/ys instead forces full restack copies of the multi-GB cache
        # every step (measured ~3x cache traffic on musicgen decode; §Perf
        # cell C) — while-loop carries alias in place.
        def body(carry, xs):
            x, st = carry
            ps, layer = xs
            st = dict(st)
            x = C.constrain(x, ("batch", None, None))
            for i, spec in enumerate(cfg.block_pattern):
                si = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, layer, 0, keepdims=False),
                    st[f"p{i}"],
                )
                x, ns = block_decode(ps[f"p{i}"], x, si, idx, cfg=cfg, spec=spec)
                st[f"p{i}"] = jax.tree.map(
                    lambda a, n: jax.lax.dynamic_update_index_in_dim(
                        a, n.astype(a.dtype), layer, 0
                    ),
                    st[f"p{i}"],
                    ns,
                )
            return (x, st), None

        (x, ns), _ = jax.lax.scan(
            body, (x, state["period"]),
            (blocks["period"], jnp.arange(n_full, dtype=jnp.int32)),
        )
        new_state["period"] = ns
    if rem:
        new_state["rem"] = {}
        for i in range(rem):
            x, s = block_decode(
                blocks["rem"][f"r{i}"], x, state["rem"][f"r{i}"], idx,
                cfg=cfg, spec=cfg.block_pattern[i],
            )
            new_state["rem"][f"r{i}"] = s
    return x, new_state


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------

def embed_inputs(params: Pytree, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    if cfg.input_mode == "embeddings":
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        return C.constrain(x, ("batch", None, None))
    x = jnp.take(params["embed"]["table"], batch["tokens"], axis=0).astype(
        jnp.dtype(cfg.dtype)
    )
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if cfg.prefix_len and "prefix_embeds" in batch:
        x = jnp.concatenate([batch["prefix_embeds"].astype(x.dtype), x], axis=1)
    return C.constrain(x, ("batch", None, None))


def apply_head(params: Pytree, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    """h: (B, T, d) -> logits (B, T, V) or (B, K, T, V) for multi-codebook."""
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", h, params["embed"]["table"])
    elif cfg.n_codebooks > 1:
        logits = jnp.einsum("btd,kdv->bktv", h, params["head"]["w"])
    else:
        logits = jnp.einsum("btd,dv->btv", h, params["head"]["w"])
    logits = logits.astype(jnp.float32)
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


def cross_entropy(logits: jax.Array, targets: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Mean CE over valid (target >= 0) positions. logits f32 (..., V)."""
    valid = targets >= 0
    tsafe = jnp.maximum(targets, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, tsafe[..., None], axis=-1)[..., 0]
    ce = (lse - tgt) * valid
    n = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(ce) / n, n.astype(jnp.float32)


def forward_fullseq(
    params: Pytree,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    *,
    remat: str = "dots",
) -> Tuple[jax.Array, jax.Array]:
    """Returns (hidden (B,T,d), aux_loss)."""
    x = embed_inputs(params, cfg, batch)
    x, aux = stack_fullseq(
        params["blocks"], x, cfg=cfg, prefix_len=cfg.prefix_len, remat=remat
    )
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return x, aux


def train_loss(
    params: Pytree,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    *,
    remat: str = "dots",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    h, aux = forward_fullseq(params, cfg, batch, remat=remat)
    if cfg.prefix_len:
        # Loss only over the text region (after the stub prefix).
        h = h[:, cfg.prefix_len :]
    logits = apply_head(params, cfg, h)
    if cfg.n_codebooks > 1:
        targets = batch["targets"]  # (B, K, T)
        ce, n = cross_entropy(logits, targets)
    else:
        ce, n = cross_entropy(logits, batch["targets"])
    loss = ce + aux
    metrics = {"loss": loss, "ce": ce, "aux": aux, "n_tokens": n}
    if cfg.mtp_depth:
        mtp_l = mtp_loss(params, cfg, h_backbone=h, batch=batch)
        loss = loss + cfg.mtp_loss_weight * mtp_l
        metrics["mtp"] = mtp_l
        metrics["loss"] = loss
    return loss, metrics


def mtp_loss(params: Pytree, cfg: ModelConfig, *, h_backbone: jax.Array, batch) -> jax.Array:
    """DeepSeek-V3 multi-token-prediction auxiliary loss (depth 1+)."""
    tokens = batch["tokens"]
    total = jnp.zeros((), jnp.float32)
    h = h_backbone
    for k in range(cfg.mtp_depth):
        p = params["mtp"][f"d{k}"]
        # Combine h_t with the embedding of token t+k+1.
        emb = jnp.take(params["embed"]["table"], tokens[:, k + 1 :], axis=0)
        h_in = h[:, : emb.shape[1]]
        cat = jnp.concatenate(
            [L.rms_norm(h_in, p["ln_h"]["scale"], cfg.norm_eps),
             L.rms_norm(emb, p["ln_e"]["scale"], cfg.norm_eps)],
            axis=-1,
        )
        h = jnp.einsum("bte,ed->btd", cat, p["proj"])
        h, _ = block_fullseq(p["block"], h, cfg=cfg, spec=cfg.block_pattern[-1])
        logits = apply_head(params, cfg, h)
        # Predict token t+k+2 at position t.
        tgt = batch["targets"][:, k + 1 :]
        ce, _ = cross_entropy(logits, tgt)
        total = total + ce
    return total / max(cfg.mtp_depth, 1)


def prefill(
    params: Pytree,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    *,
    max_len: int,
    remat: str = "dots",
) -> Tuple[jax.Array, Pytree]:
    """Returns (last-token logits (B, V...), decode state)."""
    x = embed_inputs(params, cfg, batch)
    x, state = stack_prefill(
        params["blocks"], x, cfg=cfg, max_len=max_len, prefix_len=cfg.prefix_len, remat=remat
    )
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = apply_head(params, cfg, x[:, -1:])
    return logits, state


def decode_step(
    params: Pytree,
    cfg: ModelConfig,
    state: Pytree,
    batch: Dict[str, jax.Array],
    idx: jax.Array,
) -> Tuple[jax.Array, Pytree]:
    """One decode step.  batch carries 'tokens' (B,1) or 'embeds' (B,1,d)."""
    x = embed_inputs(params, cfg, batch)
    x, state = stack_decode(params["blocks"], state, x, idx, cfg=cfg)
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = apply_head(params, cfg, x)
    return logits, state
