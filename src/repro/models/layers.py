"""Layer implementations for the unified decoder substrate.

Every temporal mixer exposes two entry points:

* ``*_fullseq(params, x, ...) -> y``                — training / prefill
* ``*_decode(params, x, state, ...) -> (y, state)`` — one-token decode

States are pytrees so the whole stack's state can be stacked along the scan
axis.  All heavy attention paths go through ``chunked_attention`` — a pure-jnp
flash-style online-softmax implementation that (a) keeps compiled memory
realistic at 32k+ sequence lengths and (b) doubles as the oracle for the
Pallas TPU kernels in ``repro.kernels``.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import LayerSpec, ModelConfig

Pytree = Any

# Kernel dispatch hook: repro.kernels.ops installs TPU Pallas implementations
# here when enabled; the default is the pure-jnp path (CPU / dry-run).
_ATTENTION_IMPL = {"impl": None}


def set_attention_impl(fn) -> None:
    _ATTENTION_IMPL["impl"] = fn


# ---------------------------------------------------------------------------
# Basics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, D) with positions (..., T) or (T,). Rotates pairs (even/odd
    split convention, as used by llama/gemma/qwen)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, wi: jax.Array, wo: jax.Array) -> jax.Array:
    """Fused SwiGLU MLP.  wi: (d, 2, ff); wo: (ff, d)."""
    h = jnp.einsum("btd,dcf->btcf", x, wi)
    gate, up = h[..., 0, :], h[..., 1, :]
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    return jnp.einsum("btf,fd->btd", act, wo)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — pure jnp
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _block_mask(
    iq: jax.Array,
    jk: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
    prefix_len: int,
    kv_valid: Optional[jax.Array],
) -> jax.Array:
    """Boolean mask (Tq_blk, Tk_blk) from absolute position vectors."""
    m = jnp.ones((iq.shape[0], jk.shape[0]), bool)
    if causal:
        c = jk[None, :] <= iq[:, None]
        if prefix_len:
            c = c | ((iq[:, None] < prefix_len) & (jk[None, :] < prefix_len))
        m = m & c
    if window is not None:
        m = m & (jk[None, :] > iq[:, None] - window)
    if kv_valid is not None:
        m = m & (jk[None, :] < kv_valid)
    return m


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,
    q_offset: int = 0,
    kv_positions: Optional[jax.Array] = None,
    kv_valid: Optional[jax.Array] = None,
    chunk_q: int = 1024,
    chunk_k: int = 1024,
    scale: Optional[float] = None,
) -> jax.Array:
    """Online-softmax attention over KV chunks.

    q: (B, Hq, Tq, D);  k, v: (B, Hkv, Tk, D) with Hq % Hkv == 0.
    ``kv_positions``: absolute position of each kv slot (Tk,) — used by
    ring-buffer caches; defaults to arange.
    ``kv_valid``: number of valid kv slots (scalar) for linear caches.
    """
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    Dv = v.shape[-1]  # MLA: value dim may differ from qk dim
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    cq = min(chunk_q, Tq)
    ck = min(chunk_k, Tk)
    pad_q = (-Tq) % cq
    pad_k = (-Tk) % ck
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Tqp, Tkp = Tq + pad_q, Tk + pad_k
    nq, nk = Tqp // cq, Tkp // ck

    if kv_positions is None:
        kv_pos = jnp.arange(Tkp, dtype=jnp.int32)
    else:
        kv_pos = jnp.pad(kv_positions.astype(jnp.int32), (0, pad_k), constant_values=-1)
    kv_in_range = jnp.arange(Tkp) < Tk  # mask out pure padding slots

    qr = q.reshape(B, Hkv, G, nq, cq, D)
    kr = k.reshape(B, Hkv, nk, ck, D)
    vr = v.reshape(B, Hkv, nk, ck, Dv)

    def q_block(qi, q_blk):
        iq = q_offset + qi * cq + jnp.arange(cq, dtype=jnp.int32)

        def kv_step(carry, inputs):
            m_run, l_run, acc = carry
            k_blk, v_blk, jpos, jvalid = inputs
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", q_blk, k_blk, preferred_element_type=jnp.float32
            ) * scale
            mask = _block_mask(
                iq, jpos, causal=causal, window=window,
                prefix_len=prefix_len, kv_valid=kv_valid,
            )
            mask = mask & jvalid[None, :] & (jpos[None, :] >= 0)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, Hkv, G, cq), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, G, cq), jnp.float32),
            jnp.zeros((B, Hkv, G, cq, Dv), jnp.float32),
        )
        jpos_blocks = kv_pos.reshape(nk, ck)
        jvalid_blocks = kv_in_range.reshape(nk, ck)
        (m_f, l_f, acc_f), _ = jax.lax.scan(
            kv_step,
            init,
            (kr.swapaxes(0, 2).swapaxes(1, 2), vr.swapaxes(0, 2).swapaxes(1, 2),
             jpos_blocks, jvalid_blocks),
        )
        return acc_f / jnp.maximum(l_f, 1e-30)[..., None]

    if nq == 1:
        out = q_block(0, qr[:, :, :, 0])
        out = out[:, :, :, None]
    else:
        out = jax.lax.map(
            lambda args: q_block(args[0], args[1]),
            (jnp.arange(nq), qr.swapaxes(0, 3).swapaxes(1, 3).swapaxes(2, 3)),
        )  # (nq, B, Hkv, G, cq, D)
        out = jnp.moveaxis(out, 0, 3)  # (B, Hkv, G, nq, cq, D)
    out = out.reshape(B, Hq, Tqp, Dv)[:, :, :Tq]
    return out.astype(q.dtype)


def banded_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,
    q_offset: int = 0,
    kv_positions: Optional[jax.Array] = None,
    kv_valid: Optional[jax.Array] = None,
    chunk_q: int = 1024,
    chunk_k: int = 1024,
    scale: Optional[float] = None,
) -> jax.Array:
    """Causally-banded blocked attention: only lower-triangular (and, for
    sliding windows, in-band) block pairs are COMPUTED — the pure-jnp
    analogue of flash attention's causal block skipping.  Halves attention
    FLOPs vs ``chunked_attention`` for causal masks and cuts them ~T/window-
    fold for local layers.  Offsets are processed as a static Python loop
    (HLO size O(n_blocks)); within each offset all block rows batch into one
    einsum.  Semantics identical to ``chunked_attention`` (tested).
    """
    if kv_positions is not None or kv_valid is not None or q.shape[2] != k.shape[2]:
        # Ring caches / unequal lengths: fall back to the scanning variant.
        return chunked_attention(
            q, k, v, causal=causal, window=window, prefix_len=prefix_len,
            q_offset=q_offset, kv_positions=kv_positions, kv_valid=kv_valid,
            chunk_q=chunk_q, chunk_k=chunk_k, scale=scale,
        )
    B, Hq, T, D = q.shape
    _, Hkv, _, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    c = min(chunk_q, chunk_k, T)
    pad = (-T) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    n = Tp // c
    qr = q.reshape(B, Hkv, G, n, c, D)
    kr = k.reshape(B, Hkv, n, c, D)
    vr = v.reshape(B, Hkv, n, c, Dv)

    m_run = jnp.full((B, Hkv, G, n, c), NEG_INF, jnp.float32)
    l_run = jnp.zeros((B, Hkv, G, n, c), jnp.float32)
    acc = jnp.zeros((B, Hkv, G, n, c, Dv), jnp.float32)

    max_back = n - 1
    if window is not None:
        max_back = min(max_back, (window - 1) // c + 1)
    pb = (prefix_len + c - 1) // c if prefix_len else 0  # prefix blocks

    def apply_block(m_run, l_run, acc, rows, cols, qs, ks, vs):
        """rows/cols: block indices (len R). qs: (B,Hkv,G,R,c,D)."""
        iq = q_offset + rows[:, None] * c + jnp.arange(c)[None, :]  # (R,c)
        jk = cols[:, None] * c + jnp.arange(c)[None, :]
        s = jnp.einsum("bhgrqd,bhrkd->bhgrqk", qs, ks,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((rows.shape[0], c, c), bool)
        if causal:
            cm = jk[:, None, :] <= iq[:, :, None]
            if prefix_len:
                cm = cm | ((iq[:, :, None] < prefix_len) & (jk[:, None, :] < prefix_len))
            mask = mask & cm
        if window is not None:
            mask = mask & (jk[:, None, :] > iq[:, :, None] - window)
        mask = mask & (jk[:, None, :] < T)  # padding
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)                                  # (B,H,G,R,c)
        m_old = m_run[:, :, :, rows]
        m_new = jnp.maximum(m_old, m_blk)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_old - m_new)
        l_new = l_run[:, :, :, rows] * corr + jnp.sum(p, axis=-1)
        upd = jnp.einsum("bhgrqk,bhrkd->bhgrqd", p.astype(vs.dtype), vs,
                         preferred_element_type=jnp.float32)
        acc_new = acc[:, :, :, rows] * corr[..., None] + upd
        return (
            m_run.at[:, :, :, rows].set(m_new),
            l_run.at[:, :, :, rows].set(l_new),
            acc.at[:, :, :, rows].set(acc_new),
        )

    for o in range(0, max_back + 1):
        rows = jnp.arange(o, n)
        cols = rows - o
        if int(rows.shape[0]) == 0:
            continue
        qs = qr[:, :, :, o:]
        ks = kr[:, :, : n - o]
        vs = vr[:, :, : n - o]
        m_run, l_run, acc = apply_block(m_run, l_run, acc, rows, cols, qs, ks, vs)
    if pb > 1 and causal:
        # Prefix-LM: early rows also attend FORWARD within the prefix blocks.
        for u in range(1, pb):
            rows = jnp.arange(0, pb - u)
            cols = rows + u
            qs = qr[:, :, :, : pb - u]
            ks = kr[:, :, u:pb]
            vs = vr[:, :, u:pb]
            m_run, l_run, acc = apply_block(m_run, l_run, acc, rows, cols, qs, ks, vs)

    out = acc / jnp.maximum(l_run, 1e-30)[..., None]
    out = out.reshape(B, Hq, Tp, Dv)[:, :, :T]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Softmax attention layer (GQA / MQA / MHA, RoPE, qk-norm, windows, prefix-LM)
# ---------------------------------------------------------------------------

def _rope_theta(cfg: ModelConfig, spec: LayerSpec) -> float:
    return spec.rope_theta if spec.rope_theta is not None else cfg.rope_theta


def _attention_remat(q, k, v, *, window, prefix_len, scale=None):
    """Flash-style AD: discard the online-softmax internals (the per-chunk
    scan residuals are enormous) and recompute attention in the backward
    pass — the same trade flash attention's backward makes."""
    impl = _ATTENTION_IMPL["impl"] or banded_attention

    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def inner(q, k, v):
        return impl(q, k, v, causal=True, window=window, prefix_len=prefix_len, scale=scale)

    return inner(q, k, v)


def attn_fullseq(
    p: Pytree,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    spec: LayerSpec,
    prefix_len: int = 0,
) -> jax.Array:
    B, T, _ = x.shape
    theta = _rope_theta(cfg, spec)
    q = jnp.einsum("btd,dhk->bhtk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bhtk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bhtk", x, p["wv"])
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    pos = jnp.arange(T, dtype=jnp.int32)
    q = apply_rope(q, pos, theta)
    k = apply_rope(k, pos, theta)
    out = _attention_remat(q, k, v, window=spec.window, prefix_len=prefix_len)
    return jnp.einsum("bhtk,hkd->btd", out, p["wo"])


def attn_init_cache(
    cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int, dtype=None
) -> Pytree:
    """Linear cache for global layers; ring buffer (size=window) for local."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    L = min(max_len, spec.window) if spec.window else max_len
    shape = (batch, cfg.n_kv_heads, L, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def _cache_write(cache_arr: jax.Array, new: jax.Array, idx: jax.Array, ring: bool):
    """Write one token (B, H, 1, D) at logical position idx.

    Uses a scatter (``.at[].set``) rather than dynamic-update-slice: with the
    cache sequence dim sharded over the model axis, SPMD lowers a DUS at a
    traced index to a masked select over the WHOLE local shard (measured 2x
    cache traffic per layer on musicgen decode); a single-row scatter
    partitions sparsely.
    """
    L = cache_arr.shape[2]
    slot = (idx % L) if ring else idx
    return cache_arr.at[:, :, slot].set(new[:, :, 0].astype(cache_arr.dtype))


def attn_prefill_cache(
    p: Pytree, x: jax.Array, *, cfg: ModelConfig, spec: LayerSpec, cache: Pytree
) -> Pytree:
    """Populate the cache from a full prefill sequence (post-RoPE K)."""
    B, T, _ = x.shape
    theta = _rope_theta(cfg, spec)
    k = jnp.einsum("btd,dhk->bhtk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bhtk", x, p["wv"])
    if cfg.use_qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    pos = jnp.arange(T, dtype=jnp.int32)
    k = apply_rope(k, pos, theta)
    L = cache["k"].shape[2]
    if spec.window and T > L:
        # Ring buffer: keep the last L tokens at slots pos % L.
        keep = jnp.arange(T - L, T)
        slots = keep % L
        k_keep = jnp.take(k, keep, axis=2)
        v_keep = jnp.take(v, keep, axis=2)
        order = jnp.argsort(slots)
        knew = jnp.take(k_keep, order, axis=2)
        vnew = jnp.take(v_keep, order, axis=2)
        return {"k": knew.astype(cache["k"].dtype), "v": vnew.astype(cache["v"].dtype)}
    pad = L - T
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return {"k": k.astype(cache["k"].dtype), "v": v.astype(cache["v"].dtype)}


def attn_decode(
    p: Pytree,
    x: jax.Array,
    cache: Pytree,
    idx: jax.Array,
    *,
    cfg: ModelConfig,
    spec: LayerSpec,
) -> Tuple[jax.Array, Pytree]:
    """x: (B, 1, d); idx: scalar int32, the position being generated."""
    theta = _rope_theta(cfg, spec)
    q = jnp.einsum("btd,dhk->bhtk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bhtk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bhtk", x, p["wv"])
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    posv = jnp.full((1,), idx, jnp.int32)
    q = apply_rope(q, posv, theta)
    k = apply_rope(k, posv, theta)
    ring = spec.window is not None and cache["k"].shape[2] == spec.window
    cache = {
        "k": _cache_write(cache["k"], k, idx, ring),
        "v": _cache_write(cache["v"], v, idx, ring),
    }
    out = _decode_attention(q, cache, idx, spec)
    return jnp.einsum("bhtk,hkd->btd", out, p["wo"]), cache


def _decode_attention(q, cache, idx, spec):
    """One-token attention against a (possibly ring) cache."""
    k, v = cache["k"], cache["v"]
    B, Hkv, L, D = k.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    qr = q.reshape(B, Hkv, G, 1, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qr, k.astype(qr.dtype),
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    ring = spec.window is not None and L == spec.window
    slots = jnp.arange(L, dtype=jnp.int32)
    if ring:
        kv_pos = idx - jnp.mod(idx - slots, L)
        mask = (kv_pos >= 0) & (kv_pos <= idx)
        if spec.window is not None:
            mask = mask & (kv_pos > idx - spec.window)
    else:
        mask = slots <= idx
        if spec.window is not None:
            mask = mask & (slots > idx - spec.window)
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    p_attn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p_attn.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hq, 1, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA — DeepSeek multi-head latent attention
# ---------------------------------------------------------------------------

def mla_fullseq(p: Pytree, x: jax.Array, *, cfg: ModelConfig, spec: LayerSpec) -> jax.Array:
    m = cfg.mla
    B, T, _ = x.shape
    nope, rpe, vdim = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    # Queries through the low-rank path.
    cq = rms_norm(jnp.einsum("btd,dr->btr", x, p["q_down"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("btr,rhk->bhtk", cq, p["q_up"])  # (B, H, T, nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    # Compressed KV cache + decoupled rope key.
    ckv_full = jnp.einsum("btd,dr->btr", x, p["kv_down"])
    ckv, k_rope = ckv_full[..., : m.kv_lora_rank], ckv_full[..., m.kv_lora_rank :]
    ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    kv = jnp.einsum("btr,rhk->bhtk", ckv, p["kv_up"])
    k_nope, v = kv[..., :nope], kv[..., nope:]
    pos = jnp.arange(T, dtype=jnp.int32)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, None], pos, cfg.rope_theta)  # (B, 1, T, rpe)
    k_rope_b = jnp.broadcast_to(k_rope, (B, cfg.n_heads, T, rpe))
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_cat = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    scale = 1.0 / math.sqrt(nope + rpe)
    out = _attention_remat(q_cat, k_cat, v, window=spec.window, prefix_len=0, scale=scale)
    return jnp.einsum("bhtk,hkd->btd", out, p["wo"])


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Pytree:
    m = cfg.mla
    dtype = dtype or jnp.dtype(cfg.dtype)
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def mla_prefill_cache(p, x, *, cfg: ModelConfig, cache: Pytree) -> Pytree:
    m = cfg.mla
    T = x.shape[1]
    ckv_full = jnp.einsum("btd,dr->btr", x, p["kv_down"])
    ckv = rms_norm(ckv_full[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(
        ckv_full[..., m.kv_lora_rank :][:, None], jnp.arange(T, dtype=jnp.int32), cfg.rope_theta
    )[:, 0]
    L = cache["ckv"].shape[1]
    pad = L - T
    ckv = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
    k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    return {"ckv": ckv.astype(cache["ckv"].dtype), "krope": k_rope.astype(cache["krope"].dtype)}


def mla_decode(
    p: Pytree, x: jax.Array, cache: Pytree, idx: jax.Array, *, cfg: ModelConfig
) -> Tuple[jax.Array, Pytree]:
    """Absorbed-matrix MLA decode: attend directly in the compressed space."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    nope, rpe, vdim = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    cq = rms_norm(jnp.einsum("btd,dr->btr", x, p["q_down"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("btr,rhk->bhtk", cq, p["q_up"])[:, :, 0]  # (B, H, nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    posv = jnp.full((1,), idx, jnp.int32)
    q_rope = apply_rope(q_rope[:, :, None], posv, cfg.rope_theta)[:, :, 0]
    # New cache entry.
    ckv_full = jnp.einsum("btd,dr->btr", x, p["kv_down"])[:, 0]
    ckv_new = rms_norm(ckv_full[: , : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    krope_new = apply_rope(ckv_full[:, m.kv_lora_rank :][:, None, None], posv, cfg.rope_theta)[:, 0, 0]
    cache = {
        "ckv": cache["ckv"].at[:, idx].set(ckv_new.astype(cache["ckv"].dtype)),
        "krope": cache["krope"].at[:, idx].set(krope_new.astype(cache["krope"].dtype)),
    }
    # Absorb kv_up(K) into the query: q_c = q_nope @ W_uk  -> compressed space.
    w_uk = p["kv_up"][..., :nope]  # (r, H, nope)
    q_c = jnp.einsum("bhk,rhk->bhr", q_nope, w_uk)  # (B, H, r)
    s = jnp.einsum("bhr,btr->bht", q_c.astype(cache["ckv"].dtype), cache["ckv"],
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhk,btk->bht", q_rope.astype(cache["krope"].dtype),
                       cache["krope"], preferred_element_type=jnp.float32)
    s = s / math.sqrt(nope + rpe)
    L = cache["ckv"].shape[1]
    mask = jnp.arange(L) <= idx
    s = jnp.where(mask[None, None], s, NEG_INF)
    attn = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bht,btr->bhr", attn.astype(cache["ckv"].dtype), cache["ckv"],
                     preferred_element_type=jnp.float32)  # (B, H, r)
    w_uv = p["kv_up"][..., nope:]  # (r, H, v)
    out = jnp.einsum("bhr,rhv->bhv", ctx.astype(x.dtype), w_uv)
    y = jnp.einsum("bhv,hvd->bd", out, p["wo"])[:, None]
    return y.astype(x.dtype), cache


# ---------------------------------------------------------------------------
# MoE — capacity-based sort/scatter dispatch (no O(T·E·C) one-hot einsums)
# ---------------------------------------------------------------------------

def moe_forward(
    p: Pytree, x: jax.Array, *, cfg: ModelConfig, deterministic: bool = True,
    rng: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y, aux_loss).  x: (B, T, d).

    Dispatch is PER BATCH ROW (GShard "groups" = batch rows): sort/scatter
    stays local to each data shard, expert tensors are sharded on the model
    axis, and the only cross-device traffic is the expert-dim resharding of
    the (B, E, C, d) buffers — measured ~40x less collective volume than a
    global-token sort on deepseek-v3 prefill (EXPERIMENTS.md §Perf).
    """
    from repro.distributed import constraints as DC

    m = cfg.moe
    if m.dispatch == "global":
        return moe_forward_global(p, x, cfg=cfg, deterministic=deterministic, rng=rng)
    B, T, d = x.shape
    E, K = m.n_experts, m.top_k
    logits = jnp.einsum(
        "btd,de->bte", x.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    if m.router_noise and not deterministic and rng is not None:
        logits = logits + jax.random.normal(rng, logits.shape) * m.router_noise
    if m.router_fn == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        gate_w, gate_i = jax.lax.top_k(scores, K)
        gate_w = gate_w / jnp.maximum(jnp.sum(gate_w, -1, keepdims=True), 1e-9)
        gate_w = gate_w * m.routed_scale
        probs = scores / jnp.maximum(jnp.sum(scores, -1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_i = jax.lax.top_k(probs, K)
        gate_w = gate_w / jnp.maximum(jnp.sum(gate_w, -1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch-style), over the global batch.
    # NOTE: mean over explicit axes (no reshape) — reshaping the sharded
    # (B, T, E) probs forced a 24 GB all-gather per layer (measured).
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[gate_i.reshape(-1)].add(1.0) / (B * T * K)
    aux = E * jnp.sum(me * ce) * m.aux_loss_weight

    capacity = int(math.ceil(m.capacity_factor * T * K / E))
    capacity = max(capacity, 4)

    def dispatch_row(xr, er, wr):
        """xr: (T, d); er/wr: (T, K).

        Returns the (E, C, d) expert buffer plus an INVERTED slot map
        (dst, wslot): destination token and gate weight per expert slot.
        The combine then scatter-adds from the expert-sharded domain, so
        only the (T, d) output crosses shards — not the (T*K, d) gather
        (8x less all-reduce volume at top-8; EXPERIMENTS.md §Perf cell B).
        """
        e_flat = er.reshape(-1)
        w_flat = wr.reshape(-1).astype(jnp.float32)
        tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
        order = jnp.argsort(e_flat, stable=True)
        e_s, w_s, tok_s = e_flat[order], w_flat[order], tok[order]
        first = jnp.searchsorted(e_s, e_s, side="left")
        pos = jnp.arange(e_s.shape[0], dtype=jnp.int32) - first.astype(jnp.int32)
        keep = pos < capacity
        slot = jnp.where(keep, e_s * capacity + pos, E * capacity)
        buf = jnp.zeros((E * capacity + 1, d), xr.dtype)
        buf = buf.at[slot].set(xr[tok_s], mode="drop")
        # Inverted map: expert slot -> (destination token, gate weight).
        dst = jnp.full((E * capacity + 1,), T, jnp.int32).at[slot].set(
            jnp.where(keep, tok_s, T), mode="drop"
        )
        wslot = jnp.zeros((E * capacity + 1,), jnp.float32).at[slot].set(
            jnp.where(keep, w_s, 0.0), mode="drop"
        )
        return buf[:-1].reshape(E, capacity, d), (
            dst[:-1].reshape(E, capacity),
            wslot[:-1].reshape(E, capacity),
        )

    buf, (dst, wslot) = jax.vmap(dispatch_row)(x, gate_i, gate_w)  # (B, E, C, d)
    buf = DC.constrain(buf, ("batch", "experts", None, None))

    h = jnp.einsum("becd,edgf->becgf", buf, p["wi"])
    act = jax.nn.silu(h[..., 0, :].astype(jnp.float32)).astype(x.dtype) * h[..., 1, :]
    eo = jnp.einsum("becf,efd->becd", act, p["wo"])            # (B, E, C, d)
    eo = DC.constrain(eo, ("batch", "experts", None, None))

    def combine_row(eor, dstr, wr):
        contrib = eor.astype(jnp.float32) * wr[..., None]      # (E, C, d)
        y = jnp.zeros((T + 1, d), jnp.float32)
        y = y.at[dstr.reshape(-1)].add(contrib.reshape(E * capacity, d), mode="drop")
        return y[:T]

    y = jax.vmap(combine_row)(eo, dst, wslot).astype(x.dtype)   # (B, T, d)

    if m.n_shared_experts:
        y = y + swiglu(x, p["shared"]["wi"], p["shared"]["wo"])
    return y, aux


def moe_forward_global(
    p: Pytree, x: jax.Array, *, cfg: ModelConfig, deterministic: bool = True,
    rng: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Global-token-sort dispatch (the naive baseline, kept selectable via
    ``MoEConfig.dispatch='global'``): sorts ALL tokens across the batch, which
    SPMD cannot shard — every device gathers every token.  Retained so the
    §Perf before/after and the Fig. 6-style injection sweep can measure it."""
    m = cfg.moe
    B, T, d = x.shape
    n_tok = B * T
    xf = x.reshape(n_tok, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    if m.router_fn == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        gate_w, gate_i = jax.lax.top_k(scores, m.top_k)
        gate_w = gate_w / jnp.maximum(jnp.sum(gate_w, -1, keepdims=True), 1e-9)
        gate_w = gate_w * m.routed_scale
        probs = scores / jnp.maximum(jnp.sum(scores, -1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_i = jax.lax.top_k(probs, m.top_k)
        gate_w = gate_w / jnp.maximum(jnp.sum(gate_w, -1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((m.n_experts,), jnp.float32).at[gate_i.reshape(-1)].add(1.0) / (
        n_tok * m.top_k
    )
    aux = m.n_experts * jnp.sum(me * ce) * m.aux_loss_weight
    capacity = max(int(math.ceil(m.capacity_factor * n_tok * m.top_k / m.n_experts)), 4)
    e_flat = gate_i.reshape(-1)
    w_flat = gate_w.reshape(-1).astype(jnp.float32)
    tok_flat = jnp.repeat(jnp.arange(n_tok, dtype=jnp.int32), m.top_k)
    order = jnp.argsort(e_flat, stable=True)
    e_s, w_s, tok_s = e_flat[order], w_flat[order], tok_flat[order]
    first = jnp.searchsorted(e_s, e_s, side="left")
    pos = jnp.arange(e_s.shape[0], dtype=jnp.int32) - first.astype(jnp.int32)
    keep = pos < capacity
    slot = jnp.where(keep, e_s * capacity + pos, m.n_experts * capacity)
    buf = jnp.zeros((m.n_experts * capacity + 1, d), x.dtype)
    buf = buf.at[slot].set(xf[tok_s], mode="drop")
    eb = buf[:-1].reshape(m.n_experts, capacity, d)
    h = jnp.einsum("ecd,edgf->ecgf", eb, p["wi"])
    act = jax.nn.silu(h[..., 0, :].astype(jnp.float32)).astype(x.dtype) * h[..., 1, :]
    eo = jnp.einsum("ecf,efd->ecd", act, p["wo"])
    out_rows = eo.reshape(m.n_experts * capacity, d)
    gathered = jnp.where(keep[:, None], out_rows[jnp.minimum(slot, out_rows.shape[0] - 1)], 0)
    y = jnp.zeros((n_tok, d), jnp.float32)
    y = y.at[tok_s].add(gathered.astype(jnp.float32) * w_s[:, None])
    y = y.astype(x.dtype)
    if m.n_shared_experts:
        y = y + swiglu(xf[None], p["shared"]["wi"], p["shared"]["wo"])[0]
    return y.reshape(B, T, d), aux


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma) recurrent block
# ---------------------------------------------------------------------------

def _causal_conv_fullseq(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, T, C); w: (W, C); b: (C,). Depthwise causal conv via shifts."""
    W = w.shape[0]
    T = x.shape[1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):
        # Tap i sees x[t - (W-1-i)]: left-pad by W-1-i, keep first T steps.
        shifted = jnp.pad(x, ((0, 0), (W - 1 - i, 0), (0, 0)))[:, :T]
        out = out + shifted.astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _block_diag_gate(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, T, H, bw); w: (H, bw, bw); b: (H, bw) -> sigmoid gate."""
    g = jnp.einsum("bthi,hij->bthj", x.astype(jnp.float32), w.astype(jnp.float32))
    return jax.nn.sigmoid(g + b.astype(jnp.float32))


def rglru_fullseq(p: Pytree, x: jax.Array, *, cfg: ModelConfig) -> jax.Array:
    r = cfg.rglru
    B, T, d = x.shape
    w = r.lru_width or d
    H = cfg.n_heads
    bw = w // H
    xb = jnp.einsum("btd,dw->btw", x, p["wx"])
    yb = jnp.einsum("btd,dw->btw", x, p["wy"])
    xc = _causal_conv_fullseq(xb, p["conv_w"], p["conv_b"])
    xh = xc.reshape(B, T, H, bw)
    gi = _block_diag_gate(xh, p["gate_w"][0], p["gate_b"][0])  # input gate
    gr = _block_diag_gate(xh, p["gate_w"][1], p["gate_b"][1])  # recurrence gate
    log_a = -8.0 * gr * jax.nn.softplus(p["a_param"].astype(jnp.float32)).reshape(H, bw)
    a = jnp.exp(log_a)
    gated_x = xh.astype(jnp.float32) * gi
    multiplier = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    inp = gated_x * multiplier

    # h_t = a_t * h_{t-1} + inp_t  — associative scan over T.
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_seq = a.reshape(B, T, w)
    b_seq = inp.reshape(B, T, w)
    _, h = jax.lax.associative_scan(combine, (a_seq, b_seq), axis=1)
    h = h.reshape(B, T, w).astype(x.dtype)
    out = h * jax.nn.gelu(yb.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("btw,wd->btd", out, p["wo"])


def rglru_init_state(cfg: ModelConfig, batch: int, dtype=None) -> Pytree:
    r = cfg.rglru
    dtype = dtype or jnp.dtype(cfg.dtype)
    w = r.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, r.conv_width - 1, w), dtype),
    }


def rglru_decode(
    p: Pytree, x: jax.Array, state: Pytree, *, cfg: ModelConfig
) -> Tuple[jax.Array, Pytree]:
    r = cfg.rglru
    B = x.shape[0]
    d = cfg.d_model
    w = r.lru_width or d
    H = cfg.n_heads
    bw = w // H
    xb = jnp.einsum("btd,dw->btw", x, p["wx"])[:, 0]  # (B, w)
    yb = jnp.einsum("btd,dw->btw", x, p["wy"])[:, 0]
    hist = jnp.concatenate([state["conv"], xb[:, None].astype(state["conv"].dtype)], axis=1)
    xc = (
        jnp.sum(hist.astype(jnp.float32) * p["conv_w"].astype(jnp.float32), axis=1)
        + p["conv_b"].astype(jnp.float32)
    ).astype(x.dtype)
    xh = xc.reshape(B, 1, H, bw)
    gi = _block_diag_gate(xh, p["gate_w"][0], p["gate_b"][0])[:, 0]
    gr = _block_diag_gate(xh, p["gate_w"][1], p["gate_b"][1])[:, 0]
    log_a = -8.0 * gr * jax.nn.softplus(p["a_param"].astype(jnp.float32)).reshape(H, bw)
    a = jnp.exp(log_a).reshape(B, w)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)).reshape(B, w)
    h_new = a * state["h"] + xc.astype(jnp.float32).reshape(B, w) * gi.reshape(B, w) * mult
    out = h_new.astype(x.dtype) * jax.nn.gelu(yb.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bw,wd->bd", out, p["wo"])[:, None]
    new_state = {"h": h_new, "conv": hist[:, 1:]}
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba-2 SSD block
# ---------------------------------------------------------------------------

def _ssd_project(p, x, cfg):
    s = cfg.ssd
    z = jnp.einsum("btd,dhk->bthk", x, p["wz"])
    xi = jnp.einsum("btd,dhk->bthk", x, p["wx"])
    bc = jnp.einsum("btd,dcgn->btcgn", x, p["wBC"])
    dt = jnp.einsum("btd,dh->bth", x, p["wdt"])
    return z, xi, bc, dt


def _ssd_conv_fullseq(xi, bc, p, cfg):
    s = cfg.ssd
    B, T = xi.shape[:2]
    nh, hd = xi.shape[2], xi.shape[3]
    xi_f = xi.reshape(B, T, nh * hd)
    conv_wx = p["conv_x"].reshape(s.conv_width, nh * hd)
    xi_c = _causal_conv_fullseq(xi_f, conv_wx, p["conv_b_x"].reshape(-1))
    xi_c = jax.nn.silu(xi_c.astype(jnp.float32)).astype(xi.dtype).reshape(B, T, nh, hd)
    bc_f = bc.reshape(B, T, -1)
    conv_wbc = p["conv_BC"].reshape(s.conv_width, -1)
    bc_c = _causal_conv_fullseq(bc_f, conv_wbc, p["conv_b_BC"].reshape(-1))
    bc_c = jax.nn.silu(bc_c.astype(jnp.float32)).astype(bc.dtype).reshape(bc.shape)
    return xi_c, bc_c


def ssd_fullseq(p: Pytree, x: jax.Array, *, cfg: ModelConfig) -> jax.Array:
    """Chunked SSD (Mamba-2 alg. 1), pure jnp (oracle for the Pallas kernel)."""
    s = cfg.ssd
    B, T, d = x.shape
    z, xi, bc, dt = _ssd_project(p, x, cfg)
    xi, bc = _ssd_conv_fullseq(xi, bc, p, cfg)
    Bm, Cm = bc[:, :, 0], bc[:, :, 1]  # (B, T, G, N)
    nh = xi.shape[2]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,T,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,) negative
    y = ssd_scan_ref(xi, dt, A, Bm, Cm, chunk=s.chunk_size)
    y = y + xi * p["D"].astype(xi.dtype)[None, None, :, None]
    # Gated RMSNorm (mamba2): norm(y * silu(z)).
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + 1e-6) * p["gnorm"].astype(jnp.float32)
    g = g.astype(x.dtype)
    return jnp.einsum("bthk,hkd->btd", g, p["wo"])


def ssd_scan_ref(xi, dt, A, Bm, Cm, *, chunk: int = 256) -> jax.Array:
    """Reference chunked SSD scan.

    xi: (B,T,H,P) values; dt: (B,T,H) f32; A: (H,) f32 negative;
    Bm, Cm: (B,T,G,N).  Groups broadcast over heads (H % G == 0).
    Returns (B,T,H,P) in xi.dtype.
    """
    Bsz, T, H, P = xi.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    c = min(chunk, T)
    pad = (-T) % c
    if pad:
        xi = jnp.pad(xi, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = T + pad
    nc = Tp // c
    xi_c = xi.reshape(Bsz, nc, c, H, P)
    dt_c = dt.reshape(Bsz, nc, c, H)
    B_c = Bm.reshape(Bsz, nc, c, G, N)
    C_c = Cm.reshape(Bsz, nc, c, G, N)
    # Broadcast groups to heads.
    B_h = jnp.repeat(B_c, rep, axis=3)  # (B,nc,c,H,N)
    C_h = jnp.repeat(C_c, rep, axis=3)

    dA = dt_c * A[None, None, None, :]               # (B,nc,c,H)  log-decay
    cum = jnp.cumsum(dA, axis=2)                     # within-chunk cumulative
    # Intra-chunk (lower-triangular "attention-like" matrix).
    # L[i,j] = exp(cum[i]-cum[j]) for i >= j.
    li = cum[:, :, :, None, :]                       # (B,nc,c,1,H)
    lj = cum[:, :, None, :, :]                       # (B,nc,1,c,H)
    decay = jnp.exp(jnp.minimum(li - lj, 0.0))       # clip avoids inf on upper tri
    tri = jnp.tril(jnp.ones((c, c), bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, 0.0)
    scores = jnp.einsum("bkihn,bkjhn->bkijh", C_h.astype(jnp.float32), B_h.astype(jnp.float32))
    scores = scores * decay
    xdt = xi_c.astype(jnp.float32) * dt_c[..., None]  # (B,nc,c,H,P)
    y_intra = jnp.einsum("bkijh,bkjhp->bkihp", scores, xdt)

    # Chunk summary states: S_k = sum_j exp(cum_end - cum_j) dt_j B_j x_j^T.
    seg = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,nc,c,H)
    S_chunk = jnp.einsum("bkjhn,bkjhp->bkhnp", (B_h.astype(jnp.float32) * (seg * dt_c)[..., None]),
                         xi_c.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])          # (B,nc,H)

    def step(Sprev, inp):
        Sc, dk = inp
        Snew = Sprev * dk[..., None, None] + Sc
        return Snew, Sprev

    S0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    _, S_before = jax.lax.scan(
        step, S0, (S_chunk.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    S_before = S_before.swapaxes(0, 1)               # (B,nc,H,N,P) state entering chunk
    inter_decay = jnp.exp(cum)                       # decay from chunk start to i
    y_inter = jnp.einsum("bkihn,bkhnp->bkihp", C_h.astype(jnp.float32) * inter_decay[..., None],
                         S_before)
    y = (y_intra + y_inter).reshape(Bsz, Tp, H, P)[:, :T]
    return y.astype(xi.dtype)


def ssd_init_state(cfg: ModelConfig, batch: int, dtype=None) -> Pytree:
    s = cfg.ssd
    dtype = dtype or jnp.dtype(cfg.dtype)
    di = s.d_inner(cfg.d_model)
    nh = di // s.head_dim
    return {
        "S": jnp.zeros((batch, nh, s.d_state, s.head_dim), jnp.float32),
        "conv_x": jnp.zeros((batch, s.conv_width - 1, nh, s.head_dim), dtype),
        "conv_BC": jnp.zeros((batch, s.conv_width - 1, 2, s.n_groups, s.d_state), dtype),
    }


def ssd_decode(
    p: Pytree, x: jax.Array, state: Pytree, *, cfg: ModelConfig
) -> Tuple[jax.Array, Pytree]:
    s = cfg.ssd
    B = x.shape[0]
    z, xi, bc, dt = _ssd_project(p, x, cfg)
    z, xi, bc, dt = z[:, 0], xi[:, 0], bc[:, 0], dt[:, 0]
    # Conv state update.
    hist_x = jnp.concatenate([state["conv_x"], xi[:, None].astype(state["conv_x"].dtype)], axis=1)
    xi_c = jnp.sum(hist_x.astype(jnp.float32) * p["conv_x"].astype(jnp.float32)[None], axis=1)
    xi_c = jax.nn.silu(xi_c + p["conv_b_x"].astype(jnp.float32)[None]).astype(x.dtype)
    hist_bc = jnp.concatenate([state["conv_BC"], bc[:, None].astype(state["conv_BC"].dtype)], axis=1)
    bc_c = jnp.sum(hist_bc.astype(jnp.float32) * p["conv_BC"].astype(jnp.float32)[None], axis=1)
    bc_c = jax.nn.silu(bc_c + p["conv_b_BC"].astype(jnp.float32)[None]).astype(x.dtype)
    Bv, Cv = bc_c[:, 0], bc_c[:, 1]                   # (B, G, N)
    H = xi_c.shape[1]
    rep = H // s.n_groups
    B_h = jnp.repeat(Bv, rep, axis=1)                 # (B, H, N)
    C_h = jnp.repeat(Cv, rep, axis=1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dtv * A[None])                       # (B,H)
    S = state["S"] * dA[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", B_h.astype(jnp.float32) * dtv[..., None], xi_c.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhnp->bhp", C_h.astype(jnp.float32), S)
    y = y + xi_c.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
    g = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = (g * jax.lax.rsqrt(var + 1e-6) * p["gnorm"].astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bhk,hkd->bd", g, p["wo"])[:, None]
    new_state = {"S": S, "conv_x": hist_x[:, 1:], "conv_BC": hist_bc[:, 1:]}
    return out, new_state
