"""Parameter-tree description and initialization.

Parameters are plain nested dicts of arrays.  A parallel tree of ``ParamSpec``
describes each leaf: shape, dtype, *logical axis names* and initializer.
Logical axes ("embed", "ffn", "q_heads", "experts", ...) are mapped to mesh
axes by ``repro.distributed.sharding`` — model code never mentions a mesh.

Depth is stacked for ``jax.lax.scan``: the repeating block pattern produces
one stacked entry per period position (leading logical axis "layers"), plus
unstacked entries for the truncated final period.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import (
    ATTN,
    MLA,
    MLP_DENSE,
    MLP_MOE,
    MLP_NONE,
    RGLRU,
    SSD,
    LayerSpec,
    ModelConfig,
)

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    init: str = "fan_in"      # fan_in | zeros | ones | rglru_a | ssd_a_log | ssd_dt_bias | normal_<std>
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (self.shape, self.logical_axes)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def _norm(d: int) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((d,), ("embed",), "ones", "float32")}


def _attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    out: Dict[str, ParamSpec] = {
        "wq": ParamSpec((d, h, hd), ("embed", "q_heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("q_heads", "head_dim", "embed")),
    }
    if cfg.use_qk_norm:
        out["q_norm"] = ParamSpec((hd,), ("head_dim",), "ones", "float32")
        out["k_norm"] = ParamSpec((hd,), ("head_dim",), "ones", "float32")
    return out


def _mla_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "q_down": ParamSpec((d, m.q_lora_rank), ("embed", "q_lora")),
        "q_norm": ParamSpec((m.q_lora_rank,), ("q_lora",), "ones", "float32"),
        "q_up": ParamSpec((m.q_lora_rank, h, qk), ("q_lora", "q_heads", "head_dim")),
        # kv_down projects to the compressed cache [c_kv | k_rope].
        "kv_down": ParamSpec(
            (d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "kv_lora")
        ),
        "kv_norm": ParamSpec((m.kv_lora_rank,), ("kv_lora",), "ones", "float32"),
        "kv_up": ParamSpec(
            (m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim),
            ("kv_lora", "q_heads", "head_dim"),
        ),
        "wo": ParamSpec((h, m.v_head_dim, d), ("q_heads", "head_dim", "embed")),
    }


def _dense_mlp_specs(d_model: int, d_ff: int) -> Dict[str, ParamSpec]:
    return {
        # Fused [gate; up] SwiGLU input projection.
        "wi": ParamSpec((d_model, 2, d_ff), ("embed", None, "ffn")),
        "wo": ParamSpec((d_ff, d_model), ("ffn", "embed")),
    }


def _moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    m = cfg.moe
    d = cfg.d_model
    out: Dict[str, ParamSpec] = {
        "router": ParamSpec((d, m.n_experts), ("embed", None), "fan_in", "float32"),
        "wi": ParamSpec((m.n_experts, d, 2, m.d_ff), ("experts", "embed", None, "moe_ffn")),
        "wo": ParamSpec((m.n_experts, m.d_ff, d), ("experts", "moe_ffn", "embed")),
    }
    if m.n_shared_experts:
        ff = m.shared_d_ff or m.d_ff * m.n_shared_experts
        out["shared"] = _dense_mlp_specs(d, ff)
    return out


def _rglru_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    r = cfg.rglru
    d = cfg.d_model
    w = r.lru_width or d
    h = cfg.n_heads
    assert w % h == 0, "lru_width must divide into gate heads"
    bw = w // h
    return {
        "wx": ParamSpec((d, w), ("embed", "lru")),
        "wy": ParamSpec((d, w), ("embed", "lru")),
        "conv_w": ParamSpec((r.conv_width, w), (None, "lru")),
        "conv_b": ParamSpec((w,), ("lru",), "zeros"),
        # Block-diagonal input & recurrence gates (Griffin eq. 3-4).
        "gate_w": ParamSpec((2, h, bw, bw), (None, "lru_heads", None, None)),
        "gate_b": ParamSpec((2, h, bw), (None, "lru_heads", None), "zeros"),
        "a_param": ParamSpec((w,), ("lru",), "rglru_a", "float32"),
        "wo": ParamSpec((w, d), ("lru", "embed")),
    }


def _ssd_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    s = cfg.ssd
    d = cfg.d_model
    di = s.d_inner(d)
    nh = di // s.head_dim
    g, st = s.n_groups, s.d_state
    return {
        "wz": ParamSpec((d, nh, s.head_dim), ("embed", "q_heads", "head_dim")),
        "wx": ParamSpec((d, nh, s.head_dim), ("embed", "q_heads", "head_dim")),
        "wBC": ParamSpec((d, 2, g, st), ("embed", None, None, "state")),
        "wdt": ParamSpec((d, nh), ("embed", "q_heads")),
        "conv_x": ParamSpec((s.conv_width, nh, s.head_dim), (None, "q_heads", "head_dim")),
        "conv_BC": ParamSpec((s.conv_width, 2, g, st), (None, None, None, "state")),
        "conv_b_x": ParamSpec((nh, s.head_dim), ("q_heads", "head_dim"), "zeros"),
        "conv_b_BC": ParamSpec((2, g, st), (None, None, "state"), "zeros"),
        "A_log": ParamSpec((nh,), ("q_heads",), "ssd_a_log", "float32"),
        "dt_bias": ParamSpec((nh,), ("q_heads",), "ssd_dt_bias", "float32"),
        "D": ParamSpec((nh,), ("q_heads",), "ones", "float32"),
        "gnorm": ParamSpec((nh, s.head_dim), ("q_heads", "head_dim"), "ones", "float32"),
        "wo": ParamSpec((nh, s.head_dim, d), ("q_heads", "head_dim", "embed")),
    }


def layer_specs_tree(cfg: ModelConfig, spec: LayerSpec) -> Dict[str, Any]:
    """Spec tree for a single layer of the given kind."""
    out: Dict[str, Any] = {"ln1": _norm(cfg.d_model)}
    if spec.kind == ATTN:
        out["attn"] = _attn_specs(cfg)
    elif spec.kind == MLA:
        out["attn"] = _mla_specs(cfg)
    elif spec.kind == RGLRU:
        out["rglru"] = _rglru_specs(cfg)
    elif spec.kind == SSD:
        out["ssd"] = _ssd_specs(cfg)
    else:
        raise ValueError(spec.kind)
    if spec.mlp != MLP_NONE:
        out["ln2"] = _norm(cfg.d_model)
        if spec.mlp == MLP_DENSE:
            out["mlp"] = _dense_mlp_specs(cfg.d_model, cfg.d_ff)
        elif spec.mlp == MLP_MOE:
            out["moe"] = _moe_specs(cfg)
        else:
            raise ValueError(spec.mlp)
    return out


def _stack_specs(tree: Pytree, n: int) -> Pytree:
    """Add a leading 'layers' axis of size n to every spec leaf."""

    def f(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, ("layers",) + s.logical_axes, s.init, s.dtype)

    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def block_layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_full_periods, n_remainder_layers) for the scan layout."""
    period = len(cfg.block_pattern)
    return cfg.n_layers // period, cfg.n_layers % period


def _retype(tree: Pytree, dtype: str) -> Pytree:
    """Weight dtype follows cfg.dtype; f32 leaves (norms, gates) stay f32."""

    def f(s: ParamSpec) -> ParamSpec:
        if s.dtype == "bfloat16" and dtype != "bfloat16":
            return ParamSpec(s.shape, s.logical_axes, s.init, dtype)
        return s

    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    cfg.validate()
    d, v = cfg.d_model, cfg.vocab_size
    out: Dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        out["embed"] = {
            "table": ParamSpec((v, d), ("vocab", "embed"), "normal_1.0")
        }
    n_full, rem = block_layout(cfg)
    blocks: Dict[str, Any] = {}
    if n_full:
        blocks["period"] = {
            f"p{i}": _stack_specs(layer_specs_tree(cfg, s), n_full)
            for i, s in enumerate(cfg.block_pattern)
        }
    if rem:
        blocks["rem"] = {
            f"r{i}": layer_specs_tree(cfg, cfg.block_pattern[i]) for i in range(rem)
        }
    out["blocks"] = blocks
    out["final_norm"] = _norm(d)
    if not cfg.tie_embeddings:
        if cfg.n_codebooks > 1:
            out["head"] = {
                "w": ParamSpec((cfg.n_codebooks, d, v), (None, "embed", "vocab"))
            }
        else:
            out["head"] = {"w": ParamSpec((d, v), ("embed", "vocab"))}
    if cfg.mtp_depth:
        # DeepSeek-V3 MTP: one extra block per depth, input = proj([h; e(t+k)]).
        out["mtp"] = {
            f"d{k}": {
                "proj": ParamSpec((2 * d, d), (None, "embed")),
                "ln_h": _norm(d),
                "ln_e": _norm(d),
                "block": layer_specs_tree(cfg, cfg.block_pattern[-1]),
            }
            for k in range(cfg.mtp_depth)
        }
    return _retype(out, cfg.dtype)


# ---------------------------------------------------------------------------
# Tree helpers
# ---------------------------------------------------------------------------

def iter_specs(tree: Pytree, prefix: str = "") -> Iterator[Tuple[str, ParamSpec]]:
    if isinstance(tree, ParamSpec):
        yield prefix, tree
        return
    for k in sorted(tree):
        yield from iter_specs(tree[k], f"{prefix}/{k}" if prefix else k)


def count_params(specs: Pytree, active_only: bool = False) -> int:
    total = 0
    for _, s in iter_specs(specs):
        n = s.size
        if active_only and "experts" in s.logical_axes:
            # Routed experts: only top_k of n_experts are active per token.
            e_dim = s.shape[s.logical_axes.index("experts")]
            frac = min(1.0, _ACTIVE_TOPK[0] / e_dim) if _ACTIVE_TOPK[0] else 1.0
            n = int(n * frac)
        total += n
    return total


# count_params needs the top_k without re-threading cfg; set by callers.
_ACTIVE_TOPK = [0]


def count_params_cfg(cfg: ModelConfig, active_only: bool = False) -> int:
    specs = param_specs(cfg)
    if cfg.moe:
        _ACTIVE_TOPK[0] = cfg.moe.top_k
    try:
        return count_params(specs, active_only=active_only)
    finally:
        _ACTIVE_TOPK[0] = 0


def non_embedding_param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Parameter count excluding vocab tables (for 6·N·D MODEL_FLOPS)."""
    specs = param_specs(cfg)
    if cfg.moe:
        _ACTIVE_TOPK[0] = cfg.moe.top_k
    try:
        total = 0
        for _, s in iter_specs(specs):
            if "vocab" in s.logical_axes:
                continue
            n = s.size
            if active_only and "experts" in s.logical_axes:
                e_dim = s.shape[s.logical_axes.index("experts")]
                frac = min(1.0, _ACTIVE_TOPK[0] / e_dim) if _ACTIVE_TOPK[0] else 1.0
                n = int(n * frac)
            total += n
        return total
    finally:
        _ACTIVE_TOPK[0] = 0


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _init_leaf(key: jax.Array, s: ParamSpec) -> jax.Array:
    dt = jnp.dtype(s.dtype)
    if s.init == "zeros":
        return jnp.zeros(s.shape, dt)
    if s.init == "ones":
        return jnp.ones(s.shape, dt)
    if s.init == "rglru_a":
        # Λ such that a = exp(-8·softplus(Λ)) lands in [0.9, 0.999].
        u = jax.random.uniform(key, s.shape, jnp.float32, 0.9, 0.999)
        y = -jnp.log(u) / 8.0
        lam = jnp.log(jnp.expm1(y))
        return lam.astype(dt)
    if s.init == "ssd_a_log":
        a = jax.random.uniform(key, s.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(a).astype(dt)
    if s.init == "ssd_dt_bias":
        dtv = jax.random.uniform(key, s.shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(dtv)).astype(dt)  # inverse softplus
    if s.init.startswith("normal_"):
        std = float(s.init.split("_", 1)[1])
        return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(dt)
    if s.init == "fan_in":
        # Fan-in = product of all axes left of the last "output block".
        # Heuristic: treat the first axis (after any 'layers' stack) as input.
        shape = s.shape
        offset = 1 if (s.logical_axes and s.logical_axes[0] == "layers") else 0
        fan_in = shape[offset] if len(shape) > offset else 1
        std = 1.0 / math.sqrt(max(1, fan_in))
        return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(dt)
    raise ValueError(s.init)


def init_params(cfg: ModelConfig, key: jax.Array) -> Pytree:
    """Materialize a parameter tree (smoke-scale use only).

    Per-leaf keys derive from a CRC of the path — deterministic across
    processes (readiness L3 requires bit-reproducible init).
    """
    import zlib

    specs = param_specs(cfg)
    flat = list(iter_specs(specs))
    leaves = {}
    for path, s in flat:
        k = jax.random.fold_in(key, zlib.crc32(path.encode()) & 0x7FFFFFFF)
        leaves[path] = _init_leaf(k, s)
    return unflatten(leaves)


def unflatten(flat: Dict[str, Any]) -> Pytree:
    out: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out


def flatten(tree: Pytree, prefix: str = "") -> Dict[str, Any]:
    if not isinstance(tree, dict):
        return {prefix: tree}
    out: Dict[str, Any] = {}
    for k, v in tree.items():
        out.update(flatten(v, f"{prefix}/{k}" if prefix else k))
    return out


def abstract_params(cfg: ModelConfig) -> Pytree:
    """ShapeDtypeStruct tree for dry-run lowering (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        param_specs(cfg),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
