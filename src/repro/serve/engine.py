"""Serving engine: prefill + decode with continuous batching (slot-based).

A fixed grid of ``batch`` slots is decoded in lock-step (one jitted decode
step per token across all slots — the standard TPU serving shape).  Finished
sequences free their slot; queued requests are prefilled into free slots
between decode steps.  Per-slot position indices live in the engine; the
jitted step uses the MAXIMUM position for cache masking, which is correct
(slots are masked by their own valid lengths via the per-slot `stop` logic)
but admits some wasted attention span for ragged batches — the paper-style
time-series benchmark tracks exactly this kind of serving regression.

Greedy and temperature sampling supported; everything is seeded and
deterministic (readiness L3).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig

Pytree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0    # 0 = greedy
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]
    prompt_len: int


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Pytree,
        *,
        batch: int,
        max_len: int,
        seed: int = 0,
    ):
        assert cfg.input_mode == "tokens", "engine serves token LMs"
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.key = jax.random.key(seed)

        self._decode = jax.jit(
            lambda p, s, b, i: T.decode_step(p, cfg, s, b, i)
        )
        # Single-sequence prefill reused per admission (padded to slot shape).
        self._prefill = jax.jit(
            lambda p, b: T.prefill(p, cfg, b, max_len=max_len, remat="none"),
            static_argnames=(),
        )

    # -- batched offline generation (all requests same length budget) --
    def generate(self, requests: List[Request]) -> List[Completion]:
        """Simple scheduler: admit in waves of ``batch``, decode lock-step."""
        out: List[Completion] = []
        for i in range(0, len(requests), self.batch):
            out.extend(self._generate_wave(requests[i : i + self.batch]))
        return out

    def _generate_wave(self, wave: List[Request]) -> List[Completion]:
        n = len(wave)
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((self.batch, plen), np.int32)
        for j, r in enumerate(wave):
            toks[j, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        logits, state = self._prefill(self.params, batch)
        completions = [Completion(r.uid, [], len(r.prompt)) for r in wave]
        live = np.ones(self.batch, bool)
        live[n:] = False
        budget = max(r.max_new_tokens for r in wave)
        cur = self._sample(logits[:, 0], wave)
        for j, r in enumerate(wave):
            completions[j].tokens.append(int(cur[j]))
        for t in range(1, budget):
            idx = jnp.asarray(plen + t - 1, jnp.int32)
            logits, state = self._decode(
                self.params, state, {"tokens": cur[:, None]}, idx
            )
            cur = self._sample(logits[:, 0], wave)
            for j, r in enumerate(wave):
                if not live[j]:
                    continue
                tok = int(cur[j])
                completions[j].tokens.append(tok)
                if len(completions[j].tokens) >= r.max_new_tokens or (
                    r.eos_id is not None and tok == r.eos_id
                ):
                    live[j] = False
            if not live.any():
                break
        return completions

    def _sample(self, logits: jax.Array, wave: List[Request]) -> jnp.ndarray:
        temps = np.zeros(self.batch, np.float32)
        for j, r in enumerate(wave):
            temps[j] = r.temperature
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if float(np.max(temps)) == 0.0:
            return greedy
        self.key, sub = jax.random.split(self.key)
        t = jnp.asarray(np.maximum(temps, 1e-6))
        sampled = jax.random.categorical(sub, logits / t[:, None]).astype(jnp.int32)
        return jnp.where(jnp.asarray(temps) > 0, sampled, greedy)
