"""Deterministic synthetic data pipeline.

Production shape without external data: documents with lognormal lengths are
packed into fixed-length sequences with EOS separators; every batch is a pure
function of (seed, step, host) so restarts resume bit-identically (readiness
L3) and multi-host sharding never duplicates data.  Modality stubs supply
frame/patch embeddings for the audio/VLM architectures per the assignment.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

EOS = 0
PAD_TARGET = -1


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 512
    global_batch: int = 8
    seed: int = 0
    mean_doc_len: float = 350.0
    sigma_doc_len: float = 0.6
    n_hosts: int = 1
    host_id: int = 0


class SyntheticLM:
    """Packed synthetic token stream (zipfian unigrams, per-doc shift so the
    model has learnable structure)."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        assert data.global_batch % data.n_hosts == 0
        self.cfg = cfg
        self.data = data
        self.host_batch = data.global_batch // data.n_hosts
        # Zipf-ish unigram distribution over the vocab.
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks**1.1
        self.probs = p / p.sum()

    def _rng(self, step: int) -> np.random.Generator:
        # (seed, step, host) -> independent stream; restart-stable.
        return np.random.default_rng(
            np.random.SeedSequence([self.data.seed, step, self.data.host_id])
        )

    def _pack_row(self, rng: np.random.Generator) -> np.ndarray:
        S = self.data.seq_len
        out = np.empty(S + 1, dtype=np.int32)
        pos = 0
        while pos < S + 1:
            ln = int(rng.lognormal(np.log(self.data.mean_doc_len), self.data.sigma_doc_len))
            ln = max(8, min(ln, S + 1 - pos))
            doc = rng.choice(len(self.probs), size=ln, p=self.probs).astype(np.int32)
            # learnable structure: token_{t+1} correlates with token_t.
            shift = int(rng.integers(1, 17))
            doc[1:] = (doc[:-1] + shift) % self.cfg.vocab_size
            doc[-1] = EOS
            out[pos : pos + ln] = doc
            pos += ln
        return out

    def batch(self, step: int) -> Dict[str, jax.Array]:
        rng = self._rng(step)
        rows = np.stack([self._pack_row(rng) for _ in range(self.host_batch)])
        tokens, targets = rows[:, :-1], rows[:, 1:].copy()
        targets[targets == EOS] = PAD_TARGET  # don't train on separators
        out: Dict[str, Any] = {
            "tokens": jnp.asarray(tokens),
            "targets": jnp.asarray(targets),
        }
        cfg = self.cfg
        if cfg.input_mode == "embeddings":
            emb = rng.standard_normal((self.host_batch, self.data.seq_len, cfg.d_model))
            out = {"embeds": jnp.asarray(emb, dtype=cfg.dtype)}
            if cfg.n_codebooks > 1:
                tgt = rng.integers(
                    0, cfg.vocab_size,
                    (self.host_batch, cfg.n_codebooks, self.data.seq_len),
                )
                out["targets"] = jnp.asarray(tgt, dtype=jnp.int32)
            else:
                out["targets"] = jnp.asarray(targets)
        elif cfg.prefix_len:
            pe = rng.standard_normal((self.host_batch, cfg.prefix_len, cfg.d_model))
            out["prefix_embeds"] = jnp.asarray(pe, dtype=cfg.dtype)
        return out

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
