"""Continuous campaign daemon: lag-driven refresh over pipeline documents.

A one-shot ``python -m repro run`` executes a pipeline and exits — but the
paper's whole point is *continuous* benchmarking: a collection that keeps
pace with an evolving ecosystem instead of being re-measured by hand.  This
module is that service mode::

    python -m repro daemon examples/pipelines/continuous.yml --store S

The daemon watches a set of registered pipeline documents and re-executes
cells on declarative triggers, declared per document by a ``schedule@v1``
component (see :data:`repro.core.orchestrator.SCHEDULE_SCHEMA`):

* ``lag`` — a producer cell whose newest store entry is older than the
  document's ``target_lag`` budget is stale and gets re-executed.
* ``watermark`` — when a watched prefix's *columnar watermark* advances
  (new measurements landed upstream, e.g. written by another daemon or a
  CI job sharing the store), every producer cell of the document is
  marked stale.
* ``downstream`` — consumer analyses/gates re-run only when the store
  sequence of a prefix they read has advanced past the cursor saved at
  their last run: an analysis is never recomputed over unchanged inputs.

**The incremental contract**: each tick computes staleness *per cell* from
the store manifest (no report is parsed on the warm path) plus the columnar
watermarks, and drains only the stale slice — through the in-process thread
scheduler or the ``CampaignBroker`` process pool (``worker_mode``).  A fresh
cell is never re-executed; on a crash restart the daemon resumes from
``daemon_state.json`` and, where that is missing, recovers each cell's last
refresh time by matching stored reports against the cell's signature
(prefix + spec fields + injection frame) — finished work is never repeated.

**Operational hardening** (the Clubmark playbook): per-tick and per-cell
deadlines, SIGTERM/SIGINT graceful drain (finish the in-flight cell batch,
persist the state cursor, exit 0), SIGHUP re-reads the document set, and
``python -m repro daemon-status`` renders per-document lag / last-refresh /
next-due / queue-depth from the state file and store directories without
touching the running process.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import signal
import tempfile
import threading
import time
import traceback
import uuid
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core import cicd
from repro.core.component import REGISTRY, ComponentRegistry, PipelineError
from repro.core.harness import Harness
from repro.core.orchestrator import SCHEDULE_TRIGGERS
from repro.core.retry import retry_counters
from repro.core.store import ResultStore

STATE_VERSION = 1
STATE_FILENAME = "daemon_state.json"
DEFAULT_TARGET_LAG = 300.0
DEFAULT_TICK_S = 5.0
DEFAULT_TRIGGERS = ("lag", "downstream")
DEFAULT_QUARANTINE_AFTER = 3
#: Bounded per-cell failure history kept in the state file (newest last).
QUARANTINE_HISTORY = 5


# ---------------------------------------------------------------------------
# Cell identity — what "this cell" means across ticks and restarts
# ---------------------------------------------------------------------------

def _sig_hash(doc: Dict[str, Any]) -> str:
    return hashlib.sha1(
        json.dumps(doc, sort_keys=True, default=str).encode()).hexdigest()[:16]


def payload_signature(payload: Dict[str, Any]) -> str:
    """Stable identity of one producer cell: prefix + spec fields +
    injection frame.  Seed and scheduling inputs are deliberately excluded —
    identity is *what gets measured*, not how it is dispatched."""
    spec = payload.get("spec", {}) or {}
    inj = payload.get("injections") or {}
    return _sig_hash({
        "prefix": payload.get("prefix", "default"),
        "arch": spec.get("arch", ""),
        "shape": spec.get("shape", ""),
        "system": spec.get("system", ""),
        "variant": spec.get("variant") or spec.get("shape", ""),
        "env": {k: str(v) for k, v in (inj.get("env") or {}).items()},
        "overrides": {k: str(v) for k, v in (inj.get("overrides") or {}).items()},
    })


def report_signature(prefix: str, report) -> str:
    """The same signature computed from a *stored* report, so a daemon with
    no state file can recognize which cell produced an existing entry.
    Mirrors :func:`payload_signature` field by field: harnesses record
    ``arch`` and the injection frame in ``report.parameter``, and the spec
    vocabulary in ``report.experiment``."""
    inj = report.parameter.get("injections") or {}
    return _sig_hash({
        "prefix": prefix,
        "arch": str(report.parameter.get("arch", "")),
        "shape": report.experiment.usecase,
        "system": report.experiment.system,
        "variant": report.experiment.variant,
        "env": {k: str(v) for k, v in (inj.get("env") or {}).items()},
        "overrides": {k: str(v) for k, v in (inj.get("overrides") or {}).items()},
    })


# ---------------------------------------------------------------------------
# Per-document schedule policy (the schedule@v1 declaration, resolved)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SchedulePolicy:
    target_lag: float = DEFAULT_TARGET_LAG
    triggers: Tuple[str, ...] = DEFAULT_TRIGGERS
    watch: Tuple[str, ...] = ()
    tick_s: float = DEFAULT_TICK_S
    cell_deadline_s: float = 0.0
    tick_deadline_s: float = 0.0
    max_cells_per_tick: int = 0
    #: Circuit-breaker: a cell whose refresh fails this many consecutive
    #: ticks is parked (skipped by staleness, surfaced by daemon-status)
    #: instead of burning broker respawn budget forever.  0 disables.
    quarantine_after: int = DEFAULT_QUARANTINE_AFTER

    @staticmethod
    def from_calls(calls: Sequence[Any], *,
                   target_lag: Optional[float] = None,
                   tick_s: Optional[float] = None) -> "SchedulePolicy":
        """The document's ``schedule@v1`` declaration (defaults when absent);
        explicit daemon-level overrides win over the document."""
        inputs: Dict[str, Any] = {}
        for call in calls:
            if call.name == "schedule":
                inputs = dict(call.inputs)
                break
        triggers = tuple(str(t) for t in inputs.get("triggers", DEFAULT_TRIGGERS))
        unknown = sorted(set(triggers) - set(SCHEDULE_TRIGGERS))
        if unknown:
            raise PipelineError(
                f"schedule: unknown trigger(s) {unknown}; "
                f"known: {list(SCHEDULE_TRIGGERS)}")
        return SchedulePolicy(
            target_lag=float(target_lag if target_lag is not None
                             else inputs.get("target_lag", DEFAULT_TARGET_LAG)),
            triggers=triggers,
            watch=tuple(str(p) for p in inputs.get("watch", ())),
            tick_s=float(tick_s if tick_s is not None
                         else inputs.get("tick_s", DEFAULT_TICK_S)),
            cell_deadline_s=float(inputs.get("cell_deadline_s", 0.0)),
            tick_deadline_s=float(inputs.get("tick_deadline_s", 0.0)),
            max_cells_per_tick=int(inputs.get("max_cells_per_tick", 0)),
            quarantine_after=int(
                inputs.get("quarantine_after", DEFAULT_QUARANTINE_AFTER)),
        )


@dataclasses.dataclass
class _Document:
    """One registered pipeline document, parsed and decomposed."""

    path: str
    calls: List[Any]
    policy: SchedulePolicy
    #: {cell_key: payload} for every producer cell (sweep points included).
    cells: Dict[str, Dict[str, Any]]
    #: [(consumer_key, call, consumed_prefixes)] for analyses/gates.
    consumers: List[Tuple[str, Any, List[str]]]
    #: prefixes this document's producers write.
    produced: List[str]


def _decompose(path: str, calls: List[Any], policy: SchedulePolicy) -> _Document:
    from repro.core import workers as workers_mod  # lazy: heavy import chain

    payloads, owners = workers_mod.pipeline_payloads(calls)
    cells: Dict[str, Dict[str, Any]] = {}
    for ci, idxs in owners.items():
        for k, j in enumerate(idxs):
            payload = payloads[j]
            key = f"{ci:03d}.{k:03d}.{payload_signature(payload)}"
            cells[key] = payload
    produced = sorted({p.get("prefix", "default") for p in payloads})
    consumers: List[Tuple[str, Any, List[str]]] = []
    for ci, call in enumerate(calls):
        if call.name in cicd._PRODUCERS or call.name == "schedule":
            continue
        prefixes = cicd._consumed_prefixes(call)
        if call.name == "campaign-report" and not prefixes:
            prefixes = list(produced)  # whole-store report: watch our producers
        consumers.append((f"{ci:03d}.{call.name}", call, prefixes))
    return _Document(path=path, calls=calls, policy=policy,
                     cells=cells, consumers=consumers, produced=produced)


# ---------------------------------------------------------------------------
# The daemon
# ---------------------------------------------------------------------------

class CampaignDaemon:
    """Long-running refresh service over registered pipeline documents.

    ``tick(now=...)`` is the testable unit: one staleness pass + refresh of
    exactly the stale slice, state persisted afterwards.  ``run()`` wraps it
    with the signal-handled service loop.
    """

    def __init__(
        self,
        store: Union[str, Path, ResultStore],
        documents: Sequence[Union[str, Path]],
        *,
        backend: str = "dir",
        state_path: Optional[Union[str, Path]] = None,
        harness: Optional[Harness] = None,
        workers: int = 2,
        worker_mode: str = "thread",
        target_lag: Optional[float] = None,
        interval: Optional[float] = None,
        max_ticks: Optional[int] = None,
        registry: Optional[ComponentRegistry] = None,
    ):
        self.store = (store if isinstance(store, ResultStore)
                      else ResultStore(store, backend=backend))
        self.document_paths = [str(p) for p in documents]
        self.state_path = Path(state_path) if state_path else (
            Path(self.store.root) / STATE_FILENAME)
        if harness is None:
            from repro.core.harness import ExecHarness  # the run_pipeline default
            harness = ExecHarness(steps=2, batch=2, seq=16)
        self.harness = harness
        self.workers = max(1, int(workers))
        if worker_mode not in ("thread", "process"):
            raise PipelineError(
                f"bad worker_mode {worker_mode!r} (want 'thread' or 'process')")
        self.worker_mode = worker_mode
        self.target_lag_override = target_lag
        self.interval_override = interval
        self.max_ticks = max_ticks
        self.registry = registry or REGISTRY
        self.documents: List[_Document] = []
        self.state: Dict[str, Any] = {}
        self.ticks = 0
        self._stop = threading.Event()
        self._reload = threading.Event()
        self.load_documents()
        self.state = self._load_state()

    # ------------------------------------------------------------ documents
    def load_documents(self) -> None:
        """(Re-)parse every registered document — the SIGHUP path."""
        docs: List[_Document] = []
        for path in self.document_paths:
            text = Path(path).read_text()
            calls = cicd.parse_pipeline_text(text, registry=self.registry)
            policy = SchedulePolicy.from_calls(
                calls, target_lag=self.target_lag_override,
                tick_s=self.interval_override)
            docs.append(_decompose(path, calls, policy))
        if not docs:
            raise PipelineError("daemon needs at least one pipeline document")
        self.documents = docs

    # ---------------------------------------------------------------- state
    def _load_state(self) -> Dict[str, Any]:
        try:
            state = json.loads(self.state_path.read_text())
        except (OSError, ValueError):
            state = {}
        if int(state.get("version", STATE_VERSION)) != STATE_VERSION:
            state = {}
        state.setdefault("version", STATE_VERSION)
        state.setdefault("ticks", 0)
        state.setdefault("documents", {})
        self.ticks = int(state.get("ticks", 0))
        return state

    def save_state(self) -> None:
        self.state["version"] = STATE_VERSION
        self.state["ticks"] = self.ticks
        self.state["updated"] = time.time()
        self.state_path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.state_path.parent, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.state, f, indent=2, default=str)
            os.replace(tmp, self.state_path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def _doc_state(self, doc: _Document) -> Dict[str, Any]:
        docs = self.state.setdefault("documents", {})
        st = docs.setdefault(doc.path, {})
        st.setdefault("cells", {})
        st.setdefault("consumers", {})
        st.setdefault("watch_marks", {})
        return st

    # ------------------------------------------------------------ staleness
    def _recovered_refresh_times(self, doc: _Document) -> Dict[str, float]:
        """{cell_key: newest matching entry timestamp} recovered from the
        store itself — the crash-restart path.  Parses each produced prefix
        once (warm queries hit the parsed-report cache); only consulted for
        cells the state file doesn't know."""
        by_sig: Dict[Tuple[str, str], float] = {}
        for prefix in doc.produced:
            for entry, report in self.store.query_with_entries(prefix):
                sig = report_signature(prefix, report)
                ts = float(entry.timestamp)
                key = (prefix, sig)
                if ts > by_sig.get(key, float("-inf")):
                    by_sig[key] = ts
        out: Dict[str, float] = {}
        for key, payload in doc.cells.items():
            sig = payload_signature(payload)
            ts = by_sig.get((payload.get("prefix", "default"), sig))
            if ts is not None:
                out[key] = ts
        return out

    def _stale_cells(self, doc: _Document, now: float) -> Dict[str, str]:
        """{cell_key: reason} for every producer cell due for refresh."""
        st = self._doc_state(doc)
        if st.get("suspended"):
            return {}  # parked documents are never stale (tick skips them too)
        cells_st = st["cells"]
        recovered: Optional[Dict[str, float]] = None
        watch_advanced: List[str] = []
        if "watermark" in doc.policy.triggers:
            marks = st["watch_marks"]
            for prefix in doc.policy.watch:
                wm = int(self.store.columnar.watermark(prefix))
                if wm > int(marks.get(prefix, -1)):
                    watch_advanced.append(prefix)
        stale: Dict[str, str] = {}
        for key, payload in doc.cells.items():
            if cells_st.get(key, {}).get("quarantined"):
                continue  # parked by the circuit-breaker; clear to resume
            last = cells_st.get(key, {}).get("last_refresh")
            if last is None:
                if recovered is None:
                    recovered = self._recovered_refresh_times(doc)
                last = recovered.get(key)
                if last is not None:
                    # Persist the recovery so the next tick is manifest-only.
                    cells_st.setdefault(key, {})["last_refresh"] = float(last)
                    cells_st[key].setdefault("cell", _cell_label(payload))
            if last is None:
                stale[key] = "never-run"
            elif "lag" in doc.policy.triggers and \
                    now - float(last) > doc.policy.target_lag:
                stale[key] = "lag"
            elif watch_advanced:
                stale[key] = f"watermark:{','.join(watch_advanced)}"
        return stale

    def _due_consumers(self, doc: _Document) -> List[Tuple[str, Any, Dict[str, int]]]:
        """Consumers whose consumed prefixes advanced past their cursors."""
        if "downstream" not in doc.policy.triggers:
            return []
        st = self._doc_state(doc)
        due = []
        for key, call, prefixes in doc.consumers:
            cursors = {p: _last_seq(self.store, p) for p in prefixes}
            saved = st["consumers"].get(key, {}).get("cursors", {})
            if any(seq > int(saved.get(p, -1)) for p, seq in cursors.items()):
                due.append((key, call, cursors))
        return due

    # -------------------------------------------------------------- refresh
    def _refresh_cells(
        self, doc: _Document, stale: Dict[str, str], now: float,
    ) -> Dict[str, Dict[str, Any]]:
        """Execute exactly the stale slice; returns {cell_key: result}."""
        from repro.core import workers as workers_mod  # lazy: heavy import

        keys = sorted(stale)
        if doc.policy.max_cells_per_tick > 0:
            keys = keys[: doc.policy.max_cells_per_tick]
        batch = f"daemon-t{self.ticks}-{uuid.uuid4().hex[:6]}"
        payloads = []
        for i, key in enumerate(keys):
            p = dict(doc.cells[key])
            # A FRESH uid per refresh: reusing one across ticks would make a
            # future retry's adoption check adopt a stale tick's report.
            p["task_uid"] = f"{batch}:{i}"
            payloads.append(p)
        results: Dict[str, Dict[str, Any]] = {}
        if not payloads:
            return results
        if self.worker_mode == "process":
            broker = workers_mod.CampaignBroker(
                self.store, workers=self.workers, name=batch,
                deadline_s=doc.policy.cell_deadline_s or None)
            by_idx = broker.run(payloads, harness=self.harness)
            for i, key in enumerate(keys):
                results[key] = by_idx.get(i) or {}
        else:
            t0 = time.monotonic()

            def _one(payload: Dict[str, Any]) -> Dict[str, Any]:
                return workers_mod._execute_payload(
                    payload, store=self.store, harness=self.harness,
                    worker_id="daemon", attempt=1, resource_scope="thread")

            if self.workers > 1 and len(payloads) > 1:
                from repro.core.scheduler import CampaignScheduler
                sched = CampaignScheduler(
                    parallelism=self.workers, name="daemon.refresh")
                trs = sched.map_items(_one, payloads)
                for key, tr in zip(keys, trs):
                    results[key] = tr.value if tr.error is None else {
                        "error": tr.error, "readiness": 0}
            else:
                for key, payload in zip(keys, payloads):
                    if self._stop.is_set():
                        break  # graceful drain: leave the rest to next start
                    if doc.policy.tick_deadline_s and \
                            time.monotonic() - t0 > doc.policy.tick_deadline_s:
                        break  # per-tick deadline: remaining cells stay stale
                    results[key] = _one(payload)
        st = self._doc_state(doc)
        for key, result in results.items():
            cell_st = st["cells"].setdefault(key, {})
            cell_st["cell"] = _cell_label(doc.cells[key])
            cell_st["last_refresh"] = now
            cell_st["last_seq"] = _last_seq(
                self.store, doc.cells[key].get("prefix", "default"))
            cell_st["refresh_count"] = int(cell_st.get("refresh_count", 0)) + 1
            cell_st["last_error"] = result.get("error")
            if result.get("error"):
                # Circuit-breaker accounting: consecutive failed refreshes,
                # with a bounded attempt history for the status view.
                streak = int(cell_st.get("fail_streak", 0)) + 1
                cell_st["fail_streak"] = streak
                history = list(cell_st.get("history", []))
                history.append({
                    "ts": now,
                    "error": str(result.get("error"))[:300],
                    "attempts": int(result.get("attempts", 0) or 0),
                })
                cell_st["history"] = history[-QUARANTINE_HISTORY:]
                qa = doc.policy.quarantine_after
                if qa and streak >= qa:
                    cell_st["quarantined"] = {
                        "since": now,
                        "reason": f"{streak} consecutive failed refreshes "
                                  f"(quarantine_after={qa}); last: "
                                  f"{str(result.get('error'))[:120]}",
                        "fail_streak": streak,
                    }
            else:
                cell_st["fail_streak"] = 0
                cell_st.pop("history", None)
                cell_st.pop("quarantined", None)
        return results

    def clear_quarantine(self, cell_key: Optional[str] = None) -> List[str]:
        """Un-park quarantined cells (all of them, or just ``cell_key``);
        they become eligible for refresh on the next tick.  Returns the
        cleared keys.  The operator path after fixing a poisoned cell."""
        cleared: List[str] = []
        for doc in self.documents:
            cells_st = self._doc_state(doc)["cells"]
            for key, cell_st in cells_st.items():
                if cell_key is not None and key != cell_key:
                    continue
                if cell_st.pop("quarantined", None) is not None:
                    cell_st["fail_streak"] = 0
                    cleared.append(key)
        if cleared:
            self.save_state()
        return cleared

    # ------------------------------------------------------ suspend/resume
    def _match_documents(self, doc: str) -> List[_Document]:
        return [d for d in self.documents
                if d.path == doc or Path(d.path).name == doc]

    def suspend(self, doc: str) -> List[str]:
        """Park one document's schedule (matched by path or basename):
        persisted in the state file and skipped by every staleness scan
        until :meth:`resume` — the service keeps ticking the rest.
        Returns the suspended paths; unknown documents are an error, not a
        silent no-op."""
        matches = self._match_documents(doc)
        if not matches:
            known = ", ".join(d.path for d in self.documents)
            raise PipelineError(
                f"no registered document matches {doc!r}; known: {known}")
        out: List[str] = []
        for d in matches:
            self._doc_state(d)["suspended"] = {"since": time.time()}
            out.append(d.path)
        self.save_state()
        return out

    def resume(self, doc: str) -> List[str]:
        """Lift a :meth:`suspend`; returns the paths actually resumed."""
        matches = self._match_documents(doc)
        if not matches:
            known = ", ".join(d.path for d in self.documents)
            raise PipelineError(
                f"no registered document matches {doc!r}; known: {known}")
        out = [d.path for d in matches
               if self._doc_state(d).pop("suspended", None) is not None]
        if out:
            self.save_state()
        return out

    def _run_consumers(
        self, doc: _Document, due: List[Tuple[str, Any, Dict[str, int]]],
        now: float,
    ) -> Dict[str, Dict[str, Any]]:
        st = self._doc_state(doc)
        out: Dict[str, Dict[str, Any]] = {}
        for key, call, cursors in due:
            if self._stop.is_set():
                break
            try:
                summary = cicd._run_component(
                    call, store=self.store, harness=self.harness,
                    harness_factory=None, registry=self.registry)
            except Exception as e:  # noqa: BLE001 — isolation, like run_pipeline
                summary = {"component": call.name, "component_ref": call.ref,
                           "error": f"{type(e).__name__}: {e}\n"
                                    f"{traceback.format_exc(limit=3)}"}
            out[key] = summary
            # Cursors move even on error: a crashing analysis must not spin
            # every tick — it re-runs when its inputs next advance.
            cst = st["consumers"].setdefault(key, {})
            cst["cursors"] = {p: int(s) for p, s in cursors.items()}
            cst["last_run"] = now
            cst["run_count"] = int(cst.get("run_count", 0)) + 1
            cst["last_error"] = summary.get("error")
        return out

    # ----------------------------------------------------------------- tick
    def tick(self, now: Optional[float] = None) -> Dict[str, Any]:
        """One staleness pass: refresh stale producers, re-run due consumers,
        persist state.  ``now`` is injectable for deterministic tests."""
        now = time.time() if now is None else float(now)
        summary: Dict[str, Any] = {"tick": self.ticks, "now": now,
                                   "documents": {}}
        for doc in self.documents:
            if self._stop.is_set():
                break
            st = self._doc_state(doc)
            if st.get("suspended"):
                # Parked by the operator: no staleness scan, no refreshes,
                # no consumers — the document sits out ticks (and its lag
                # grows) until `daemon-status --resume` lifts it.
                summary["documents"][doc.path] = {
                    "cells": len(doc.cells),
                    "suspended": True,
                    "stale": {},
                    "refreshed": [],
                    "fresh": [],
                    "quarantined": sorted(
                        k for k, c in st["cells"].items()
                        if c.get("quarantined")),
                    "consumers_run": [],
                }
                continue
            stale = self._stale_cells(doc, now)
            refreshed = self._refresh_cells(doc, stale, now)
            # Watch marks advance only once acted on, so a missed tick never
            # loses an upstream change.
            if "watermark" in doc.policy.triggers:
                marks = self._doc_state(doc)["watch_marks"]
                for prefix in doc.policy.watch:
                    marks[prefix] = int(self.store.columnar.watermark(prefix))
            due = self._due_consumers(doc)
            consumed = self._run_consumers(doc, due, now)
            st = self._doc_state(doc)
            st["last_tick"] = now
            quarantined = sorted(
                k for k, c in st["cells"].items() if c.get("quarantined"))
            summary["documents"][doc.path] = {
                "cells": len(doc.cells),
                "stale": {k: stale[k] for k in sorted(stale)},
                "refreshed": sorted(refreshed),
                "fresh": sorted(set(doc.cells) - set(stale) - set(quarantined)),
                "quarantined": quarantined,
                "consumers_run": sorted(consumed),
            }
        self.ticks += 1
        self.save_state()
        return summary

    # ---------------------------------------------------------- service loop
    def request_stop(self) -> None:
        self._stop.set()

    def _install_signals(self) -> bool:
        if threading.current_thread() is not threading.main_thread():
            return False

        def _term(signum, frame):  # noqa: ARG001
            self._stop.set()

        def _hup(signum, frame):  # noqa: ARG001
            self._reload.set()

        signal.signal(signal.SIGTERM, _term)
        signal.signal(signal.SIGINT, _term)
        if hasattr(signal, "SIGHUP"):
            signal.signal(signal.SIGHUP, _hup)
        return True

    def _interval(self) -> float:
        if self.interval_override is not None:
            return max(0.05, float(self.interval_override))
        return max(0.05, min(d.policy.tick_s for d in self.documents))

    def run(self) -> int:
        """The service loop: tick, sleep, repeat — until SIGTERM/SIGINT
        (graceful drain: the in-flight cell finishes, state persists, exit
        0) or ``max_ticks`` ticks have run (the CI harness mode)."""
        self._install_signals()
        try:
            while not self._stop.is_set():
                if self._reload.is_set():
                    self._reload.clear()
                    try:
                        self.load_documents()
                    except (OSError, PipelineError) as e:
                        # A torn edit must not kill the service; the old
                        # document set keeps running until the next HUP.
                        print(f"daemon: reload failed, keeping old documents: {e}")
                self.tick()
                if self.max_ticks is not None and self.ticks >= self.max_ticks:
                    break
                deadline = time.monotonic() + self._interval()
                while time.monotonic() < deadline:
                    if self._stop.is_set() or self._reload.is_set():
                        break
                    time.sleep(min(0.1, max(0.01, deadline - time.monotonic())))
        finally:
            self.save_state()
        return 0


def _cell_label(payload: Dict[str, Any]) -> str:
    spec = payload.get("spec", {}) or {}
    return (f"{payload.get('prefix', 'default')}/"
            f"{spec.get('arch', '?')}.{spec.get('shape', '?')}."
            f"{spec.get('system', '?')}")


def _last_seq(store: ResultStore, prefix: str) -> int:
    index = store.index(prefix)
    return int(index[-1].seq) if index else -1


# ---------------------------------------------------------------------------
# Status view — reads state + store + queue directories, no daemon required
# ---------------------------------------------------------------------------

def queue_depth(store_root: Union[str, Path]) -> int:
    """Outstanding (not-done) cells across every work queue under the store
    root — the broker removes finished queues, so nonzero means a drain is
    in flight right now."""
    from repro.core.workers import QUEUE_DIRNAME
    from repro.core.workqueue import WorkQueue, WorkQueueError

    depth = 0
    base = Path(store_root) / QUEUE_DIRNAME
    if not base.is_dir():
        return 0
    for qdir in sorted(base.iterdir()):
        if not qdir.is_dir():
            continue
        try:
            q = WorkQueue(qdir)
            depth += max(0, q.n_tasks - q.done_count())
        except WorkQueueError:
            continue  # torn/partial queue directory
    return depth


def worker_liveness(store_root: Union[str, Path]) -> Dict[str, Any]:
    """Per-host worker liveness aggregated from every active queue's worker
    registry (``<queue>/workers/`` files; mtime = last touch).  Remote hosts
    joined via ``python -m repro.core.workers`` appear here too — the
    registry lives on the shared filesystem, like everything else."""
    from repro.core.workers import QUEUE_DIRNAME, host_of
    from repro.core.workqueue import WorkQueue

    workers: List[Dict[str, Any]] = []
    base = Path(store_root) / QUEUE_DIRNAME
    if base.is_dir():
        for qdir in sorted(base.iterdir()):
            if not qdir.is_dir():
                continue
            try:
                for w in WorkQueue(qdir).worker_registry():
                    w["queue"] = qdir.name
                    workers.append(w)
            except OSError:
                continue
    hosts: Dict[str, Dict[str, int]] = {}
    for w in workers:
        host = str(w.get("host") or host_of(str(w.get("worker", ""))) or "?")
        slot = hosts.setdefault(host, {"workers": 0, "alive": 0})
        slot["workers"] += 1
        slot["alive"] += int(bool(w.get("alive")))
    return {"workers": workers, "hosts": hosts}


def daemon_status(
    store: Union[str, Path, ResultStore],
    documents: Sequence[Union[str, Path]],
    *,
    backend: str = "dir",
    state_path: Optional[Union[str, Path]] = None,
    target_lag: Optional[float] = None,
    now: Optional[float] = None,
    registry: Optional[ComponentRegistry] = None,
) -> Dict[str, Any]:
    """Per-document lag / last-refresh / next-due / queue-depth, computed
    from the state file and the store manifest (the daemon itself is not
    contacted — this works on a crashed or stopped deployment too)."""
    store = (store if isinstance(store, ResultStore)
             else ResultStore(store, backend=backend))
    state_file = Path(state_path) if state_path else (
        Path(store.root) / STATE_FILENAME)
    try:
        state = json.loads(state_file.read_text())
    except (OSError, ValueError):
        state = {}
    now = time.time() if now is None else float(now)
    registry = registry or REGISTRY
    out: Dict[str, Any] = {
        "state_path": str(state_file),
        "ticks": int(state.get("ticks", 0)),
        "updated": state.get("updated"),
        "queue_depth": queue_depth(store.root),
        # Robustness surfaces: who is draining (per host), and how hard the
        # I/O layer has been working (process-local retry counters).
        "workers": worker_liveness(store.root),
        "retry_counters": retry_counters(),
        "documents": {},
    }
    for path in documents:
        path = str(path)
        calls = cicd.parse_pipeline_text(Path(path).read_text(),
                                         registry=registry)
        policy = SchedulePolicy.from_calls(calls, target_lag=target_lag)
        doc = _decompose(path, calls, policy)
        doc_st = state.get("documents", {}).get(path, {})
        suspended = doc_st.get("suspended")
        cells_st = doc_st.get("cells", {})
        cells = []
        for key in sorted(doc.cells):
            payload = doc.cells[key]
            st = cells_st.get(key, {})
            last = st.get("last_refresh")
            if last is None:
                # No state: fall back to the prefix manifest's newest entry
                # (cheap, metadata-only; per-cell precision needs the state).
                prefix = payload.get("prefix", "default")
                index = store.index(prefix)
                last = float(index[-1].timestamp) if index else None
            lag = (now - float(last)) if last is not None else None
            next_due = (float(last) + policy.target_lag
                        if last is not None else now)
            quarantined = st.get("quarantined")
            cells.append({
                "key": key,
                "cell": _cell_label(payload),
                "last_refresh": last,
                "lag_s": lag,
                "next_due": next_due,
                # A quarantined cell is parked, not due — that is the
                # point.  Likewise every cell of a suspended document.
                "due": (not quarantined and not suspended
                        and (lag is None or lag > policy.target_lag)),
                "refresh_count": int(st.get("refresh_count", 0)),
                "last_error": st.get("last_error"),
                "fail_streak": int(st.get("fail_streak", 0)),
                "quarantined": quarantined,
                "history": list(st.get("history", [])),
            })
        out["documents"][path] = {
            "target_lag": policy.target_lag,
            "triggers": list(policy.triggers),
            "last_tick": doc_st.get("last_tick"),
            "suspended": suspended,
            "quarantined": [c["key"] for c in cells if c["quarantined"]],
            "cells": cells,
            "consumers": {
                key: {
                    "last_run": doc_st.get("consumers", {}).get(key, {}).get("last_run"),
                    "run_count": int(doc_st.get("consumers", {})
                                     .get(key, {}).get("run_count", 0)),
                }
                for key, _, _ in doc.consumers
            },
        }
    return out


def render_status(status: Dict[str, Any]) -> str:
    """Human view of :func:`daemon_status` (one line per cell)."""
    lines = [f"daemon state: {status['state_path']} "
             f"(ticks={status['ticks']}, queue_depth={status['queue_depth']})"]
    hosts = status.get("workers", {}).get("hosts", {})
    for host in sorted(hosts):
        h = hosts[host]
        lines.append(f"  host {host:<30} workers={h['workers']} "
                     f"alive={h['alive']}")
    counters = status.get("retry_counters", {})
    for site in sorted(counters):
        c = counters[site]
        if c.get("retries") or c.get("exhausted"):
            lines.append(f"  retries {site:<27} calls={c['calls']} "
                         f"retried={c['retries']} exhausted={c['exhausted']}")
    for path, doc in status["documents"].items():
        lines.append(f"\n{path}  target_lag={doc['target_lag']:.0f}s "
                     f"triggers={','.join(doc['triggers'])}")
        if doc.get("suspended"):
            since = doc["suspended"].get("since")
            when = f" since {time.strftime('%H:%M:%S', time.localtime(since))}" \
                if since else ""
            lines.append(f"  SUSPENDED{when} — skipped by staleness scans "
                         f"(resume with --resume)")
        for c in doc["cells"]:
            lag = "never" if c["lag_s"] is None else f"{c['lag_s']:.1f}s"
            if c.get("quarantined"):
                q = c["quarantined"]
                lines.append(f"  {c['cell']:<44} QUARANTINED "
                             f"(streak={q.get('fail_streak', '?')}): "
                             f"{q.get('reason', '')}")
                for h in c.get("history", []):
                    lines.append(f"      attempt@{h.get('ts', 0):.0f}: "
                                 f"{str(h.get('error', '')).splitlines()[0][:100]}")
                continue
            due = "DUE" if c["due"] else "fresh"
            lines.append(f"  {c['cell']:<44} lag={lag:<10} {due:<6} "
                         f"refreshes={c['refresh_count']}")
        for key, c in doc["consumers"].items():
            lines.append(f"  [consumer] {key:<33} runs={c['run_count']}")
    return "\n".join(lines)
