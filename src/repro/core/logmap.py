"""The paper's own example application (§II-A): ``logmap``.

    "a simple application called logmap, which computes the logistic map
     function for a vector of input values ... a synthetic benchmark with
     multiple use cases through varying the computational intensity and
     the workload"

Faithful port: x_{n+1} = r·x_n·(1−x_n) iterated ``intensity``-many sweeps
over a ``workload``-sized vector (jitted; ``lax.fori_loop``).  The paper's
variant tags map to parameter presets (``large-intensity``,
``large-workload``, ...), and ``LogmapHarness`` emits the paper's two output
files as protocol metrics: runtime (``logmap.out``) and per-kernel stats
(``logmap.stats``).  Demonstrates onboarding a NON-LLM benchmark repository
into the same collection — the decentralized-collection point of Fig. 2 ②.
"""

from __future__ import annotations

import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol
from repro.core.harness import BenchmarkSpec, Harness, Injections, artifact_digest

# The paper's CLI: logmap --workload 6 --intensity 2.4
# workload is a size exponent (10^w elements scaled down for CPU), intensity
# a sweep multiplier.
VARIANTS: Dict[str, Dict[str, float]] = {
    "small": {"workload": 4, "intensity": 0.8},
    "large-intensity": {"workload": 4, "intensity": 2.4},
    "large-workload": {"workload": 6, "intensity": 0.8},
    "large-intensity.large-workload": {"workload": 6, "intensity": 2.4},
}

R = 3.741  # chaotic-regime logistic parameter


def logmap_kernel(x0: jax.Array, n_iters: int) -> jax.Array:
    def body(_, x):
        return R * x * (1.0 - x)

    return jax.lax.fori_loop(0, n_iters, body, x0)


def run_logmap(workload: float, intensity: float, *, seed: int = 0,
               base_iters: int = 50) -> Dict[str, float]:
    n = int(10 ** workload)
    iters = max(1, int(base_iters * intensity))
    rng = np.random.default_rng(seed)
    x0 = jnp.asarray(rng.uniform(0.1, 0.9, n), jnp.float32)
    fn = jax.jit(logmap_kernel, static_argnums=1)
    out = jax.block_until_ready(fn(x0, iters))  # compile+warm
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(x0, iters))
    dt = time.perf_counter() - t0
    flops = 3.0 * n * iters
    return {
        "kernel_time_s": dt,                      # logmap.stats
        "elements": float(n),
        "iterations": float(iters),
        "gflops_per_s": flops / dt / 1e9,
        "checksum": float(jnp.sum(out)),
        "_digest": artifact_digest(out),
    }


class LogmapHarness(Harness):
    """Harness adapter for the logmap benchmark repository."""

    name = "logmap"

    def run(self, spec: BenchmarkSpec, injections: Optional[Injections] = None) -> protocol.Report:
        inj = injections or Injections()
        variant = spec.effective_variant()
        preset = dict(VARIANTS.get(variant, VARIANTS["small"]))
        # Feature injection can override the paper's CLI parameters.
        for k in ("workload", "intensity"):
            if k in inj.overrides:
                preset[k] = float(inj.overrides[k])
        t0 = time.perf_counter()
        stats = run_logmap(preset["workload"], preset["intensity"], seed=spec.seed)
        runtime = time.perf_counter() - t0
        digest = stats.pop("_digest")
        report = protocol.new_report(
            system=spec.system,
            variant=variant,
            usecase="logmap",
            parameter={"arch": "logmap", **preset, "injections": inj.describe()},
        )
        report.data.append(protocol.DataEntry(
            success=bool(np.isfinite(stats["checksum"])),
            runtime=runtime,
            queue="cpu",
            job_id=f"logmap-{spec.seed}",
            metrics={
                **stats,
                "step_time_s": stats["kernel_time_s"],
                # Roofline instrumentation (INSTRUMENTED level): elementwise
                # kernel — 3 flops and 8 bytes per element-iteration.
                "hlo_flops": 3.0 * stats["elements"] * stats["iterations"],
                "hlo_bytes": 8.0 * stats["elements"] * stats["iterations"],
                "collective_bytes": 0.0,
                "t_compute": 0.0,
                "t_memory": 0.0,
                "t_collective": 0.0,
                "artifact_digest": digest,
                "seed": spec.seed,
            },
        ))
        return report
