"""Benchmark harness adapters (the paper's JUBE/ReFrame/Ramble slot, §IV-D).

exaCB never executes workloads itself — it orchestrates and delegates to a
harness that conforms to the protocol.  Two adapters are provided:

* ``ExecHarness``  — actually runs the (reduced-scale) workload on the local
  devices and measures wall time; fills deterministic artifact digests so a
  benchmark can reach the REPRODUCIBLE readiness level.
* ``DryRunHarness`` (see ``repro.core.dryrun_harness``) — lowers + compiles
  the full-scale cell for a production mesh and reports roofline terms; this
  is the "system-scale" harness used by the JUREAP-style studies.

A harness receives a ``BenchmarkSpec`` (the cell) plus optional
``Injections`` (feature-injection orchestrator, §V-A3) and returns a
protocol ``Report``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, FrozenSet, List, Optional

import numpy as np

from repro.core import protocol
from repro.core.readiness import Readiness


@dataclasses.dataclass(frozen=True)
class BenchmarkSpec:
    """One benchmark cell: architecture × input shape × system.

    ``require_readiness`` is the cell's demand on the harness (as a
    ``Readiness`` level): a cell requiring REPRODUCIBLE negotiates against
    the harness capability declaration *before* dispatch and fails fast on
    a harness that cannot attain it (see :func:`negotiate`).  0 (FAILED)
    means no requirement — the seed behavior.
    """

    arch: str
    shape: str          # the paper's "usecase"
    system: str         # the paper's "machine"
    variant: str = ""   # defaults to shape
    seed: int = 0
    require_readiness: int = 0

    @property
    def cell(self) -> str:
        return f"{self.arch}.{self.shape}.{self.system}"

    def effective_variant(self) -> str:
        return self.variant or self.shape


@dataclasses.dataclass
class Injections:
    """Framework-level workload augmentation without touching the benchmark
    definition (paper §V-A3, Figs. 6/8)."""

    env: Dict[str, str] = dataclasses.field(default_factory=dict)
    # Wraps the step callable: launcher(step_fn) -> step_fn  (jpwr analogue).
    launcher: Optional[Callable[[Callable], Callable]] = None
    # Config knob overrides (remat policy, microbatches, sharding strategy...).
    overrides: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def describe(self) -> Dict[str, Any]:
        return {
            "env": dict(self.env),
            "launcher": getattr(self.launcher, "__name__", None) if self.launcher else None,
            "overrides": dict(self.overrides),
        }


# os.environ is process-global, so concurrent scheduler workers cannot each
# get a private view.  Two mechanisms make injection safe under the pool:
#
# 1. A per-KEY reentrant lock held for the frame's whole lifetime: workers
#    injecting *distinct* keys (a multi-knob campaign) run fully in
#    parallel, while two cells injecting the SAME key (an env-knob sweep)
#    serialize against each other — each cell really executes under its own
#    value instead of the last entrant's.  Keys are acquired in sorted order
#    to prevent deadlock; RLocks keep same-thread nesting legal.
# 2. A process-wide registry of active frames guarded by ``_ENV_LOCK`` so
#    exits restore the youngest surviving frame's value (same-thread
#    nesting) or the pre-injection original.
#
# PROCESS-WORKER CAVEAT: all of this state is *per interpreter*.  Under the
# ``spawn`` start method a worker process begins with a fresh module — no
# locks, no frames, no saved originals, and (unlike ``fork``) not even the
# parent's merged os.environ mutations.  Injection frames therefore must be
# re-applied INSIDE the worker: the execution plane ships env frames as
# payload/config data and the worker bootstrap re-enters ``injected_env``
# before running cells (see ``repro.core.workers.worker_main``).  A parent
# holding an active frame while spawning workers injects nothing into them.
_ENV_LOCK = threading.RLock()
_ENV_FRAMES: List[Dict[str, str]] = []
_ENV_SAVED: Dict[str, Optional[str]] = {}
_ENV_KEY_LOCKS: Dict[str, threading.RLock] = {}


def _key_locks(keys) -> List[threading.RLock]:
    with _ENV_LOCK:
        return [_ENV_KEY_LOCKS.setdefault(k, threading.RLock()) for k in sorted(keys)]


def _restore_env_key(k: str) -> None:
    """Re-apply the youngest surviving frame's value for ``k``, or the saved
    pre-injection original.  Caller holds ``_ENV_LOCK``."""
    survivor = next((f for f in reversed(_ENV_FRAMES) if k in f), None)
    if survivor is not None:
        os.environ[k] = survivor[k]
        return
    original = _ENV_SAVED.pop(k)
    if original is None:
        os.environ.pop(k, None)
    else:
        os.environ[k] = original


@contextmanager
def injected_env(env: Dict[str, str]):
    # Coerce up front: env values are strings by contract, but YAML-parsed
    # inputs can arrive as ints/bools and os.environ would reject them
    # halfway through the apply loop.
    frame = {str(k): str(v) for k, v in env.items()}
    key_locks = _key_locks(frame)
    for lk in key_locks:
        lk.acquire()
    try:
        with _ENV_LOCK:
            applied = []
            try:
                for k, v in frame.items():
                    if k not in _ENV_SAVED:
                        _ENV_SAVED[k] = os.environ.get(k)
                    os.environ[k] = v
                    applied.append(k)
                _ENV_FRAMES.append(frame)
            except BaseException:
                # Partial application must not leak: roll back what landed.
                for k in applied:
                    _restore_env_key(k)
                raise
        try:
            yield
        finally:
            with _ENV_LOCK:
                _ENV_FRAMES.remove(frame)
                for k in frame:
                    _restore_env_key(k)
    finally:
        for lk in reversed(key_locks):
            lk.release()


@dataclasses.dataclass(frozen=True)
class HarnessCapabilities:
    """What a harness declares it can do — the downward half of the typed
    component contract.  ``BenchmarkSpec`` requirements negotiate against
    this *before* dispatch, so a cell demanding REPRODUCIBLE fails fast on
    a harness that cannot attain it instead of burning an execution slot
    and reporting a mystery gap afterwards.
    """

    max_readiness: Readiness = Readiness.REPRODUCIBLE
    #: Step kinds the harness can execute; empty = unrestricted.
    step_kinds: FrozenSet[str] = frozenset()
    env_injection: bool = True
    override_injection: bool = True
    launcher_injection: bool = True

    def describe(self) -> Dict[str, Any]:
        return {
            "max_readiness": self.max_readiness.name,
            "step_kinds": sorted(self.step_kinds) or "any",
            "env_injection": self.env_injection,
            "override_injection": self.override_injection,
            "launcher_injection": self.launcher_injection,
        }


class CapabilityError(ValueError):
    """A cell's requirements exceed the harness's declared capabilities."""


def _shape_kind(shape: str) -> Optional[str]:
    """Step kind of a named shape (lazy import — harness adapters must stay
    importable without the benchmark collection)."""
    try:
        from repro.configs import shapes as SH
        return getattr(SH.SHAPES.get(shape), "kind", None)
    except Exception:
        return None


def negotiate(spec: BenchmarkSpec, harness: "Harness",
              injections: Optional[Injections] = None) -> HarnessCapabilities:
    """Check one cell (+ its injections) against the harness capability
    declaration; raises :class:`CapabilityError` naming every violated
    capability, returns the capabilities when the cell is dispatchable."""
    caps = harness.capabilities()
    reasons: List[str] = []
    if spec.require_readiness > int(caps.max_readiness):
        reasons.append(
            f"cell requires readiness {Readiness(spec.require_readiness).name} "
            f"but harness attains at most {caps.max_readiness.name}")
    kind = _shape_kind(spec.shape)
    if caps.step_kinds and kind is not None and kind not in caps.step_kinds:
        reasons.append(
            f"shape {spec.shape!r} needs step kind {kind!r} "
            f"(harness supports {sorted(caps.step_kinds)})")
    if injections is not None:
        if injections.env and not caps.env_injection:
            reasons.append("env injection not supported")
        if injections.overrides and not caps.override_injection:
            reasons.append("config-override injection not supported")
        if injections.launcher is not None and not caps.launcher_injection:
            reasons.append("launcher injection not supported")
    if reasons:
        raise CapabilityError(
            f"harness {harness.name!r} cannot run cell {spec.cell}: "
            + "; ".join(reasons))
    return caps


class Harness:
    """Adapter interface: everything exaCB needs from a harness."""

    name = "abstract"

    def capabilities(self) -> HarnessCapabilities:
        """Capability declaration; the permissive default keeps third-party
        adapters working, but real adapters should narrow it honestly."""
        return HarnessCapabilities()

    def run(self, spec: BenchmarkSpec, injections: Optional[Injections] = None) -> protocol.Report:
        raise NotImplementedError

    def spawn_spec(self) -> "tuple[str, Dict[str, Any]]":
        """Spawn-safe construction recipe: ``("module:factory", kwargs)``.

        Process workers never receive harness *objects* — a spawned
        interpreter rebuilds the harness from this recipe (dotted-path
        factory + plain-data kwargs), which is what makes cell dispatch
        picklable data instead of closures.  Adapters that cannot be
        reconstructed from plain data stay thread-mode only.
        """
        raise NotImplementedError(
            f"harness {self.name!r} declares no spawn_spec(): it cannot run "
            "under process workers (worker_mode: process); use thread mode "
            "or implement spawn_spec()")


def artifact_digest(*arrays) -> str:
    """Deterministic digest of output artifacts (REPRODUCIBLE level)."""
    h = hashlib.sha256()
    for a in arrays:
        h.update(np.asarray(a).tobytes())
    return h.hexdigest()[:16]


class ExecHarness(Harness):
    """Runs the reduced-scale cell for real on local devices.

    Smoke-scale analogue of a JUBE run: builds the model, executes the step
    kind the shape dictates, measures wall time, and reports protocol-
    compliant metrics including artifact digests.
    """

    name = "exec"

    def capabilities(self) -> HarnessCapabilities:
        # Real execution with deterministic artifact digests: every level up
        # to REPRODUCIBLE, all three step kinds, every injection mechanism.
        return HarnessCapabilities(
            max_readiness=Readiness.REPRODUCIBLE,
            step_kinds=frozenset({"train", "prefill", "decode"}),
        )

    def __init__(self, *, steps: int = 3, batch: int = 2, seq: int = 16):
        self.steps = steps
        self.batch = batch
        self.seq = seq

    def spawn_spec(self):
        return "repro.core.harness:ExecHarness", {
            "steps": self.steps, "batch": self.batch, "seq": self.seq}

    def run(self, spec: BenchmarkSpec, injections: Optional[Injections] = None) -> protocol.Report:
        import jax
        import jax.numpy as jnp

        from repro import configs
        from repro.configs import shapes as SH
        from repro.models import params as P
        from repro.models import transformer as T

        inj = injections or Injections()
        report = protocol.new_report(
            system=spec.system,
            variant=spec.effective_variant(),
            usecase=spec.shape,
            software_version=jax.__version__,
            parameter={"arch": spec.arch, "injections": inj.describe(), "scale": "smoke"},
        )
        cfg = configs.get_smoke(spec.arch)
        for k, v in inj.overrides.items():
            if hasattr(cfg, k):
                cfg = dataclasses.replace(cfg, **{k: v})
        remat = str(inj.overrides.get("remat", "none"))
        shape = SH.SHAPES[spec.shape]
        kind = shape.kind

        with injected_env(inj.env):
            t_build = time.perf_counter()
            params = P.init_params(cfg, jax.random.key(spec.seed))
            B, S = self.batch, self.seq
            batch = _smoke_batch(cfg, kind, B, S, spec.seed)

            if kind == SH.TRAIN:
                # Full fwd+bwd so remat/microbatch injections have real effect.
                def step(p, b):
                    loss, grads = jax.value_and_grad(
                        lambda pp: T.train_loss(pp, cfg, b, remat=remat)[0]
                    )(p)
                    return loss + 0.0 * grads["final_norm"]["scale"].sum()
            elif kind == SH.PREFILL:
                def step(p, b):
                    logits, _ = T.prefill(p, cfg, b, max_len=cfg.prefix_len + S, remat=remat)
                    return logits
            else:  # decode
                state0 = T.init_decode_state(cfg, B, cfg.prefix_len + S)

                def step(p, b):
                    logits, _ = T.decode_step(p, cfg, state0, b, jnp.asarray(0, jnp.int32))
                    return logits

            if inj.launcher is not None:
                step = inj.launcher(step)

            fn = jax.jit(step)
            out = jax.block_until_ready(fn(params, batch))
            times = []
            for _ in range(self.steps):
                t0 = time.perf_counter()
                out = jax.block_until_ready(fn(params, batch))
                times.append(time.perf_counter() - t0)
            runtime = time.perf_counter() - t_build

        cost = _cost_analysis(fn, params, batch)
        launcher_metrics = getattr(step, "exacb_metrics", None) or {}
        entry = protocol.DataEntry(
            success=bool(np.all(np.isfinite(np.asarray(out, dtype=np.float32)))),
            runtime=runtime,
            nodes=1,
            tasks_per_node=jax.device_count(),
            job_id=f"local-{os.getpid()}",
            queue="cpu",
            metrics={
                "step_time_s": float(np.median(times)),
                "step_time_min_s": float(np.min(times)),
                "hlo_flops": cost.get("flops", 0.0),
                "hlo_bytes": cost.get("bytes accessed", 0.0),
                "collective_bytes": 0.0,  # single local device
                "t_compute": 0.0,
                "t_memory": 0.0,
                "t_collective": 0.0,
                "artifact_digest": artifact_digest(out),
                "seed": spec.seed,
                **launcher_metrics,
            },
        )
        report.data.append(entry)
        return report


def _smoke_batch(cfg, kind, B, S, seed):
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    out: Dict[str, Any] = {}
    if kind == "decode":
        if cfg.input_mode == "embeddings":
            out["embeds"] = jnp.asarray(rng.standard_normal((B, 1, cfg.d_model)), dtype=cfg.dtype)
        else:
            out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), dtype=jnp.int32)
        return out
    if cfg.input_mode == "embeddings":
        out["embeds"] = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), dtype=cfg.dtype)
    else:
        if cfg.prefix_len:
            out["prefix_embeds"] = jnp.asarray(
                rng.standard_normal((B, cfg.prefix_len, cfg.d_model)), dtype=cfg.dtype
            )
        out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), dtype=jnp.int32)
    if kind == "train":
        if cfg.n_codebooks > 1:
            out["targets"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, cfg.n_codebooks, S)), dtype=jnp.int32
            )
        else:
            out["targets"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), dtype=jnp.int32)
    return out


def _cost_analysis(jitted, *args) -> Dict[str, float]:
    try:
        compiled = jitted.lower(*args).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):  # older jax returns one dict per device
            ca = ca[0] if ca else {}
        return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}
    except Exception:
        return {}
