"""Concurrent campaign scheduler — the execution core behind collection-scale
continuous benchmarking.

The paper's central claim is that exaCB scales CB to *collections* (JUREAP:
70+ applications).  Running a collection's cells serially makes wall-clock
linear in collection size; this module provides the bounded worker pool the
orchestrators and the CI/CD layer dispatch through instead:

* **Per-cell failure isolation is preserved** — a task body that raises is
  captured into its ``TaskResult``; sibling tasks keep running and dependent
  tasks still execute (post-processing analyses the *surviving* results, the
  paper's resilience requirement).
* **Dependency-aware ordering** — tasks declare the keys they consume;
  a task starts as soon as (and only when) all of its dependencies have
  finished.  Independent executions run in parallel; a post-processing
  component waits only on the execution components whose prefixes it reads.
* **Streaming results** — ``on_result`` fires from the coordinating thread
  the moment each task completes (persistence itself happens inside
  ``ExecutionOrchestrator.run_cell``, which appends to the store before the
  collection finishes, so a later failure cannot lose earlier cells).
"""

from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import threading
import time
import traceback
from collections import defaultdict, deque
from typing import Any, Callable, Dict, FrozenSet, List, Optional, Sequence


class SchedulerError(ValueError):
    pass


@dataclasses.dataclass(frozen=True)
class Task:
    """One schedulable unit: a thunk plus the task keys it depends on.

    ``meta`` is opaque caller context carried through to the ``TaskResult``
    — the CI/CD layer stamps the resolved component reference
    (``execution@v3``) so failure summaries name the component, not just
    the task key."""

    key: str
    fn: Callable[[], Any]
    deps: FrozenSet[str] = frozenset()
    meta: Any = None


@dataclasses.dataclass
class TaskResult:
    key: str
    value: Any = None
    error: Optional[str] = None
    seconds: float = 0.0
    worker: str = ""
    meta: Any = None

    @property
    def ok(self) -> bool:
        return self.error is None


class CampaignScheduler:
    """Bounded worker pool with dependency-aware dispatch."""

    def __init__(self, *, parallelism: int = 4, name: str = "campaign"):
        self.parallelism = max(1, int(parallelism))
        self.name = name

    # ------------------------------------------------------------------ core
    def run_tasks(
        self,
        tasks: Sequence[Task],
        *,
        on_result: Optional[Callable[[TaskResult], None]] = None,
    ) -> Dict[str, TaskResult]:
        """Run a task DAG; returns ``{key: TaskResult}`` for every task.

        Raises ``SchedulerError`` on duplicate keys, unknown dependencies, or
        dependency cycles — structural errors are the caller's bug, unlike
        task-body failures, which are isolated into results.
        """
        tasks = list(tasks)
        by_key: Dict[str, Task] = {}
        for t in tasks:
            if t.key in by_key:
                raise SchedulerError(f"duplicate task key {t.key!r}")
            by_key[t.key] = t
        for t in tasks:
            for d in t.deps:
                if d not in by_key:
                    raise SchedulerError(f"task {t.key!r} depends on unknown {d!r}")
        indegree = {t.key: len(t.deps) for t in tasks}
        dependents: Dict[str, List[str]] = defaultdict(list)
        for t in tasks:
            for d in t.deps:
                dependents[d].append(t.key)

        # Kahn pre-pass: reject cyclic DAGs BEFORE any task body runs.
        # Detecting the cycle only after the pool drains would execute the
        # acyclic portion of a structurally-broken campaign — side effects
        # (store appends) from a document the caller then learns was invalid.
        remaining = dict(indegree)
        peel = deque(k for k, deg in remaining.items() if deg == 0)
        seen = 0
        while peel:
            key = peel.popleft()
            seen += 1
            for dep_key in dependents[key]:
                remaining[dep_key] -= 1
                if remaining[dep_key] == 0:
                    peel.append(dep_key)
        if seen != len(tasks):
            stuck = sorted(k for k, deg in remaining.items() if deg > 0)
            raise SchedulerError(f"dependency cycle among tasks: {stuck}")

        done: Dict[str, TaskResult] = {}
        ready = deque(t.key for t in tasks if indegree[t.key] == 0)
        with cf.ThreadPoolExecutor(
            max_workers=self.parallelism, thread_name_prefix=self.name
        ) as pool:
            futures: Dict[cf.Future, str] = {}
            while ready or futures:
                while ready:
                    key = ready.popleft()
                    futures[pool.submit(self._run_one, by_key[key])] = key
                finished, _ = cf.wait(futures, return_when=cf.FIRST_COMPLETED)
                for fut in finished:
                    key = futures.pop(fut)
                    result = fut.result()  # _run_one never raises
                    done[key] = result
                    if on_result is not None:
                        on_result(result)
                    # A failed dependency still *completed* — dependents run
                    # against whatever survived (failure isolation).
                    for dep_key in dependents[key]:
                        indegree[dep_key] -= 1
                        if indegree[dep_key] == 0:
                            ready.append(dep_key)
        return done

    @staticmethod
    def _run_one(task: Task) -> TaskResult:
        t0 = time.perf_counter()
        try:
            value = task.fn()
            return TaskResult(
                task.key,
                value=value,
                seconds=time.perf_counter() - t0,
                worker=threading.current_thread().name,
                meta=task.meta,
            )
        except Exception as e:  # noqa: BLE001 — isolation is the point
            return TaskResult(
                task.key,
                error=f"{type(e).__name__}: {e}\n{traceback.format_exc(limit=3)}",
                seconds=time.perf_counter() - t0,
                worker=threading.current_thread().name,
                meta=task.meta,
            )

    # ----------------------------------------------------------- convenience
    def map_items(
        self,
        fn: Callable[[Any], Any],
        items: Sequence[Any],
        *,
        metas: Optional[Sequence[Any]] = None,
        on_result: Optional[Callable[[TaskResult], None]] = None,
    ) -> List[TaskResult]:
        """Run ``fn`` over independent items; results in input order.

        ``metas`` (aligned with ``items``; defaults to the items themselves)
        is carried through to each ``TaskResult.meta`` so streaming
        ``on_result`` consumers can identify which item a result belongs to
        without parsing task keys.
        """
        items = list(items)
        if metas is None:
            meta_list: List[Any] = items
        else:
            meta_list = list(metas)
            if len(meta_list) != len(items):
                raise SchedulerError(
                    f"metas length {len(meta_list)} != items length {len(items)}")
        tasks = [
            Task(key=f"item-{i:05d}", fn=(lambda it=item: fn(it)), meta=meta)
            for i, (item, meta) in enumerate(zip(items, meta_list))
        ]
        done = self.run_tasks(tasks, on_result=on_result)
        return [done[f"item-{i:05d}"] for i in range(len(items))]
