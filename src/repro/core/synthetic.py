"""Synthetic harnesses for exercising the execution plane itself.

Real harnesses measure workloads; these measure *exaCB* — they are the
instruments behind ``benchmarks/bench_workers.py`` and the worker-plane
tests, deliberately free of jax so a spawned worker interpreter boots in
milliseconds:

* :class:`SpinHarness` — a CPU-bound, pure-Python, fixed-iteration integer
  mix.  Pure Python means the GIL serializes it under the thread pool while
  process workers run it truly in parallel: exactly the workload the
  broker architecture exists for.  Reports are deterministic functions of
  the cell (pinned timestamps, digest = f(seed, iters, cell)) so thread-
  and process-mode stores are byte-comparable modulo resource accounting.
* :class:`BlockingHarness` — writes a ``started.<cell>.<pid>`` sentinel and
  then blocks until a release file appears; the crash-reclaim tests SIGKILL
  the worker mid-cell (pid comes from the sentinel) and verify the lease
  protocol recovers.

Both are spawn-safe (:meth:`Harness.spawn_spec`) — construction state is a
plain kwargs dict, never a closure.
"""

from __future__ import annotations

import hashlib
import os
import time
from pathlib import Path
from typing import Optional

from repro.core.harness import BenchmarkSpec, Harness, Injections, injected_env
from repro.core.protocol import DataEntry, Report, new_report

#: Env var SpinHarness echoes into its metrics — lets tests prove an
#: injection frame was genuinely applied inside a spawned worker.
SPIN_ENV_KNOB = "EXACB_SPIN_ENV"


def _deterministic_report(spec: BenchmarkSpec, *, digest_salt: str) -> Report:
    """Protocol report fully determined by the cell: pinned timestamps and
    pipeline id so two runs of the same cell are byte-identical."""
    r = new_report(system=spec.system, variant=spec.effective_variant(),
                   usecase=spec.shape, pipeline_id="synthetic")
    r.experiment.timestamp = 1000.0
    r.reporter.timestamp = 1000.0
    digest = hashlib.sha256(
        f"{spec.cell}.{spec.seed}.{digest_salt}".encode()).hexdigest()[:16]
    metrics = {
        "step_time_s": 1.0 + (int(digest, 16) % 1000) / 1e4,
        "hlo_flops": 1.0, "hlo_bytes": 1.0, "collective_bytes": 0.0,
        "t_compute": 1.0, "t_memory": 1.0, "t_collective": 0.0,
        "artifact_digest": digest,
        "seed": spec.seed,
    }
    r.data.append(DataEntry(success=True, runtime=0.1, metrics=metrics))
    return r


class SpinHarness(Harness):
    """CPU-bound synthetic cell: ``iters`` rounds of pure-Python integer
    mixing seeded from the cell identity."""

    name = "spin"

    def __init__(self, *, iters: int = 200_000):
        self.iters = int(iters)

    def spawn_spec(self):
        return "repro.core.synthetic:SpinHarness", {"iters": self.iters}

    def run(self, spec: BenchmarkSpec, injections: Optional[Injections] = None) -> Report:
        inj = injections or Injections()
        with injected_env(inj.env):
            env_echo = os.environ.get(SPIN_ENV_KNOB, "")
            acc = (spec.seed * 2654435761 + len(spec.cell)) & 0xFFFFFFFF
            for i in range(self.iters):
                acc = (acc * 6364136223846793005 + i) & 0xFFFFFFFFFFFFFFFF
                acc ^= acc >> 33
        report = _deterministic_report(spec, digest_salt=f"spin.{self.iters}.{acc}")
        report.parameter["arch"] = spec.arch
        report.data[0].metrics["spin_result"] = float(acc % 10**9)
        if env_echo:
            report.data[0].metrics["spin_env_echo"] = float(env_echo)
        return report


class BlockingHarness(Harness):
    """Blocks inside ``run`` until ``<sentinel_dir>/release`` exists.

    The sentinel file name carries the executing pid so a test can SIGKILL
    the exact process that claimed the cell.  After the kill, the test
    creates the release file — the reclaimed retry then completes
    immediately.
    """

    name = "blocking"

    def __init__(self, *, sentinel_dir: str, timeout_s: float = 60.0):
        self.sentinel_dir = str(sentinel_dir)
        self.timeout_s = float(timeout_s)

    def spawn_spec(self):
        return "repro.core.synthetic:BlockingHarness", {
            "sentinel_dir": self.sentinel_dir, "timeout_s": self.timeout_s}

    def run(self, spec: BenchmarkSpec, injections: Optional[Injections] = None) -> Report:
        root = Path(self.sentinel_dir)
        root.mkdir(parents=True, exist_ok=True)
        (root / f"started.{spec.cell}.{os.getpid()}").write_text(str(time.time()))
        deadline = time.monotonic() + self.timeout_s
        while not (root / "release").exists():
            if time.monotonic() > deadline:
                raise RuntimeError(f"BlockingHarness timed out on {spec.cell}")
            time.sleep(0.02)
        return _deterministic_report(spec, digest_salt="blocking")
