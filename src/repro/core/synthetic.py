"""Synthetic harnesses for exercising the execution plane itself.

Real harnesses measure workloads; these measure *exaCB* — they are the
instruments behind ``benchmarks/bench_workers.py`` and the worker-plane
tests, deliberately free of jax so a spawned worker interpreter boots in
milliseconds:

* :class:`SpinHarness` — a CPU-bound, pure-Python, fixed-iteration integer
  mix.  Pure Python means the GIL serializes it under the thread pool while
  process workers run it truly in parallel: exactly the workload the
  broker architecture exists for.  Reports are deterministic functions of
  the cell (pinned timestamps, digest = f(seed, iters, cell)) so thread-
  and process-mode stores are byte-comparable modulo resource accounting.
* :class:`BlockingHarness` — writes a ``started.<cell>.<pid>`` sentinel and
  then blocks until a release file appears; the crash-reclaim tests SIGKILL
  the worker mid-cell (pid comes from the sentinel) and verify the lease
  protocol recovers.  ``block_calls`` narrows the trap to one specific
  invocation so duet tests can kill a worker *between* rounds.
* :class:`DuetNoiseHarness` — a noisy-environment model: each duet round
  draws one multiplicative jitter shared by both roles of the pair (the
  two invocations of a round are consecutive calls on one worker), so the
  absolute metric series is noisy while per-round deltas are clean.  The
  candidate-side slowdown is injected through ``EXACB_DUET_SLOWDOWN`` —
  this is the harness behind the duet-gate discrimination tests and the
  ``duet`` CI job.

Both are spawn-safe (:meth:`Harness.spawn_spec`) — construction state is a
plain kwargs dict, never a closure.
"""

from __future__ import annotations

import hashlib
import os
import time
from pathlib import Path
from typing import Optional

from repro.core.harness import BenchmarkSpec, Harness, Injections, injected_env
from repro.core.protocol import DataEntry, Report, new_report

#: Env var SpinHarness echoes into its metrics — lets tests prove an
#: injection frame was genuinely applied inside a spawned worker.
SPIN_ENV_KNOB = "EXACB_SPIN_ENV"

#: Env var DuetNoiseHarness reads as a multiplicative slowdown — duet tests
#: inject it on the candidate role only to model a real regression.
DUET_SLOWDOWN_KNOB = "EXACB_DUET_SLOWDOWN"


def _deterministic_report(spec: BenchmarkSpec, *, digest_salt: str) -> Report:
    """Protocol report fully determined by the cell: pinned timestamps and
    pipeline id so two runs of the same cell are byte-identical."""
    r = new_report(system=spec.system, variant=spec.effective_variant(),
                   usecase=spec.shape, pipeline_id="synthetic")
    r.experiment.timestamp = 1000.0
    r.reporter.timestamp = 1000.0
    digest = hashlib.sha256(
        f"{spec.cell}.{spec.seed}.{digest_salt}".encode()).hexdigest()[:16]
    metrics = {
        "step_time_s": 1.0 + (int(digest, 16) % 1000) / 1e4,
        "hlo_flops": 1.0, "hlo_bytes": 1.0, "collective_bytes": 0.0,
        "t_compute": 1.0, "t_memory": 1.0, "t_collective": 0.0,
        "artifact_digest": digest,
        "seed": spec.seed,
    }
    r.data.append(DataEntry(success=True, runtime=0.1, metrics=metrics))
    return r


class SpinHarness(Harness):
    """CPU-bound synthetic cell: ``iters`` rounds of pure-Python integer
    mixing seeded from the cell identity."""

    name = "spin"

    def __init__(self, *, iters: int = 200_000):
        self.iters = int(iters)

    def spawn_spec(self):
        return "repro.core.synthetic:SpinHarness", {"iters": self.iters}

    def run(self, spec: BenchmarkSpec, injections: Optional[Injections] = None) -> Report:
        inj = injections or Injections()
        with injected_env(inj.env):
            env_echo = os.environ.get(SPIN_ENV_KNOB, "")
            acc = (spec.seed * 2654435761 + len(spec.cell)) & 0xFFFFFFFF
            for i in range(self.iters):
                acc = (acc * 6364136223846793005 + i) & 0xFFFFFFFFFFFFFFFF
                acc ^= acc >> 33
        report = _deterministic_report(spec, digest_salt=f"spin.{self.iters}.{acc}")
        report.parameter["arch"] = spec.arch
        report.data[0].metrics["spin_result"] = float(acc % 10**9)
        if env_echo:
            report.data[0].metrics["spin_env_echo"] = float(env_echo)
        return report


class BlockingHarness(Harness):
    """Blocks inside ``run`` until ``<sentinel_dir>/release`` exists.

    The sentinel file name carries the executing pid so a test can SIGKILL
    the exact process that claimed the cell.  After the kill, the test
    creates the release file — the reclaimed retry then completes
    immediately.
    """

    name = "blocking"

    def __init__(self, *, sentinel_dir: str, timeout_s: float = 60.0,
                 block_calls: Optional[int] = None):
        self.sentinel_dir = str(sentinel_dir)
        self.timeout_s = float(timeout_s)
        # None: every call blocks (the original single-cell trap).  An int
        # blocks only that 0-based call *of this process* — a duet test sets
        # 2 to let round 0's pair persist, then traps round 1's baseline.
        # The counter is per-interpreter, so a reclaimed retry (fresh spawn)
        # starts at call 0 and sails past the trap.
        self.block_calls = block_calls if block_calls is None else int(block_calls)
        self._calls = 0

    def spawn_spec(self):
        return "repro.core.synthetic:BlockingHarness", {
            "sentinel_dir": self.sentinel_dir, "timeout_s": self.timeout_s,
            "block_calls": self.block_calls}

    def run(self, spec: BenchmarkSpec, injections: Optional[Injections] = None) -> Report:
        call = self._calls
        self._calls += 1
        if self.block_calls is not None and call != self.block_calls:
            return _deterministic_report(spec, digest_salt="blocking")
        root = Path(self.sentinel_dir)
        root.mkdir(parents=True, exist_ok=True)
        # The sentinel is written only by the blocking call, so a test that
        # waits for it knows every earlier call has already persisted.
        (root / f"started.{spec.cell}.{os.getpid()}").write_text(str(time.time()))
        deadline = time.monotonic() + self.timeout_s
        while not (root / "release").exists():
            if time.monotonic() > deadline:
                raise RuntimeError(f"BlockingHarness timed out on {spec.cell}")
            time.sleep(0.02)
        return _deterministic_report(spec, digest_salt="blocking")


class DuetNoiseHarness(Harness):
    """Noisy-environment model for duet-gate discrimination tests.

    Every duet round draws one multiplicative jitter from a hash of
    ``(seed, round)`` — and because the two roles of a round execute as
    consecutive calls on one worker, both sides of a pair see the *same*
    jitter, exactly like frequency scaling or a noisy neighbor hitting a
    real interleaved pair.  The absolute ``step_time_s`` series therefore
    swings by up to ``noise`` between rounds (enough to fool an absolute
    gate at tight tolerance) while per-round candidate−baseline deltas
    stay clean.  A genuine regression is modeled by injecting
    ``EXACB_DUET_SLOWDOWN`` on the candidate role only.
    """

    name = "duet-noise"

    def __init__(self, *, base_s: float = 1.0, noise: float = 0.5,
                 seed: int = 0, pair_calls: int = 2):
        self.base_s = float(base_s)
        self.noise = float(noise)
        self.seed = int(seed)
        # Calls per round (baseline + candidate); the per-process call
        # counter divided by this yields the shared-jitter round index.
        self.pair_calls = max(1, int(pair_calls))
        self._calls = 0

    def spawn_spec(self):
        return "repro.core.synthetic:DuetNoiseHarness", {
            "base_s": self.base_s, "noise": self.noise,
            "seed": self.seed, "pair_calls": self.pair_calls}

    def run(self, spec: BenchmarkSpec, injections: Optional[Injections] = None) -> Report:
        inj = injections or Injections()
        with injected_env(inj.env):
            slowdown = float(os.environ.get(DUET_SLOWDOWN_KNOB, "1.0"))
        round_idx = self._calls // self.pair_calls
        self._calls += 1
        h = int(hashlib.sha256(
            f"{self.seed}.{round_idx}".encode()).hexdigest()[:8], 16)
        jitter = 1.0 + self.noise * (h / 0xFFFFFFFF)
        report = _deterministic_report(spec, digest_salt=f"duet.{round_idx}")
        report.data[0].metrics["step_time_s"] = self.base_s * jitter * slowdown
        report.data[0].metrics["duet_jitter"] = jitter
        return report
