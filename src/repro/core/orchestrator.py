"""The three exaCB orchestrators (paper §V-A).

exaCB deliberately avoids a monolithic orchestrator: execution, feature
injection and post-processing are independent so partial infrastructure
failures never lose results, and analyses re-run without re-executing
benchmarks.  Each orchestrator is configured with a declarative ``inputs``
dict mirroring the paper's GitLab CI/CD ``component:/inputs:`` blocks, e.g.::

    ExecutionOrchestrator(inputs={
        "prefix":  "jureca.single",
        "usecase": "train_4k",         # shape
        "variant": "single",
        "machine": "v5e-pod-16x16",
        "record":  True,
    }, harness=..., store=...)
"""

from __future__ import annotations

import dataclasses
import time
import traceback
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import analysis
from repro.core.columnar import CampaignFrame
from repro.core.harness import BenchmarkSpec, Harness, Injections
from repro.core.protocol import DataEntry, Report, new_report
from repro.core.readiness import Readiness, classify
from repro.core.regression import RegressionGate
from repro.core.scheduler import CampaignScheduler, TaskResult
from repro.core.store import ResultStore


@dataclasses.dataclass
class CellResult:
    spec: BenchmarkSpec
    report: Optional[Report]
    readiness: Readiness
    error: Optional[str] = None
    attempts: int = 1


def _unwrap_cells(specs: Sequence[BenchmarkSpec], results: Sequence[TaskResult]) -> List[CellResult]:
    """Scheduler results back to CellResults.  ``run_cell`` already isolates
    harness failures, so a task-level error only appears if the orchestrator
    machinery itself crashed — still reported, never raised."""
    out: List[CellResult] = []
    for spec, tr in zip(specs, results):
        if tr.error is not None:
            out.append(CellResult(spec, None, Readiness.FAILED, error=tr.error))
        else:
            out.append(tr.value)
    return out


class ExecutionOrchestrator:
    """Runs benchmark cells through a harness with failure isolation
    (paper §V-A1)."""

    component = "execution@v3"

    def __init__(
        self,
        *,
        inputs: Dict[str, Any],
        harness: Harness,
        store: Optional[ResultStore] = None,
        fixture: Optional[Tuple[Callable[[], None], Callable[[], None]]] = None,
        max_retries: int = 1,
    ):
        self.inputs = dict(inputs)
        self.harness = harness
        self.store = store
        self.fixture = fixture
        self.max_retries = max_retries

    @property
    def prefix(self) -> str:
        return self.inputs.get("prefix", "default")

    def run_cell(self, spec: BenchmarkSpec, injections: Optional[Injections] = None) -> CellResult:
        setup, teardown = self.fixture or (None, None)
        last_err = None
        for attempt in range(1, self.max_retries + 1):
            try:
                if setup:
                    setup()
                try:
                    report = self.harness.run(spec, injections)
                finally:
                    if teardown:
                        teardown()
                # Orchestrator-side provenance: injections are recorded even
                # if the harness forgot to (protocol over trust).
                if injections is not None:
                    report.parameter["injections"] = injections.describe()
                level, gaps = classify(report)
                report.parameter.setdefault("readiness", int(level))
                report.parameter.setdefault("readiness_gaps", gaps)
                # Persist IMMEDIATELY — a later cell failing must not lose
                # this result (the paper's resilience requirement).
                if self.store is not None and self.inputs.get("record", True):
                    self.store.append(self.prefix, report)
                return CellResult(spec, report, level, attempts=attempt)
            except Exception as e:  # noqa: BLE001 — isolation is the point
                last_err = f"{type(e).__name__}: {e}\n{traceback.format_exc(limit=3)}"
        return CellResult(spec, None, Readiness.FAILED, error=last_err, attempts=self.max_retries)

    def _parallelism(self, override: Optional[int]) -> int:
        if override is not None:
            return max(1, int(override))
        return max(1, int(self.inputs.get("parallelism", 1)))

    def run_collection(
        self,
        specs: Sequence[BenchmarkSpec],
        injections: Optional[Injections] = None,
        *,
        parallelism: Optional[int] = None,
    ) -> List[CellResult]:
        """Run every cell; failures are isolated per cell (JUREAP mode —
        heterogeneous maturity levels coexist in one collection).

        ``parallelism`` (argument, or the ``parallelism`` input) > 1 runs
        cells through a bounded scheduler pool; each cell still persists its
        report the moment it finishes, so a crash mid-collection loses
        nothing already executed.
        """
        par = self._parallelism(parallelism)
        specs = list(specs)
        if par <= 1 or len(specs) <= 1:
            return [self.run_cell(s, injections) for s in specs]
        sched = CampaignScheduler(parallelism=par, name=f"exec.{self.prefix}")
        results = sched.map_items(lambda s: self.run_cell(s, injections), specs)
        return _unwrap_cells(specs, results)


class FeatureInjectionOrchestrator:
    """Re-runs an existing, frozen benchmark definition with an injected
    feature — env knob, launcher wrapper, or config override — without
    modifying the benchmark (paper §V-A3, Figs. 6/8)."""

    component = "feature-injection@v3"

    def __init__(self, *, execution: ExecutionOrchestrator, inputs: Dict[str, Any]):
        self.execution = execution
        self.inputs = dict(inputs)

    def sweep(
        self,
        spec: BenchmarkSpec,
        *,
        env_knob: Optional[str] = None,
        override_knob: Optional[str] = None,
        values: Sequence[Any] = (),
        launcher: Optional[Callable] = None,
        parallelism: Optional[int] = None,
    ) -> List[CellResult]:
        """One run per injected value (the UCX_RNDV_THRESH experiment).

        Sweep points are independent cells — with ``parallelism`` > 1 they
        dispatch concurrently.  Override-knob points parallelize freely;
        env-knob points injecting the SAME variable serialize against each
        other inside ``harness.injected_env`` (per-key lock), because
        ``os.environ`` is process-global — each cell genuinely executes
        under its own value.
        """
        injections = []
        for v in values:
            inj = Injections(launcher=launcher)
            if env_knob:
                inj.env[env_knob] = str(v)
            if override_knob:
                inj.overrides[override_knob] = v
            injections.append(inj)
        if parallelism is None:
            parallelism = int(self.inputs.get("parallelism", 1))
        if parallelism <= 1 or len(injections) <= 1:
            return [self.execution.run_cell(spec, inj) for inj in injections]
        sched = CampaignScheduler(parallelism=parallelism, name="sweep")
        results = sched.map_items(
            lambda inj: self.execution.run_cell(spec, inj), injections
        )
        return _unwrap_cells([spec] * len(injections), results)

    def run(self, spec: BenchmarkSpec, injections: Injections) -> CellResult:
        return self.execution.run_cell(spec, injections)


class PostProcessingOrchestrator:
    """Analysis over stored results only — fully decoupled from execution
    (paper §V-A2).  Emits protocol-compliant evaluation reports back into
    the store under an ``evaluation.<prefix>`` namespace.

    Analyses read the store through the incremental columnar plane
    (``store.columnar``) by default: metric series arrive as numpy columns
    extended in O(delta) per append, so warm analysis over a long history
    never re-materializes report objects.  ``inputs={"columnar": False}``
    selects the report-object reference path (outputs are identical — the
    parity is test-enforced); ``inputs={"record": False}`` skips writing the
    evaluation report back into the store (pure read-side analysis).
    """

    component = "post-processing@v3"

    def __init__(self, *, store: ResultStore, inputs: Dict[str, Any]):
        self.store = store
        self.inputs = dict(inputs)
        self.use_columnar = bool(self.inputs.get("columnar", True))

    def _eval_prefix(self) -> str:
        return self.inputs.get("prefix", "evaluation")

    def _record(self, kind: str, payload: Dict[str, Any], source_prefix: str) -> Optional[Report]:
        if not self.inputs.get("record", True):
            return None
        rep = new_report(
            system=self.inputs.get("machine", "analysis"),
            variant=kind,
            usecase=source_prefix,
            parameter={"analysis": kind, "inputs": {k: v for k, v in self.inputs.items()}},
        )
        rep.data.append(
            DataEntry(success=True, runtime=1e-9, metrics=dict(_flatten(payload)))
        )
        self.store.append(self._eval_prefix(), rep)
        return rep

    # ---- the three analysis components from the paper ----

    def time_series(
        self,
        *,
        source_prefix: str,
        data_labels: Sequence[str],
        time_span: Optional[Tuple[float, float]] = None,
        pipeline: Sequence[str] = (),
        detector: Optional[Dict[str, Any]] = None,
    ) -> Dict[str, Any]:
        """Fig. 3/4: metric-over-time + regression flags.

        ``detector`` tunes the change-point gate per deployment — e.g. a
        virtualized CPU host needs min_rel~0.25 where a quiet TPU pod can
        run the default 0.05 (the paper keeps the same human-in-the-loop
        calibration for its Fig. 8 scopes).
        """
        since, until = (time_span or (None, None))
        out: Dict[str, Any] = {"prefix": source_prefix, "series": {}, "regressions": {}}
        if self.use_columnar:
            table = self.store.columnar.table(source_prefix)
            reports = None
        else:
            reports = self.store.query(source_prefix, since=since, until=until)
            if pipeline:
                reports = [r for r in reports
                           if r.reporter.pipeline_id in set(pipeline)]
        det_key = tuple(sorted((detector or {}).items()))
        for label in data_labels:
            if reports is None:
                # Memoized on the (immutable) table: a warm re-analysis of
                # an unchanged prefix is a dict lookup, and any store change
                # swaps the table (and thus the memo) out from under us.
                key = ("time-series", label, since, until,
                       tuple(pipeline), det_key)
                hit = table.cache.get(key)
                if hit is None:
                    ms = table.series(
                        label, since=since, until=until,
                        pipelines=list(pipeline) if pipeline else None,
                    ).sorted_by_time()
                    regs = analysis.detect_regressions(ms, **(detector or {}))
                    hit = (list(zip(ms.timestamps.tolist(), ms.values.tolist())),
                           [dataclasses.asdict(r) for r in regs])
                    table.cache[key] = hit
                series, reg_dicts = hit
            else:
                series = analysis.to_series(reports, label)
                regs = analysis.detect_regressions(series, **(detector or {}))
                reg_dicts = [dataclasses.asdict(r) for r in regs]
            out["series"][label] = list(series)
            out["regressions"][label] = list(reg_dicts)
        self._record("time-series", {
            f"{l}_points": len(out["series"][l]) for l in data_labels
        } | {
            f"{l}_regressions": len(out["regressions"][l]) for l in data_labels
        }, source_prefix)
        return out

    def machine_comparison(
        self, *, selectors: Sequence[Dict[str, str]], metric: str
    ) -> Dict[str, Any]:
        """Fig. 5: one metric across systems/prefixes."""
        if self.use_columnar:
            # compare_systems scopes itself to the selectors; the frame's
            # prefix list is irrelevant here.
            table = CampaignFrame(self.store).compare_systems(selectors, metric)
        else:
            reports = []
            for sel in selectors:
                reports.extend(
                    self.store.query(sel["prefix"], system=sel.get("system"))
                )
            table = analysis.compare_systems(reports, metric)
        out = {"metric": metric, "table": table,
               "markdown": analysis.to_markdown(table, f"machine comparison: {metric}")}
        self._record("machine-comparison", {
            f"{s}_median": v["median"] for s, v in table.items()
        }, ";".join(s["prefix"] for s in selectors))
        return out

    def scalability(
        self, *, source_prefix: str, metric: str = "step_time_s", mode: str = "strong"
    ) -> Dict[str, Any]:
        """Fig. 5/7: scaling efficiency across node counts."""
        if self.use_columnar:
            points = self.store.columnar.table(source_prefix).scaling_points(metric)
        else:
            points: Dict[int, float] = {}
            for r in self.store.query(source_prefix):
                for d in r.data:
                    v = d.metrics.get(metric)
                    if v is not None:
                        points[d.nodes] = float(v)
        fn = analysis.strong_scaling if mode == "strong" else analysis.weak_scaling
        table = fn(points)
        out = {"mode": mode, "points": points, "table": table}
        self._record(f"scalability-{mode}", {
            f"n{n}_efficiency": v["efficiency"] for n, v in table.items()
        }, source_prefix)
        return out


class GateOrchestrator:
    """Enforces regression gates over stored results (paper §IV: continuous
    benchmarking pays off when CI *acts* on performance data).

    A thin adapter: the statistical machinery lives in
    ``repro.core.regression``; this class gives it the same declarative
    ``inputs`` interface as the other orchestrators, so a pipeline document
    can declare what a gate guards exactly like it declares an execution.
    Like post-processing, a gate only reads the store — it runs after its
    producers via the component DAG and never re-executes benchmarks.
    """

    component = "gate@v1"

    def __init__(self, *, store: ResultStore, inputs: Dict[str, Any]):
        self.store = store
        self.inputs = dict(inputs)

    def run(self) -> Dict[str, Any]:
        return RegressionGate.from_inputs(self.inputs).run(self.store)


def _flatten(d: Dict[str, Any], prefix: str = "") -> List[Tuple[str, float]]:
    out = []
    for k, v in d.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.extend(_flatten(v, key + "."))
        elif isinstance(v, (int, float, bool)):
            out.append((key, float(v)))
    return out
