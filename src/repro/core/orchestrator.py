"""The three exaCB orchestrators (paper §V-A).

exaCB deliberately avoids a monolithic orchestrator: execution, feature
injection and post-processing are independent so partial infrastructure
failures never lose results, and analyses re-run without re-executing
benchmarks.  Each orchestrator is configured with a declarative ``inputs``
dict mirroring the paper's GitLab CI/CD ``component:/inputs:`` blocks, e.g.::

    ExecutionOrchestrator(inputs={
        "prefix":  "jureca.single",
        "usecase": "train_4k",         # shape
        "variant": "single",
        "machine": "v5e-pod-16x16",
        "record":  True,
    }, harness=..., store=...)
"""

from __future__ import annotations

import dataclasses
import threading
import time
import traceback
import uuid
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core import accounting, analysis
from repro.core import autotune as autotune_mod
from repro.core import chaos as chaos_mod
from repro.core import duet as duet_mod
from repro.core import fingerprint as fingerprint_mod
from repro.core.columnar import CampaignFrame
from repro.core.component import (
    PARALLELISM,
    REGISTRY,
    WORKER_MODE,
    WORKERS,
    ComponentContext,
    ComponentInputs,
    ComponentRegistry,
    ComponentSchema,
    InputSpec,
    PipelineError,
    coerce_inputs,
    merge_schemas,
    resolve_parallelism,
    resolve_worker_mode,
)
from repro.core.harness import BenchmarkSpec, CapabilityError, Harness, Injections, negotiate
from repro.core.protocol import DataEntry, Report, new_report
from repro.core.readiness import Readiness, classify, parse_level
from repro.core.regression import GATE_SCHEMA, RegressionGate
from repro.core.scheduler import CampaignScheduler, TaskResult
from repro.core.store import ResultStore

# ---------------------------------------------------------------------------
# Declared input schemas (paper §II-C: components carry versioned, declared
# ``inputs:``).  v4 is the typed-API major: canonical input names match the
# ``BenchmarkSpec`` fields (``shape``/``system``), with the paper's v3
# vocabulary (``usecase``/``machine``) kept as deprecated aliases; migration
# shims (registered below) keep v3 documents running unchanged.
# ---------------------------------------------------------------------------

_CELL_INPUTS = (
    InputSpec("prefix", str, default="default",
              help="store prefix reports land under"),
    InputSpec("arch", str, required=True,
              help="architecture id from the benchmark collection"),
    InputSpec("shape", str, default="train_4k", aliases=("usecase",),
              help="input-shape id (the paper's 'usecase')"),
    InputSpec("system", str, default="cpu-smoke", aliases=("machine",),
              help="target system id (the paper's 'machine')"),
    InputSpec("variant", str, default="",
              help="variant label; defaults to the shape"),
    InputSpec("seed", int, default=0),
    InputSpec("record", bool, default=True,
              help="persist each report the moment its cell finishes"),
    InputSpec("require_readiness", str,
              choices=("none", "runnable", "instrumented", "reproducible"),
              help="readiness level the cell demands; negotiated against "
                   "the harness capability declaration before dispatch"),
    InputSpec("harness", str,
              help="named workload harness (exec|dryrun|kernel|serve|train); "
                   "configured via harness.<kwarg> inputs, overrides the "
                   "campaign-level harness for this component"),
    PARALLELISM,
    WORKERS,
    WORKER_MODE,
)

# Duet measurement mode (execution only — feature-injection sweeps already
# vary the cell deliberately).  See docs/measurement_methodology.md.
_DUET_INPUTS = (
    InputSpec("duet", bool, default=False,
              help="run the cell as interleaved baseline/candidate pairs on "
                   "one worker; the gate then judges paired per-round deltas"),
    InputSpec("duet_rounds", int, default=4,
              help="baseline/candidate round count per duet run"),
)

EXECUTION_SCHEMA = ComponentSchema(
    "execution", 4, _CELL_INPUTS + _DUET_INPUTS,
    open_namespaces=("harness",),
    description="run one benchmark cell through a harness with failure isolation",
)

FEATURE_INJECTION_SCHEMA = ComponentSchema(
    "feature-injection", 4,
    _CELL_INPUTS + (
        InputSpec("in_command", str,
                  help="env-var injection string (paper form: "
                       "'export UCX_RNDV_THRESH=65536')"),
        InputSpec("remat", str, help="remat-policy config override"),
        InputSpec("microbatches", int, help="microbatch config override"),
        InputSpec("strategy", str, help="sharding-strategy config override"),
        InputSpec("opt_state_dtype", str, help="optimizer-state dtype override"),
        InputSpec("env_knob", str,
                  help="env var swept across 'values' (one cell per value)"),
        InputSpec("override_knob", str,
                  help="config knob swept across 'values'"),
        InputSpec("values", list, wrap_scalar=True,
                  help="sweep points for env_knob / override_knob"),
    ),
    open_namespaces=("harness",),
    description="re-run a frozen benchmark with an injected feature",
)

_ANALYSIS_INPUTS = (
    InputSpec("prefix", str, default="evaluation",
              help="store prefix evaluation reports land under"),
    InputSpec("system", str, default="analysis", aliases=("machine",)),
    InputSpec("columnar", bool, default=True,
              help="read through the incremental columnar plane"),
    InputSpec("record", bool, default=True,
              help="write the evaluation report back into the store"),
)

TIME_SERIES_SCHEMA = ComponentSchema(
    "time-series", 4,
    _ANALYSIS_INPUTS + (
        InputSpec("source_prefix", str, required=True),
        InputSpec("data_labels", list, default=("step_time_s",), element=str,
                  wrap_scalar=True),
        InputSpec("pipeline", list, default=(), element=str, wrap_scalar=True,
                  help="restrict to these reporter pipeline ids"),
    ),
    open_namespaces=("detector",),
    description="metric-over-time series + regression flags (paper Fig. 3/4)",
)

MACHINE_COMPARISON_SCHEMA = ComponentSchema(
    "machine-comparison", 4,
    _ANALYSIS_INPUTS + (
        InputSpec("selector", list, required=True, wrap_scalar=True,
                  help="prefixes (or {prefix, system} mappings) to compare"),
        InputSpec("metric", str, default="step_time_s"),
    ),
    description="one metric across systems/prefixes (paper Fig. 5)",
)

SCALABILITY_SCHEMA = ComponentSchema(
    "scalability", 4,
    _ANALYSIS_INPUTS + (
        InputSpec("source_prefix", str, required=True),
        InputSpec("metric", str, default="step_time_s"),
        InputSpec("mode", str, default="strong", choices=("strong", "weak")),
    ),
    description="scaling efficiency across node counts (paper Fig. 5/7)",
)

CAMPAIGN_REPORT_SCHEMA = ComponentSchema(
    "campaign-report", 1,
    (
        InputSpec("metric", str, default="step_time_s"),
        InputSpec("prefixes", list, default=(), element=str, wrap_scalar=True,
                  help="prefixes to summarize; empty = the whole store "
                       "(waits on every producer in the DAG)"),
    ),
    description="cross-prefix campaign summary in one columnar scan",
)

#: Trigger names the continuous daemon understands (see docs/daemon.md).
SCHEDULE_TRIGGERS = ("lag", "downstream", "watermark")

SCHEDULE_SCHEMA = ComponentSchema(
    "schedule", 1,
    (
        InputSpec("target_lag", float, default=300.0,
                  help="lag budget in seconds: a producer cell whose newest "
                       "store entry is older than this is stale"),
        InputSpec("triggers", list, default=("lag",), element=str,
                  wrap_scalar=True,
                  help="refresh triggers: 'lag' (target_lag budget), "
                       "'downstream' (a consumer analysis/gate needs fresher "
                       "inputs), 'watermark' (a watched prefix's columnar "
                       "watermark advanced)"),
        InputSpec("watch", list, default=(), element=str, wrap_scalar=True,
                  help="store prefixes whose columnar watermark advance "
                       "marks this document's producers stale"),
        InputSpec("tick_s", float, default=5.0,
                  help="daemon tick interval for this document"),
        InputSpec("cell_deadline_s", float, default=0.0,
                  help="broker deadline per refreshed cell batch; 0 = none"),
        InputSpec("tick_deadline_s", float, default=0.0,
                  help="wall budget for one document refresh; 0 = none"),
        InputSpec("max_cells_per_tick", int, default=0,
                  help="cap on stale cells refreshed per tick; 0 = all"),
        InputSpec("quarantine_after", int, default=3,
                  help="consecutive failed refreshes before a cell is "
                       "quarantined (daemon skips it, daemon-status reports "
                       "it); 0 = never quarantine"),
    ),
    description="declarative refresh policy for the continuous campaign daemon",
)

# The construction-surface union for PostProcessingOrchestrator: its three
# analyses are the schema-bearing sub-components above; a directly
# constructed orchestrator validates against their merged declaration.
POST_PROCESSING_SCHEMA = merge_schemas(
    "post-processing", 4,
    TIME_SERIES_SCHEMA, MACHINE_COMPARISON_SCHEMA, SCALABILITY_SCHEMA,
    description="analysis over stored results, decoupled from execution",
)


@dataclasses.dataclass
class CellResult:
    spec: BenchmarkSpec
    report: Optional[Report]
    readiness: Readiness
    error: Optional[str] = None
    attempts: int = 1


def _unwrap_cells(specs: Sequence[BenchmarkSpec], results: Sequence[TaskResult]) -> List[CellResult]:
    """Scheduler results back to CellResults.  ``run_cell`` already isolates
    harness failures, so a task-level error only appears if the orchestrator
    machinery itself crashed — still reported, never raised."""
    out: List[CellResult] = []
    for spec, tr in zip(specs, results):
        if tr.error is not None:
            out.append(CellResult(spec, None, Readiness.FAILED, error=tr.error))
        else:
            out.append(tr.value)
    return out


def reduce_duet(spec: BenchmarkSpec, results: Sequence[CellResult]) -> CellResult:
    """Collapse a duet's per-invocation results into one CellResult so every
    one-result-per-spec surface (collection summaries, worker markers) keeps
    its shape.  The representative report is the highest-round candidate;
    readiness is the worst across invocations; attempts counts executions."""
    errors = [r.error for r in results if r.error]
    readiness = min((r.readiness for r in results), default=Readiness.FAILED)
    report: Optional[Report] = None
    best_round = -1
    for r in results:
        if r.report is None:
            continue
        ctx = duet_mod.context_of(r.report) or {}
        if ctx.get("role") == duet_mod.ROLE_CANDIDATE and int(ctx.get("round", -1)) >= best_round:
            best_round = int(ctx.get("round", -1))
            report = r.report
    if report is None:
        report = next((r.report for r in reversed(results)
                       if r.report is not None), None)
    return CellResult(spec, report, readiness,
                      error="; ".join(errors) if errors else None,
                      attempts=sum(r.attempts for r in results))


class ExecutionOrchestrator:
    """Runs benchmark cells through a harness with failure isolation
    (paper §V-A1)."""

    component = "execution@v4"
    schema = EXECUTION_SCHEMA

    def __init__(
        self,
        *,
        inputs: Dict[str, Any],
        harness: Harness,
        store: Optional[ResultStore] = None,
        fixture: Optional[Tuple[Callable[[], None], Callable[[], None]]] = None,
        max_retries: int = 1,
        resource_scope: str = "thread",
        worker_id: str = "",
        reference_fingerprint: Optional[Dict[str, Any]] = None,
    ):
        self.inputs = coerce_inputs(self.schema, inputs)
        self.harness = harness
        self.store = store
        self.fixture = fixture
        self.max_retries = max_retries
        # "thread" attributes the calling thread's CPU to each cell (shared
        # interpreter); process workers pass "process" for whole-process
        # deltas — exact per-cell cost including harness subprocesses.
        self.resource_scope = resource_scope
        self.worker_id = worker_id
        # The environment this campaign believes it is measuring under.
        # Every cell re-captures and compares: a drifted key field (governor
        # flip, re-limited cgroup, library upgrade) downgrades chain_of_trust
        # so the gate never promotes a baseline from a changed environment.
        # Brokers pass their own capture so all workers share one reference.
        self.reference_fingerprint = (dict(reference_fingerprint)
                                      if reference_fingerprint
                                      else fingerprint_mod.capture())

    @property
    def prefix(self) -> str:
        return self.inputs.get("prefix", "default")

    def run_cell(
        self,
        spec: BenchmarkSpec,
        injections: Optional[Injections] = None,
        *,
        tags: Optional[Dict[str, Any]] = None,
    ) -> CellResult:
        # Capability negotiation BEFORE dispatch: a cell whose requirements
        # (readiness level, step kind, injection mechanisms) exceed what the
        # harness declares fails fast — no execution slot burned, and the
        # error names every violated capability instead of surfacing as a
        # mystery readiness gap afterwards.
        try:
            negotiate(spec, self.harness, injections)
        except CapabilityError as e:
            return CellResult(spec, None, Readiness.FAILED,
                              error=f"CapabilityError: {e}", attempts=0)
        setup, teardown = self.fixture or (None, None)
        last_err = None
        for attempt in range(1, self.max_retries + 1):
            try:
                acct: Dict[str, Any] = {}
                if setup:
                    setup()
                try:
                    with accounting.resource_probe(acct, self.resource_scope):
                        report = self.harness.run(spec, injections)
                finally:
                    if teardown:
                        teardown()
                # Orchestrator-side provenance: injections are recorded even
                # if the harness forgot to (protocol over trust).
                if injections is not None:
                    report.parameter["injections"] = injections.describe()
                if tags:
                    report.parameter.update(tags)
                # Environment fingerprint: every report records the runner
                # conditions it was measured under; a key-field drift from
                # the campaign reference marks the measurement untrusted.
                fp = fingerprint_mod.capture()
                fingerprint_mod.stamp(report, fp)
                drifted = fingerprint_mod.drift(self.reference_fingerprint, fp)
                if drifted:
                    report.reporter.chain_of_trust = False
                    report.parameter[fingerprint_mod.DRIFT_PARAMETER] = drifted
                level, gaps = classify(report)
                report.parameter.setdefault("readiness", int(level))
                report.parameter.setdefault("readiness_gaps", gaps)
                # Resource accounting: envelope + columnar dimensions, so
                # campaign-report can answer "what did this campaign cost".
                accounting.stamp_report(
                    report, acct,
                    worker=self.worker_id or threading.current_thread().name,
                    worker_mode="process" if self.resource_scope == "process" else "thread",
                )
                # Persist IMMEDIATELY — a later cell failing must not lose
                # this result (the paper's resilience requirement).
                if self.store is not None and self.inputs.get("record", True):
                    self.store.append(self.prefix, report)
                return CellResult(spec, report, level, attempts=attempt)
            except Exception as e:  # noqa: BLE001 — isolation is the point
                last_err = f"{type(e).__name__}: {e}\n{traceback.format_exc(limit=3)}"
        return CellResult(spec, None, Readiness.FAILED, error=last_err, attempts=self.max_retries)

    def run_duet(
        self,
        spec: BenchmarkSpec,
        injections: Optional[Injections] = None,
        *,
        rounds: Optional[int] = None,
        candidate_injections: Optional[Injections] = None,
        duet_id: Optional[str] = None,
        skip: Optional[Set[Tuple[int, str]]] = None,
    ) -> List[CellResult]:
        """Run a cell as interleaved baseline/candidate pairs (duet mode).

        Each round executes the baseline role then the candidate role
        back-to-back in this thread/process, so environmental noise that
        varies round-to-round (frequency scaling, noisy neighbors) hits
        both sides of a pair nearly equally and cancels out of the
        per-round delta the paired gate judges.  ``candidate_injections``
        defaults to ``injections`` — identical binaries, the null duet a
        healthy CI run should measure.  ``skip`` names ``(round, role)``
        slots already persisted (reclaimed-retry adoption in the worker
        plane) so a duet resumes without duplicating measurements.
        """
        n_rounds = int(rounds if rounds is not None
                       else self.inputs.get("duet_rounds", 4))
        n_rounds = max(1, n_rounds)
        duet_id = duet_id or uuid.uuid4().hex[:12]
        cand_inj = candidate_injections if candidate_injections is not None else injections
        skip = skip or set()
        results: List[CellResult] = []
        for r in range(n_rounds):
            for role, inj in ((duet_mod.ROLE_BASELINE, injections),
                              (duet_mod.ROLE_CANDIDATE, cand_inj)):
                if (r, role) in skip:
                    continue
                results.append(self.run_cell(
                    spec, inj,
                    tags={duet_mod.PARAMETER: duet_mod.tag(duet_id, role, r, n_rounds)}))
        return results

    def _parallelism(self, override: Optional[int]) -> int:
        return resolve_parallelism(self.inputs, override)

    def run_collection(
        self,
        specs: Sequence[BenchmarkSpec],
        injections: Optional[Injections] = None,
        *,
        parallelism: Optional[int] = None,
        workers: Optional[int] = None,
        worker_mode: Optional[str] = None,
    ) -> List[CellResult]:
        """Run every cell; failures are isolated per cell (JUREAP mode —
        heterogeneous maturity levels coexist in one collection).

        ``parallelism``/``workers`` (argument, or the declared inputs) > 1
        runs cells through a bounded pool; each cell still persists its
        report the moment it finishes, so a crash mid-collection loses
        nothing already executed.  ``worker_mode="process"`` dispatches
        through the broker + spawned worker processes instead of the
        in-process thread pool: true CPU parallelism, and a killed worker's
        cells are lease-reclaimed and retried rather than lost.
        """
        par = self._parallelism(workers if workers is not None else parallelism)
        mode = resolve_worker_mode(self.inputs, worker_mode)
        specs = list(specs)
        if mode == "process" and len(specs) > 1:
            if self.store is None:
                raise PipelineError(
                    "worker_mode 'process' needs a store: the work queue and "
                    "results both persist through it")
            from repro.core import workers as workers_mod  # lazy: avoid cycle
            return workers_mod.run_collection_process(
                inputs=self.inputs, harness=self.harness, store=self.store,
                specs=specs, injections=injections, workers=par)
        if bool(self.inputs.get("duet")):
            # A duet pair must stay interleaved on one executor: the whole
            # duet is one unit of work (process mode gets the same pinning
            # for free — one queue payload per spec, leased atomically).
            def runner(s: BenchmarkSpec) -> CellResult:
                return reduce_duet(s, self.run_duet(s, injections))
        else:
            def runner(s: BenchmarkSpec) -> CellResult:
                return self.run_cell(s, injections)
        if par <= 1 or len(specs) <= 1:
            return [runner(s) for s in specs]
        sched = CampaignScheduler(parallelism=par, name=f"exec.{self.prefix}")
        results = sched.map_items(runner, specs, metas=specs)
        return _unwrap_cells(specs, results)


class FeatureInjectionOrchestrator:
    """Re-runs an existing, frozen benchmark definition with an injected
    feature — env knob, launcher wrapper, or config override — without
    modifying the benchmark (paper §V-A3, Figs. 6/8)."""

    component = "feature-injection@v4"
    schema = FEATURE_INJECTION_SCHEMA

    def __init__(self, *, execution: ExecutionOrchestrator, inputs: Dict[str, Any]):
        self.execution = execution
        self.inputs = coerce_inputs(self.schema, inputs)

    def sweep(
        self,
        spec: BenchmarkSpec,
        *,
        env_knob: Optional[str] = None,
        override_knob: Optional[str] = None,
        values: Sequence[Any] = (),
        launcher: Optional[Callable] = None,
        base: Optional[Injections] = None,
        parallelism: Optional[int] = None,
    ) -> List[CellResult]:
        """One run per injected value (the UCX_RNDV_THRESH experiment).

        ``base`` injections (fixed env vars / overrides shared by every
        point) are applied under each sweep value; the swept knob wins on
        conflict.  Sweep points are independent cells — with
        ``parallelism`` > 1 they dispatch concurrently.  Override-knob
        points parallelize freely; env-knob points injecting the SAME
        variable serialize against each other inside
        ``harness.injected_env`` (per-key lock), because ``os.environ`` is
        process-global — each cell genuinely executes under its own value.
        """
        injections = []
        for v in values:
            inj = Injections(
                env=dict(base.env) if base else {},
                launcher=launcher or (base.launcher if base else None),
                overrides=dict(base.overrides) if base else {},
            )
            if env_knob:
                inj.env[env_knob] = str(v)
            if override_knob:
                inj.overrides[override_knob] = v
            injections.append(inj)
        parallelism = resolve_parallelism(self.inputs, parallelism)
        if parallelism <= 1 or len(injections) <= 1:
            return [self.execution.run_cell(spec, inj) for inj in injections]
        sched = CampaignScheduler(parallelism=parallelism, name="sweep")
        results = sched.map_items(
            lambda inj: self.execution.run_cell(spec, inj), injections
        )
        return _unwrap_cells([spec] * len(injections), results)

    def run(self, spec: BenchmarkSpec, injections: Injections) -> CellResult:
        return self.execution.run_cell(spec, injections)


class PostProcessingOrchestrator:
    """Analysis over stored results only — fully decoupled from execution
    (paper §V-A2).  Emits protocol-compliant evaluation reports back into
    the store under an ``evaluation.<prefix>`` namespace.

    Analyses read the store through the incremental columnar plane
    (``store.columnar``) by default: metric series arrive as numpy columns
    extended in O(delta) per append, so warm analysis over a long history
    never re-materializes report objects.  ``inputs={"columnar": False}``
    selects the report-object reference path (outputs are identical — the
    parity is test-enforced); ``inputs={"record": False}`` skips writing the
    evaluation report back into the store (pure read-side analysis).
    """

    component = "post-processing@v4"
    schema = POST_PROCESSING_SCHEMA

    def __init__(self, *, store: ResultStore, inputs: Dict[str, Any]):
        self.store = store
        self.inputs = coerce_inputs(self.schema, inputs)
        self.use_columnar = bool(self.inputs.get("columnar", True))

    def _eval_prefix(self) -> str:
        return self.inputs.get("prefix", "evaluation")

    def _record(self, kind: str, payload: Dict[str, Any], source_prefix: str) -> Optional[Report]:
        if not self.inputs.get("record", True):
            return None
        rep = new_report(
            system=self.inputs.get("system", "analysis"),
            variant=kind,
            usecase=source_prefix,
            parameter={"analysis": kind, "inputs": {k: v for k, v in self.inputs.items()}},
        )
        rep.data.append(
            DataEntry(success=True, runtime=1e-9, metrics=dict(_flatten(payload)))
        )
        self.store.append(self._eval_prefix(), rep)
        return rep

    # ---- the three analysis components from the paper ----

    def time_series(
        self,
        *,
        source_prefix: str,
        data_labels: Sequence[str],
        time_span: Optional[Tuple[float, float]] = None,
        pipeline: Sequence[str] = (),
        detector: Optional[Dict[str, Any]] = None,
    ) -> Dict[str, Any]:
        """Fig. 3/4: metric-over-time + regression flags.

        ``detector`` tunes the change-point gate per deployment — e.g. a
        virtualized CPU host needs min_rel~0.25 where a quiet TPU pod can
        run the default 0.05 (the paper keeps the same human-in-the-loop
        calibration for its Fig. 8 scopes).
        """
        since, until = (time_span or (None, None))
        out: Dict[str, Any] = {"prefix": source_prefix, "series": {}, "regressions": {}}
        if self.use_columnar:
            table = self.store.columnar.table(source_prefix)
            reports = None
        else:
            reports = self.store.query(source_prefix, since=since, until=until)
            if pipeline:
                reports = [r for r in reports
                           if r.reporter.pipeline_id in set(pipeline)]
        det_key = tuple(sorted((detector or {}).items()))
        for label in data_labels:
            if reports is None:
                # Memoized on the (immutable) table: a warm re-analysis of
                # an unchanged prefix is a dict lookup, and any store change
                # swaps the table (and thus the memo) out from under us.
                key = ("time-series", label, since, until,
                       tuple(pipeline), det_key)
                hit = table.cache.get(key)
                if hit is None:
                    ms = table.series(
                        label, since=since, until=until,
                        pipelines=list(pipeline) if pipeline else None,
                    ).sorted_by_time()
                    regs = analysis.detect_regressions(ms, **(detector or {}))
                    hit = (list(zip(ms.timestamps.tolist(), ms.values.tolist())),
                           [dataclasses.asdict(r) for r in regs])
                    table.cache[key] = hit
                series, reg_dicts = hit
            else:
                series = analysis.to_series(reports, label)
                regs = analysis.detect_regressions(series, **(detector or {}))
                reg_dicts = [dataclasses.asdict(r) for r in regs]
            out["series"][label] = list(series)
            out["regressions"][label] = list(reg_dicts)
        self._record("time-series", {
            f"{l}_points": len(out["series"][l]) for l in data_labels
        } | {
            f"{l}_regressions": len(out["regressions"][l]) for l in data_labels
        }, source_prefix)
        return out

    def machine_comparison(
        self, *, selectors: Sequence[Dict[str, str]], metric: str
    ) -> Dict[str, Any]:
        """Fig. 5: one metric across systems/prefixes."""
        if self.use_columnar:
            # compare_systems scopes itself to the selectors; the frame's
            # prefix list is irrelevant here.
            table = CampaignFrame(self.store).compare_systems(selectors, metric)
        else:
            reports = []
            for sel in selectors:
                reports.extend(
                    self.store.query(sel["prefix"], system=sel.get("system"))
                )
            table = analysis.compare_systems(reports, metric)
        out = {"metric": metric, "table": table,
               "markdown": analysis.to_markdown(table, f"machine comparison: {metric}")}
        self._record("machine-comparison", {
            f"{s}_median": v["median"] for s, v in table.items()
        }, ";".join(s["prefix"] for s in selectors))
        return out

    def scalability(
        self, *, source_prefix: str, metric: str = "step_time_s", mode: str = "strong"
    ) -> Dict[str, Any]:
        """Fig. 5/7: scaling efficiency across node counts."""
        if self.use_columnar:
            points = self.store.columnar.table(source_prefix).scaling_points(metric)
        else:
            points: Dict[int, float] = {}
            for r in self.store.query(source_prefix):
                for d in r.data:
                    v = d.metrics.get(metric)
                    if v is not None:
                        points[d.nodes] = float(v)
        fn = analysis.strong_scaling if mode == "strong" else analysis.weak_scaling
        table = fn(points)
        out = {"mode": mode, "points": points, "table": table}
        self._record(f"scalability-{mode}", {
            f"n{n}_efficiency": v["efficiency"] for n, v in table.items()
        }, source_prefix)
        return out


class GateOrchestrator:
    """Enforces regression gates over stored results (paper §IV: continuous
    benchmarking pays off when CI *acts* on performance data).

    A thin adapter: the statistical machinery lives in
    ``repro.core.regression``; this class gives it the same declarative
    ``inputs`` interface as the other orchestrators, so a pipeline document
    can declare what a gate guards exactly like it declares an execution.
    Like post-processing, a gate only reads the store — it runs after its
    producers via the component DAG and never re-executes benchmarks.
    """

    component = "gate@v1"
    schema = GATE_SCHEMA

    def __init__(self, *, store: ResultStore, inputs: Dict[str, Any]):
        self.store = store
        self.inputs = coerce_inputs(self.schema, inputs)

    def run(self) -> Dict[str, Any]:
        return RegressionGate.from_inputs(self.inputs).run(self.store)


def _flatten(d: Dict[str, Any], prefix: str = "") -> List[Tuple[str, float]]:
    out = []
    for k, v in d.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.extend(_flatten(v, key + "."))
        elif isinstance(v, (int, float, bool)):
            out.append((key, float(v)))
    return out


# ---------------------------------------------------------------------------
# Component runners + self-registration.  Each orchestrator registers its
# versioned schema (and the runner the CI/CD layer dispatches through) into
# the process-wide registry; ``cicd`` no longer hardcodes any of this.
# ---------------------------------------------------------------------------

def spec_from_inputs(inputs: ComponentInputs) -> BenchmarkSpec:
    """Validated component inputs → the typed benchmark cell."""
    if not inputs.get("arch"):
        raise PipelineError(
            f"{inputs.component or 'execution'}: input 'arch' is required")
    return BenchmarkSpec(
        arch=inputs["arch"],
        shape=inputs.get("shape", "train_4k"),
        system=inputs.get("system", "cpu-smoke"),
        variant=inputs.get("variant", ""),
        seed=int(inputs.get("seed", 0)),
        require_readiness=int(parse_level(inputs.get("require_readiness"))),
    )


def _cell_summary(name: str, spec: BenchmarkSpec, res: CellResult) -> Dict[str, Any]:
    return {
        "component": name,
        "cell": spec.cell,
        "readiness": int(res.readiness),
        "error": res.error,
    }


def _harness_for(inputs: ComponentInputs, ctx: ComponentContext):
    """Document-declared harness (``harness:`` + ``harness.<kwarg>`` inputs)
    wins over the campaign-level harness/factory — a pipeline can mix
    kernel, serve, and model cells without per-call wiring."""
    from repro import harnesses as harness_families

    declared = harness_families.from_inputs(inputs)
    return declared if declared is not None else ctx.harness_for(inputs)


def _run_execution(inputs: ComponentInputs, ctx: ComponentContext) -> Dict[str, Any]:
    ex = ExecutionOrchestrator(
        inputs=inputs, harness=_harness_for(inputs, ctx), store=ctx.store)
    spec = spec_from_inputs(inputs)
    if bool(inputs.get("duet")):
        results = ex.run_duet(spec)
        out = _cell_summary("execution", spec, reduce_duet(spec, results))
        out["duet"] = {"rounds": int(inputs.get("duet_rounds", 4)),
                       "invocations": len(results)}
        return out
    return _cell_summary("execution", spec, ex.run_cell(spec))


def _injections_from_inputs(inputs: ComponentInputs) -> Injections:
    inj = Injections()
    if inputs.get("in_command"):  # paper: env-var injection string
        for assign in str(inputs["in_command"]).replace("export ", "").split(";"):
            if "=" in assign:
                k, v = assign.split("=", 1)
                inj.env[k.strip()] = v.strip()
    for k in ("remat", "microbatches", "strategy", "opt_state_dtype"):
        if inputs.get(k) is not None:
            inj.overrides[k] = inputs[k]
    return inj


def _run_feature_injection(inputs: ComponentInputs, ctx: ComponentContext) -> Dict[str, Any]:
    ex = ExecutionOrchestrator(
        inputs=inputs, harness=_harness_for(inputs, ctx), store=ctx.store)
    fi = FeatureInjectionOrchestrator(execution=ex, inputs=inputs)
    spec = spec_from_inputs(inputs)
    values = inputs.get("values")
    if values:
        if not (inputs.get("env_knob") or inputs.get("override_knob")):
            raise PipelineError(
                f"{inputs.component}: 'values' needs an 'env_knob' or "
                "'override_knob' to sweep")
        # Declared fixed injections (in_command env vars, config overrides)
        # apply under every sweep point — schema-accepted inputs must never
        # silently do nothing.
        results = fi.sweep(
            spec,
            env_knob=inputs.get("env_knob"),
            override_knob=inputs.get("override_knob"),
            values=list(values),
            base=_injections_from_inputs(inputs),
        )
        errors = [r.error for r in results if r.error]
        return {
            "component": "feature-injection",
            "cell": spec.cell,
            "points": len(results),
            "readiness": [int(r.readiness) for r in results],
            "error": "; ".join(errors) if errors else None,
        }
    res = fi.run(spec, _injections_from_inputs(inputs))
    return _cell_summary("feature-injection", spec, res)


def _run_time_series(inputs: ComponentInputs, ctx: ComponentContext) -> Dict[str, Any]:
    pp = PostProcessingOrchestrator(store=ctx.store, inputs=inputs)
    out = pp.time_series(
        source_prefix=inputs["source_prefix"],
        data_labels=list(inputs["data_labels"]),
        pipeline=list(inputs["pipeline"]),
        detector=inputs.namespace("detector") or None,
    )
    return {
        "component": "time-series",
        "points": {k: len(v) for k, v in out["series"].items()},
        "regressions": {k: len(v) for k, v in out["regressions"].items()},
    }


def _run_machine_comparison(inputs: ComponentInputs, ctx: ComponentContext) -> Dict[str, Any]:
    pp = PostProcessingOrchestrator(store=ctx.store, inputs=inputs)
    out = pp.machine_comparison(
        selectors=[sel if isinstance(sel, dict) else {"prefix": sel}
                   for sel in inputs["selector"]],
        metric=inputs["metric"],
    )
    return {"component": "machine-comparison", "table": out["table"]}


def _run_scalability(inputs: ComponentInputs, ctx: ComponentContext) -> Dict[str, Any]:
    pp = PostProcessingOrchestrator(store=ctx.store, inputs=inputs)
    out = pp.scalability(
        source_prefix=inputs["source_prefix"],
        metric=inputs["metric"],
        mode=inputs["mode"],
    )
    return {"component": "scalability", "table": out["table"]}


def _run_gate(inputs: ComponentInputs, ctx: ComponentContext) -> Dict[str, Any]:
    return GateOrchestrator(store=ctx.store, inputs=inputs).run()


def _run_campaign_report(inputs: ComponentInputs, ctx: ComponentContext) -> Dict[str, Any]:
    metric = inputs["metric"]
    frame = CampaignFrame(ctx.store, prefixes=list(inputs["prefixes"]) or None)
    table = frame.summary(metric)
    return {
        "component": "campaign-report",
        "metric": metric,
        "prefixes": len(table),
        "table": table,
        "watermarks": frame.watermarks(),
        "markdown": analysis.to_markdown(table, f"campaign summary: {metric}"),
    }


def _run_schedule(inputs: ComponentInputs, ctx: ComponentContext) -> Dict[str, Any]:
    """Batch-run behavior of ``schedule@v1``: pure declaration echo.  The
    policy only *acts* under ``python -m repro daemon``; in a one-shot
    ``repro run`` it validates and reports itself so a document stays
    runnable both ways."""
    triggers = [str(t) for t in inputs.get("triggers", ())]
    unknown = sorted(set(triggers) - set(SCHEDULE_TRIGGERS))
    if unknown:
        raise PipelineError(
            f"schedule: unknown trigger(s) {unknown}; "
            f"known: {list(SCHEDULE_TRIGGERS)}")
    return {
        "component": "schedule",
        "triggers": triggers,
        "target_lag": float(inputs.get("target_lag", 300.0)),
        "watch": [str(p) for p in inputs.get("watch", ())],
        "tick_s": float(inputs.get("tick_s", 5.0)),
        "note": "declarative refresh policy; enforced by `repro daemon`",
    }


def _migrate_cell_vocabulary(inputs: Dict[str, Any]) -> Dict[str, Any]:
    """v3 → v4 shim: the paper vocabulary (``usecase``/``machine``) was
    canonical in v3, so the rename is silent here — only a *v4* document
    still using the old names earns a deprecation warning via the alias
    mechanism."""
    for old, new in (("usecase", "shape"), ("machine", "system")):
        if old in inputs and new not in inputs:
            inputs[new] = inputs.pop(old)
    return inputs


def register_components(registry: ComponentRegistry) -> ComponentRegistry:
    """Register every orchestrator-backed component (schema + runner) and
    the v3→v4 migration shims into ``registry``."""
    registry.register(EXECUTION_SCHEMA, _run_execution)
    registry.register(FEATURE_INJECTION_SCHEMA, _run_feature_injection)
    registry.register(TIME_SERIES_SCHEMA, _run_time_series)
    registry.register(MACHINE_COMPARISON_SCHEMA, _run_machine_comparison)
    registry.register(SCALABILITY_SCHEMA, _run_scalability)
    registry.register(GATE_SCHEMA, _run_gate)
    registry.register(CAMPAIGN_REPORT_SCHEMA, _run_campaign_report)
    registry.register(SCHEDULE_SCHEMA, _run_schedule)
    registry.register(chaos_mod.CHAOS_SCHEMA, chaos_mod.run_chaos_component)
    registry.register(autotune_mod.AUTOTUNE_SCHEMA, autotune_mod.run_autotune)
    for name in ("execution", "feature-injection", "time-series",
                 "machine-comparison", "scalability"):
        registry.register_migration(name, 3, 4, _migrate_cell_vocabulary)
    return registry


register_components(REGISTRY)
