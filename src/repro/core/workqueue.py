"""Lease-reclaimed, claim-based work queue on a shared filesystem.

The distributed execution plane's coordination substrate: a ``CampaignBroker``
materializes a campaign's cells into one queue directory, and N independent
worker *processes* (eventually N hosts sharing the filesystem — the paper's
JUREAP deployment model) drain it with no duplicate execution.  The protocol
reuses the store's proven concurrency machinery (``DirBackend``'s flock +
``O_EXCL`` claim files) rather than inventing a new one:

* **Tasks** are immutable JSON payloads ``tasks/<idx>.json`` written once at
  materialization — dispatch is by data (document, component-ref,
  cell-index), never by closure, so any spawned interpreter can execute any
  cell.
* **Claims** are ``O_EXCL``-created lease files ``leases/<idx>.lease``: the
  single winner of the create race owns the cell.  The owner heartbeats the
  lease (mtime refresh) while executing; a lease whose mtime goes stale for
  longer than ``lease_timeout`` marks a dead worker.
* **Reclaim** is flock-arbitrated (``.reclaim.lock``): any process may call
  :meth:`WorkQueue.reclaim_expired`; exactly one wins, unlinks the stale
  lease, and journals the event to ``reclaims.jsonl`` — the journal length
  per cell is the retry counter, and a cell reclaimed ``max_attempts`` times
  is terminally failed (failure isolation: one poisoned cell cannot wedge
  the campaign).
* **Completion** is a first-writer-wins ``done/<idx>.json`` marker (written
  to a temp file, then hard-linked into place — atomic and exclusive).  A
  slow-but-alive worker whose cell was reclaimed simply loses the marker
  race; its result is discarded.

Liveness is judged by lease mtime, so on a shared filesystem all
participating hosts should have reasonably synchronized clocks (the same
assumption the store's mtime-fingerprint cache already makes); the
tolerated drift and the full failure taxonomy are written down in
``docs/failure_model.md``.

Every filesystem touch here goes through the shared retry taxonomy
(``repro.core.retry``) and is wrapped by a named chaos injection site
(``queue.claim`` / ``queue.heartbeat`` / ``queue.complete`` /
``queue.reclaim`` — see ``repro.core.chaos``), so the protocol's
exactly-once claims are exercised under a seeded fault space, not just the
happy path.
"""

from __future__ import annotations

import json
import os
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

from repro.core import chaos
from repro.core.retry import call_with_retry, is_transient
from repro.core.store import _flock, _funlock

DEFAULT_LEASE_TIMEOUT = 15.0
DEFAULT_MAX_ATTEMPTS = 3

_META = "queue.json"
_RECLAIMS = "reclaims.jsonl"
_RECLAIM_LOCK = ".reclaim.lock"
_STOP = "stop"
_WORKERS = "workers"


class WorkQueueError(RuntimeError):
    pass


def _task_name(idx: int) -> str:
    return f"{idx:05d}"


class WorkQueue:
    """One campaign's claim-based cell queue (see module docstring)."""

    def __init__(self, root: str | Path, *, lease_timeout: float = DEFAULT_LEASE_TIMEOUT):
        self.root = Path(root)
        self.lease_timeout = float(lease_timeout)
        self._tasks = self.root / "tasks"
        self._leases = self.root / "leases"
        self._done = self.root / "done"
        self._n_tasks: Optional[int] = None

    # ------------------------------------------------------------ lifecycle
    def create(self, payloads: List[Dict[str, Any]], *, campaign: str = "campaign") -> "WorkQueue":
        """Materialize ``payloads`` as immutable task files.  ``task_uid`` is
        stamped onto each payload (campaign + index) so retries and store
        records are correlatable; the meta file is written last — a queue
        without it is invisible to workers."""
        if self.root.exists() and (self.root / _META).exists():
            raise WorkQueueError(f"queue already materialized at {self.root}")
        for d in (self._tasks, self._leases, self._done):
            d.mkdir(parents=True, exist_ok=True)
        for idx, payload in enumerate(payloads):
            payload = dict(payload)
            payload.setdefault("task_uid", f"{campaign}:{idx}")
            _atomic_json(self._tasks / f"{_task_name(idx)}.json", payload)
        _atomic_json(self.root / _META, {
            "campaign": campaign,
            "n_tasks": len(payloads),
            "created": time.time(),
            "lease_timeout": self.lease_timeout,
        })
        self._n_tasks = len(payloads)
        return self

    @property
    def n_tasks(self) -> int:
        if self._n_tasks is None:
            try:
                meta = json.loads((self.root / _META).read_text())
            except (OSError, ValueError) as e:
                raise WorkQueueError(f"no queue at {self.root}: {e}") from e
            self._n_tasks = int(meta["n_tasks"])
        return self._n_tasks

    def payload(self, idx: int) -> Dict[str, Any]:
        return json.loads((self._tasks / f"{_task_name(idx)}.json").read_text())

    # ---------------------------------------------------------------- claim
    def claim_next(self, worker: str, *, host: str = "") -> Optional[Tuple[int, Dict[str, Any], int]]:
        """Claim the lowest unowned, unfinished cell via the ``O_EXCL`` lease
        race; returns ``(idx, payload, attempt)`` or ``None`` when every cell
        is either done or currently leased.

        A task file that cannot be *read back* after the lease create wins
        must not leak the lease (the cell would be blocked until
        ``lease_timeout`` and the journal would charge a phantom attempt):
        a transient read failure releases the lease and moves on, while a
        truly corrupt payload (unparseable JSON) is terminally failed with
        a structured error marker — failure isolation, not a stuck queue.
        """
        chaos.trip("queue.claim")
        reclaims = self._reclaim_counts()
        for idx in range(self.n_tasks):
            name = _task_name(idx)
            if (self._done / f"{name}.json").exists():
                continue
            lease = self._leases / f"{name}.lease"
            if lease.exists():
                continue  # cheap pre-check; O_EXCL below is the arbiter
            try:
                fd = os.open(lease, os.O_CREAT | os.O_EXCL | os.O_WRONLY, 0o644)
            except FileExistsError:
                continue  # lost the race — another worker owns this cell
            attempt = 1 + reclaims.get(idx, 0)
            body = json.dumps({
                "worker": worker, "host": host, "attempt": attempt,
                "claimed_at": time.time(),
            }).encode()
            try:
                # The lease body is the fencing token; a transient write
                # failure is retried (an empty lease would fence its own
                # owner), and a persistent one releases the claim.
                call_with_retry(lambda: os.pwrite(fd, body, 0),
                                label="queue.claim")
            except OSError:
                os.close(fd)
                lease.unlink(missing_ok=True)
                continue
            os.close(fd)
            try:
                payload = self.payload(idx)
            except ValueError as e:
                # Corrupt payload: terminal marker (complete() releases the
                # lease we hold, so the write is race-free) — every observer
                # gets one structured answer instead of a wedged cell.
                self.complete(idx, {
                    "task_uid": "",
                    "error": f"corrupt task payload {name}.json: {e}",
                    "readiness": 0,
                    "corrupt": True,
                })
                continue
            except OSError:
                # Transient (NFS hiccup, slow materialization): release the
                # lease so the cell is immediately claimable again.
                lease.unlink(missing_ok=True)
                continue
            return idx, payload, attempt
        return None

    def lease_info(self, idx: int) -> Optional[Dict[str, Any]]:
        """The current lease body for a cell, or ``None`` when unleased
        (completed, reclaimed, or never claimed)."""
        try:
            return json.loads((self._leases / f"{_task_name(idx)}.lease").read_text())
        except (OSError, ValueError):
            return None

    def owns(self, idx: int, worker: str, attempt: int) -> bool:
        """Fencing check: does ``worker``'s claim (at ``attempt``) still hold
        the lease?  A slow-but-alive worker whose lease was reclaimed — and
        possibly re-claimed by a retry — sees False and must abandon its
        side effects (store append, done marker).  This is what makes the
        store append exactly-once under pauses (SIGSTOP, NFS stall, GC-like
        hiccups), not just under SIGKILL."""
        info = self.lease_info(idx)
        return (info is not None
                and info.get("worker") == worker
                and int(info.get("attempt", -1)) == int(attempt))

    def heartbeat(self, idx: int) -> bool:
        """Refresh the lease's liveness signal (mtime).  Returns False when
        the lease is gone — i.e. the cell was reclaimed out from under the
        caller, whose eventual ``complete`` will simply lose the race.
        Transient I/O failures *raise* (they say nothing about ownership);
        the worker's heartbeat thread retries them with backoff and fences
        the cell if they persist."""
        chaos.trip("queue.heartbeat")
        path = self._leases / f"{_task_name(idx)}.lease"
        skew_s = chaos.skew("queue.heartbeat")
        try:
            if skew_s:
                # Injected clock drift: stamp the mtime as a host whose
                # clock runs `skew_s` seconds off would.
                t = time.time() + skew_s
                os.utime(path, (t, t))
            else:
                os.utime(path)
            return True
        except FileNotFoundError:
            return False
        except OSError as e:
            if is_transient(e):
                raise
            return False

    def complete(self, idx: int, result: Dict[str, Any]) -> bool:
        """Write the terminal result marker, first writer wins.  Returns
        False when another writer (a reclaimed retry, or the reclaimer's
        terminal-failure marker) got there first."""
        chaos.trip("queue.complete")
        done = self._done / f"{_task_name(idx)}.json"

        def _staged() -> str:
            fd, tmp = tempfile.mkstemp(dir=self._done, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(result, f, default=str)
            except BaseException:
                os.unlink(tmp)
                raise
            return tmp

        tmp = call_with_retry(_staged, label="queue.complete")
        try:
            try:
                os.link(tmp, done)  # atomic + exclusive (fails if done exists)
                won = True
            except FileExistsError:
                won = False
            except OSError:
                # Filesystem without hard links: O_EXCL create is the fallback
                # arbiter (non-atomic content, but single-writer by contract).
                try:
                    dfd = os.open(done, os.O_CREAT | os.O_EXCL | os.O_WRONLY, 0o644)
                except FileExistsError:
                    won = False
                else:
                    with os.fdopen(dfd, "w") as f:
                        json.dump(result, f, default=str)
                    won = True
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        (self._leases / f"{_task_name(idx)}.lease").unlink(missing_ok=True)
        return won

    # -------------------------------------------------------------- reclaim
    def reclaim_expired(self, *, max_attempts: int = DEFAULT_MAX_ATTEMPTS) -> List[int]:
        """Reclaim every lease whose heartbeat went stale: unlink it, journal
        the event, and terminally fail cells that exhausted ``max_attempts``
        executions.  flock-arbitrated — safe to call from any process (the
        broker's monitor loop AND idle workers both do)."""
        if not self._leases.exists():
            return []
        chaos.trip("queue.reclaim")
        reclaimed: List[int] = []
        lock_fd = os.open(self.root / _RECLAIM_LOCK, os.O_CREAT | os.O_RDWR, 0o644)
        try:
            _flock(lock_fd)
            # Injected clock skew emulates a reclaimer host whose clock runs
            # fast — the drift scenario the liveness model must tolerate.
            now = time.time() + chaos.skew("queue.reclaim")
            counts = self._reclaim_counts()
            for lease in sorted(self._leases.glob("*.lease")):
                idx = int(lease.stem)
                name = _task_name(idx)
                if (self._done / f"{name}.json").exists():
                    lease.unlink(missing_ok=True)  # straggler cleanup
                    continue
                try:
                    age = now - lease.stat().st_mtime
                except OSError:
                    continue  # completed/reclaimed between glob and stat
                if age <= self.lease_timeout:
                    continue
                try:
                    info = json.loads(lease.read_text())
                except (OSError, ValueError):
                    info = {}
                attempts = counts.get(idx, 0) + 1
                # Journal FIRST, then unlink: if the journal append fails
                # persistently the lease stays put and the attempt stays
                # uncharged — the next reclaim pass retries the whole step.
                # (The reverse order could un-lease a cell without charging
                # it, making its retry budget unbounded.)
                try:
                    call_with_retry(
                        lambda: self._journal({
                            "idx": idx, "worker": info.get("worker", "?"),
                            "host": info.get("host", ""),
                            "attempt": info.get("attempt", attempts),
                            "ts": now,
                        }),
                        label="queue.reclaim")
                except OSError:
                    continue
                counts[idx] = attempts
                lease.unlink(missing_ok=True)
                if attempts >= max_attempts:
                    # Terminal failure marker — failure isolation, not retry
                    # forever.  complete() keeps first-writer-wins semantics.
                    self.complete(idx, {
                        "task_uid": self.payload(idx).get("task_uid", ""),
                        "error": f"lease expired after {attempts} failed "
                                 f"attempts (last worker {info.get('worker', '?')})",
                        "readiness": 0,
                        "attempts": attempts,
                        "reclaimed": True,
                    })
                reclaimed.append(idx)
        finally:
            _funlock(lock_fd)
            os.close(lock_fd)
        return reclaimed

    def _journal(self, entry: Dict[str, Any]) -> None:
        with open(self.root / _RECLAIMS, "a") as f:
            f.write(json.dumps(entry) + "\n")

    def release(self, idx: int, worker: str, attempt: int, *,
                charge: bool = False,
                max_attempts: int = DEFAULT_MAX_ATTEMPTS) -> bool:
        """Ownership-checked voluntary lease release — a worker fencing
        itself (persistent heartbeat failure, store append exhausted its
        retries) hands the cell back *promptly* instead of letting the
        lease age out.  Returns False when the caller no longer owns the
        lease (someone reclaimed it already).

        ``charge=True`` journals the release like a reclaim, so a cell
        whose every execution self-fences still exhausts ``max_attempts``
        and fails terminally instead of bouncing between workers forever.
        """
        lock_fd = os.open(self.root / _RECLAIM_LOCK, os.O_CREAT | os.O_RDWR, 0o644)
        try:
            _flock(lock_fd)
            if not self.owns(idx, worker, attempt):
                return False
            if charge:
                attempts = self._reclaim_counts().get(idx, 0) + 1
                try:
                    call_with_retry(
                        lambda: self._journal({
                            "idx": idx, "worker": worker, "attempt": attempt,
                            "ts": time.time(), "released": True,
                        }),
                        label="queue.release")
                except OSError:
                    return False  # keep the lease; let reclaim arbitrate
                (self._leases / f"{_task_name(idx)}.lease").unlink(missing_ok=True)
                if attempts >= max_attempts:
                    self.complete(idx, {
                        "task_uid": self.payload(idx).get("task_uid", ""),
                        "error": f"worker self-fenced after {attempts} failed "
                                 f"attempts (last worker {worker})",
                        "readiness": 0,
                        "attempts": attempts,
                        "released": True,
                    })
                return True
            (self._leases / f"{_task_name(idx)}.lease").unlink(missing_ok=True)
            return True
        finally:
            _funlock(lock_fd)
            os.close(lock_fd)

    def _reclaim_counts(self) -> Dict[int, int]:
        counts: Dict[int, int] = {}
        try:
            text = (self.root / _RECLAIMS).read_text()
        except OSError:
            return counts
        for line in text.splitlines():
            try:
                idx = int(json.loads(line)["idx"])
            except (ValueError, KeyError, TypeError):
                continue
            counts[idx] = counts.get(idx, 0) + 1
        return counts

    def reclaim_journal(self) -> List[Dict[str, Any]]:
        try:
            text = (self.root / _RECLAIMS).read_text()
        except OSError:
            return []
        out = []
        for line in text.splitlines():
            try:
                out.append(json.loads(line))
            except ValueError:
                continue
        return out

    # ------------------------------------------------------- worker registry
    def _worker_file(self, worker: str) -> Path:
        safe = "".join(c if (c.isalnum() or c in "._-") else "_" for c in worker)
        return self.root / _WORKERS / f"{safe}.json"

    def register_worker(self, worker: str, **meta: Any) -> None:
        """Announce a worker (local or remote host) joining the campaign.
        The registry file's mtime is the worker's liveness signal, exactly
        like a lease — ``daemon-status`` renders per-host liveness from it.
        Registration is best-effort: a worker that cannot register still
        drains (the registry is an observability surface, not a lock)."""
        try:
            (self.root / _WORKERS).mkdir(exist_ok=True)
            _atomic_json(self._worker_file(worker), {
                "worker": worker,
                "registered": time.time(),
                **meta,
            })
        except OSError:
            pass

    def touch_worker(self, worker: str) -> None:
        try:
            os.utime(self._worker_file(worker))
        except OSError:
            pass

    def worker_registry(self, *, alive_within: Optional[float] = None) -> List[Dict[str, Any]]:
        """Every registered worker with its liveness age.  ``alive`` uses
        ``alive_within`` (default: the lease timeout) against the registry
        file's mtime."""
        horizon = self.lease_timeout if alive_within is None else float(alive_within)
        out: List[Dict[str, Any]] = []
        wdir = self.root / _WORKERS
        if not wdir.exists():
            return out
        now = time.time()
        for p in sorted(wdir.glob("*.json")):
            try:
                entry = json.loads(p.read_text())
                age = now - p.stat().st_mtime
            except (OSError, ValueError):
                continue
            entry["age_s"] = age
            entry["alive"] = age <= horizon
            out.append(entry)
        return out

    # ------------------------------------------------------------ observers
    def done_count(self) -> int:
        try:
            return sum(1 for p in self._done.iterdir() if p.suffix == ".json")
        except OSError:
            return 0

    def finished(self) -> bool:
        return self.done_count() >= self.n_tasks

    def results(self) -> Dict[int, Dict[str, Any]]:
        """Every terminal result marker, keyed by cell index."""
        out: Dict[int, Dict[str, Any]] = {}
        if not self._done.exists():
            return out
        for p in sorted(self._done.glob("*.json")):
            try:
                out[int(p.stem)] = json.loads(p.read_text())
            except (ValueError, OSError):
                continue
        return out

    # ----------------------------------------------------------------- stop
    def request_stop(self) -> None:
        """Advisory shutdown marker: idle workers exit their drain loop."""
        (self.root / _STOP).touch()

    def stop_requested(self) -> bool:
        return (self.root / _STOP).exists()


def _atomic_json(path: Path, doc: Dict[str, Any]) -> None:
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, default=str)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
