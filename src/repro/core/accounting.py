"""Per-cell resource accounting: what did this campaign cost?

Every completed cell records wall-clock seconds, CPU seconds, and peak RSS
into the report envelope (``parameter["resources"]``) *and* as metrics on
each data entry — metrics are what the columnar plane turns into dimensions,
so ``campaign-report@v1`` (and ``CampaignFrame.summary``) can aggregate
campaign cost with no extra wiring.

Two probe scopes match the two worker modes:

* ``"thread"`` — cells share one interpreter, so per-cell CPU is the
  *calling thread's* CPU time (``time.thread_time``).  Peak RSS is still the
  process high-watermark (threads share an address space); it is recorded as
  an upper bound, not a per-cell attribution.
* ``"process"`` — each worker process runs one cell at a time, so whole-
  process deltas are exact per-cell attribution: ``os.times`` (user + system,
  **including reaped subprocess children** — a ``DryRunHarness`` cell's real
  work happens in a child interpreter) and ``getrusage`` peak RSS over SELF
  and CHILDREN.
"""

from __future__ import annotations

import copy
import os
import sys
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterator

try:
    import resource as _resource
except ImportError:  # non-POSIX
    _resource = None

RESOURCE_METRICS = ("res_wall_s", "res_cpu_s", "res_max_rss_mb")

# Envelope keys stamped by the execution plane that legitimately differ
# between two otherwise-identical runs (who ran it, when, at what cost,
# and under which observed environment conditions).
VOLATILE_PARAMETERS = ("resources", "task_uid", "worker", "host", "attempt",
                       "env_fingerprint", "fingerprint_drift")


def _peak_rss_mb(scope: str) -> float:
    if _resource is None:
        return 0.0
    rss = _resource.getrusage(_resource.RUSAGE_SELF).ru_maxrss
    if scope == "process":
        rss = max(rss, _resource.getrusage(_resource.RUSAGE_CHILDREN).ru_maxrss)
    # ru_maxrss is KiB on Linux, bytes on macOS.
    return rss / (1024.0 * 1024.0) if sys.platform == "darwin" else rss / 1024.0


@contextmanager
def resource_probe(acct: Dict[str, Any], scope: str = "thread") -> Iterator[Dict[str, Any]]:
    """Measure the wrapped block; fills ``acct`` with the resource metrics
    (always, even when the block raises — a failed cell still cost time)."""
    if scope not in ("thread", "process"):
        raise ValueError(f"unknown resource probe scope {scope!r}")
    t0 = time.perf_counter()
    c0 = os.times() if scope == "process" else time.thread_time()
    try:
        yield acct
    finally:
        wall = time.perf_counter() - t0
        if scope == "process":
            c1 = os.times()
            cpu = ((c1.user - c0.user) + (c1.system - c0.system)
                   + (c1.children_user - c0.children_user)
                   + (c1.children_system - c0.children_system))
        else:
            cpu = time.thread_time() - c0
        acct["res_wall_s"] = wall
        acct["res_cpu_s"] = cpu
        acct["res_max_rss_mb"] = _peak_rss_mb(scope)
        acct["scope"] = scope


def stamp_report(report, acct: Dict[str, Any], *, worker: str = "",
                 worker_mode: str = "thread") -> None:
    """Record one cell's accounting into its report: the full envelope under
    ``parameter["resources"]``, plus the three numeric metrics on every data
    entry so they become columnar dimensions."""
    res = dict(acct)
    res["worker"] = worker
    res["worker_mode"] = worker_mode
    report.parameter["resources"] = res
    for entry in report.data:
        for key in RESOURCE_METRICS:
            if key in acct:
                entry.metrics.setdefault(key, float(acct[key]))


def strip_volatile(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Canonicalize a report dict for cross-run parity comparison: drop
    timestamps, pipeline/job identity, and the resource-accounting fields —
    everything the execution plane legitimately varies between two runs of
    the same campaign.  Used by the parity assertions in tests and
    ``benchmarks/bench_workers.py``."""
    d = copy.deepcopy(doc)
    rep = d.get("reporter", {})
    rep["timestamp"] = 0.0
    rep["pipeline_id"] = ""
    # The environment fingerprint carries volatile observations (load,
    # frequency, thermal) that differ even between back-to-back runs.
    rep["environment"] = {}
    d.get("experiment", {})["timestamp"] = 0.0
    params = d.get("parameter", {})
    for key in VOLATILE_PARAMETERS:
        params.pop(key, None)
    for entry in d.get("data", []):
        entry["job_id"] = ""
        metrics = entry.get("metrics", {})
        for key in RESOURCE_METRICS:
            metrics.pop(key, None)
    return d
