"""Collection registry — binds the decentralized benchmark modules
(``repro.configs``) into one addressable collection (paper §IV-A:
"benchmark repositories may be organized into collection-specific groups").
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union

from repro import configs
from repro.configs import shapes as SH
from repro.core.harness import BenchmarkSpec
from repro.core.readiness import parse_level


def collection(
    system: Union[str, Sequence[str]],
    *,
    archs: Optional[List[str]] = None,
    shapes: Optional[List[str]] = None,
    require_readiness=None,
) -> List[BenchmarkSpec]:
    """All applicable benchmark cells for one system.

    ``system`` may also be a list of systems (or a comma-separated string) —
    the collection then expands into a multi-system campaign: the cross
    product of every applicable cell with every target system, ready for a
    parallel ``run_collection`` (the JUREAP multi-machine setting).

    ``require_readiness`` (a ``Readiness`` level, int, or name) stamps every
    cell with a readiness demand: the execution orchestrator negotiates it
    against the harness capability declaration before dispatch, so a whole
    collection demanding REPRODUCIBLE fails fast on a harness that cannot
    attain it.
    """
    if isinstance(system, str) and "," in system:
        system = [s.strip() for s in system.split(",") if s.strip()]
    if not isinstance(system, str):
        return campaign(system, archs=archs, shapes=shapes,
                        require_readiness=require_readiness)
    require = int(parse_level(require_readiness))
    out: List[BenchmarkSpec] = []
    for arch in archs or configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        for name, shape in SH.SHAPES.items():
            if shapes and name not in shapes:
                continue
            if not SH.applicable(cfg, shape):
                continue
            out.append(BenchmarkSpec(arch=arch, shape=name, system=system,
                                     require_readiness=require))
    return out


def campaign(
    systems: Sequence[str],
    *,
    archs: Optional[List[str]] = None,
    shapes: Optional[List[str]] = None,
    require_readiness=None,
) -> List[BenchmarkSpec]:
    """Multi-system campaign: one collection per system, concatenated in
    system order (cells stay grouped per machine for prefix bookkeeping)."""
    out: List[BenchmarkSpec] = []
    for system in systems:
        out.extend(collection(system, archs=archs, shapes=shapes,
                              require_readiness=require_readiness))
    return out


def collection_info() -> Dict[str, Dict[str, object]]:
    """Human-readable inventory (family, params, applicable shapes)."""
    from repro.models import params as P

    out: Dict[str, Dict[str, object]] = {}
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        out[arch] = {
            "family": cfg.family,
            "layers": cfg.n_layers,
            "d_model": cfg.d_model,
            "params": P.count_params_cfg(cfg),
            "active_params": P.count_params_cfg(cfg, active_only=True),
            "shapes": [s for s in SH.SHAPES if SH.applicable(cfg, SH.SHAPES[s])],
        }
    return out
