"""exaCB core — the paper's primary contribution: protocol, result store,
readiness levels, harness adapters, the three orchestrators, the campaign
scheduler, the incremental columnar metrics plane, analysis, and
energy-launcher injection."""

from repro.core.harness import BenchmarkSpec, ExecHarness, Injections  # noqa: F401
from repro.core.protocol import DataEntry, Experiment, Report, Reporter, new_report  # noqa: F401
from repro.core.readiness import Readiness, classify  # noqa: F401
from repro.core.scheduler import CampaignScheduler, Task, TaskResult  # noqa: F401
from repro.core.store import DirBackend, JsonlBackend, ResultStore  # noqa: F401
from repro.core.columnar import CampaignFrame, ColumnTable, ColumnarIndex, MetricSeries  # noqa: F401
from repro.core.cicd import parse_pipeline_text, run_pipeline  # noqa: F401
