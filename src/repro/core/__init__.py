"""exaCB core — the paper's primary contribution: protocol, result store,
readiness levels, harness adapters, the typed component API (schemas +
registry + ``Campaign`` facade), the orchestrators, the campaign scheduler,
the incremental columnar metrics plane, analysis, and energy-launcher
injection."""

from repro.core.component import (  # noqa: F401
    REGISTRY,
    ComponentInputs,
    ComponentRegistry,
    ComponentSchema,
    InputSpec,
    PipelineError,
)
from repro.core.harness import (  # noqa: F401
    BenchmarkSpec,
    CapabilityError,
    ExecHarness,
    HarnessCapabilities,
    Injections,
    negotiate,
)
from repro.core.protocol import DataEntry, Experiment, Report, Reporter, new_report  # noqa: F401
from repro.core.readiness import Readiness, classify, parse_level  # noqa: F401
from repro.core.scheduler import CampaignScheduler, Task, TaskResult  # noqa: F401
from repro.core.store import DirBackend, JsonlBackend, ResultStore  # noqa: F401
from repro.core.columnar import CampaignFrame, ColumnTable, ColumnarIndex, MetricSeries  # noqa: F401
from repro.core.cicd import parse_pipeline_text, run_pipeline, validate_pipeline  # noqa: F401
from repro.core.api import Campaign  # noqa: F401  (after cicd: api builds on it)
