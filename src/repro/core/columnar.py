"""Incremental columnar metrics plane (paper §IV-F / §V-C at JUREAP scale).

exaCB's analyses — regression gates, time-series, machine comparison,
scaling, exports — all reduce to *per-(prefix, metric) series with a few
dimension filters*.  Re-materializing whole ``Report`` objects and walking
Python dicts per call makes every warm analysis O(history); this module
keeps the same data as contiguous numpy columns so analysis cost is
O(delta) on append and vectorized on read:

* :class:`ColumnTable` — one row per stored ``DataEntry`` with value columns
  (``seq``, ``timestamp``, ``runtime``, per-metric value+presence columns)
  and dictionary-encoded dimension columns (system, variant, queue, job id,
  pipeline id, injection config), plus ``success``/``trusted`` flags and
  node/task/thread counts.
* **Watermark + sidecar** — each table records the store index entries it
  covers (``entry_seqs`` + a ``cover_hash`` over their ``seq:digest`` pairs)
  and the backend fingerprint it was built at, and persists as one compact
  ``.npz`` sidecar via the backend's ``sidecar_path`` hook.  On access:

  - unchanged fingerprint        -> O(1) cache hit (memory or sidecar);
  - appended-only transition with
    an intact covered prefix     -> fetch + encode only the delta;
  - anything else (prune, tamper,
    torn sidecar)                -> one-shot rebuild.

* :class:`ColumnarIndex` — the per-store manager that does the above,
  reachable as ``ResultStore.columnar``.
* :class:`MetricSeries` — the array-native query result consumed by the
  vectorized analysis layer and the regression detectors.
* :class:`CampaignFrame` — a cross-prefix view answering campaign-wide
  questions ("metric X across all 70 prefixes") in one scan.

Column extraction reproduces the report-object semantics *exactly* (runtime
fallback for the ``runtime`` pseudo-metric, success filtering, last-N store
entries, first-appearance grouping order), so every vectorized path is
asserted byte-identical against the report path in ``tests/test_columnar.py``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import duet as _duet
from repro.core import fingerprint as _fp
from repro.core.protocol import is_envelope
from repro.core.store import IndexEntry, ResultStore

# v2: duet/duet_role/fingerprint dimensions + duet_round column.  Older
# sidecars fail the version check in load() and rebuild cleanly.
COLUMNS_VERSION = 2
SIDECAR_NAME = "columns.npz"

# Dictionary-encoded dimension columns (int32 codes into a per-table vocab).
# "duet" is the shared duet_id ("" for non-duet rows), "duet_role" is
# baseline/candidate, "fingerprint" is the environment-class key
# (fingerprint.key_of) so queries can stratify history by runner class.
DIMENSIONS = ("system", "variant", "queue", "job_id", "pipeline", "injection",
              "duet", "duet_role", "fingerprint")

_NUMERIC = ("seq", "timestamp", "runtime", "nodes", "tasks_per_node",
            "threads_per_task", "duet_round")
_FLAGS = ("success", "trusted", "envelope")


def _cover_hash(entries: Sequence[IndexEntry]) -> str:
    """Watermark integrity token: which store entries the columns cover.
    Digests make the hash sensitive to record *content*, so a same-sequence
    rewrite cannot masquerade as the covered history."""
    h = hashlib.sha256()
    for e in entries:
        h.update(f"{e.seq}:{e.digest}\n".encode())
    return h.hexdigest()


def _tuplize(x):
    return tuple(_tuplize(i) for i in x) if isinstance(x, list) else x


@dataclasses.dataclass
class MetricSeries:
    """Array-native series for one metric: aligned ``(seq, timestamp,
    value)`` columns, already filtered.  ``*_points`` materialize the exact
    list shapes the report-object analysis functions produce."""

    metric: str
    seqs: np.ndarray        # int64
    timestamps: np.ndarray  # float64
    values: np.ndarray      # float64

    @property
    def n(self) -> int:
        return int(self.values.size)

    def sorted_by_time(self) -> "MetricSeries":
        """Lexsorted by (timestamp, value) — the exact tuple ordering
        ``sorted()`` gives ``analysis.to_series``, kept as arrays so the
        vectorized detector can consume it without a list round-trip."""
        order = np.lexsort((self.values, self.timestamps))
        return MetricSeries(self.metric, self.seqs[order],
                            self.timestamps[order], self.values[order])

    def time_points(self) -> List[Tuple[float, float]]:
        """``sorted((timestamp, value))`` — ``analysis.to_series`` parity."""
        s = self.sorted_by_time()
        return list(zip(s.timestamps.tolist(), s.values.tolist()))

    def seq_points(self) -> List[Tuple[int, float]]:
        """``(store sequence, value)`` in store order — gate-series parity."""
        return list(zip(self.seqs.tolist(), self.values.tolist()))


class ColumnTable:
    """Immutable columnar snapshot of one prefix (see module docstring)."""

    def __init__(
        self,
        prefix: str,
        columns: Dict[str, np.ndarray],
        codes: Dict[str, np.ndarray],
        vocabs: Dict[str, List[str]],
        metric_names: List[str],
        metric_values: np.ndarray,   # (n_metrics, n_rows) float64
        metric_present: np.ndarray,  # (n_metrics, n_rows) bool
        extras: Dict[int, Dict[str, Any]],
        entry_seqs: np.ndarray,      # int64, every covered index entry
        cover_hash: str,
        fingerprint: Tuple,
    ):
        self.prefix = prefix
        self.columns = columns
        self.codes = codes
        self.vocabs = vocabs
        self.metric_names = metric_names
        self.metric_values = metric_values
        self.metric_present = metric_present
        self.extras = extras
        self.entry_seqs = entry_seqs
        self.cover_hash = cover_hash
        self.fingerprint = fingerprint
        self._metric_idx = {m: i for i, m in enumerate(metric_names)}
        self._vocab_idx = {d: {v: i for i, v in enumerate(vocabs[d])}
                           for d in DIMENSIONS}
        # Derived-result memo: a table is immutable for its lifetime (any
        # store change yields a *new* table), so consumers (time-series
        # analysis, exports) key computed artifacts here and inherit exactly
        # the right invalidation — warm unchanged analyses become O(1)
        # lookups.  Treat cached values as frozen.
        self.cache: Dict[Any, Any] = {}

    # ---- shape ----
    @property
    def n_rows(self) -> int:
        return int(self.columns["seq"].size)

    @property
    def n_entries(self) -> int:
        """Covered store index entries — the incremental watermark count
        (entries without data rows still advance it)."""
        return int(self.entry_seqs.size)

    @property
    def watermark(self) -> int:
        """Highest covered store sequence (-1 when empty)."""
        return int(self.entry_seqs[-1]) if self.entry_seqs.size else -1

    # ---- construction ----
    @staticmethod
    def build(prefix: str, pairs, index: Sequence[IndexEntry],
              fingerprint: Tuple) -> "ColumnTable":
        return _encode(prefix, pairs, index, fingerprint, base=None)

    def extended(self, pairs, index: Sequence[IndexEntry],
                 fingerprint: Tuple) -> "ColumnTable":
        """New table = these columns + encoded delta rows; O(delta) encode
        plus array concatenation."""
        return _encode(self.prefix, pairs, index, fingerprint, base=self)

    def with_fingerprint(self, fingerprint: Tuple) -> "ColumnTable":
        """Same content observed under a newer fingerprint (e.g. a torn
        trailing line grew the file without completing a record)."""
        t = ColumnTable(
            self.prefix, self.columns, self.codes, self.vocabs,
            self.metric_names, self.metric_values, self.metric_present,
            self.extras, self.entry_seqs, self.cover_hash, fingerprint,
        )
        t.cache = self.cache  # identical content — derived results survive
        return t

    # ---- metric access (report-object semantics, vectorized) ----
    def _metric_column(self, metric: str, runtime_fallback: bool = True):
        i = self._metric_idx.get(metric)
        if i is None:
            vals = np.zeros(self.n_rows, dtype=np.float64)
            present = np.zeros(self.n_rows, dtype=bool)
        else:
            vals, present = self.metric_values[i], self.metric_present[i]
        if runtime_fallback and metric == "runtime":
            # Entries without an explicit "runtime" metric fall back to the
            # Table-I runtime field — exactly `to_series`/`_series` behavior.
            vals = np.where(present, vals, self.columns["runtime"])
            present = np.ones(self.n_rows, dtype=bool)
        return vals, present

    def _dim_code(self, dim: str, value: str) -> int:
        return self._vocab_idx[dim].get(value, -1)

    def series(
        self,
        metric: str,
        *,
        success_only: bool = False,
        trusted_only: bool = False,
        runtime_fallback: bool = True,
        include_envelopes: bool = True,
        since: Optional[float] = None,
        until: Optional[float] = None,
        system: Optional[str] = None,
        variant: Optional[str] = None,
        pipelines: Optional[Sequence[str]] = None,
        fingerprint: Optional[str] = None,
        last_entries: Optional[int] = None,
    ) -> MetricSeries:
        """Filtered series for one metric, in store order.

        ``last_entries=N`` keeps rows from the newest N covered *store
        entries* (not points) — the columnar twin of
        ``query_with_entries(last=N)``.  ``include_envelopes=False`` drops
        rows carried by envelope reports (baseline/gate bookkeeping, which
        mirror payload numerics into their metrics) — the report-path
        analyses do not filter these, so parity consumers keep the default.
        """
        vals, mask = self._metric_column(metric, runtime_fallback)
        mask = mask.copy()
        if success_only:
            mask &= self.columns["success"]
        if trusted_only:
            mask &= self.columns["trusted"]
        if not include_envelopes:
            mask &= ~self.columns["envelope"]
        if since is not None:
            mask &= self.columns["timestamp"] >= since
        if until is not None:
            mask &= self.columns["timestamp"] <= until
        if system is not None:
            mask &= self.codes["system"] == self._dim_code("system", system)
        if variant is not None:
            mask &= self.codes["variant"] == self._dim_code("variant", variant)
        if pipelines is not None:
            codes = [self._dim_code("pipeline", p) for p in pipelines]
            mask &= np.isin(self.codes["pipeline"], codes)
        if fingerprint is not None:
            mask &= (self.codes["fingerprint"]
                     == self._dim_code("fingerprint", fingerprint))
        if last_entries is not None:
            last = int(last_entries)
            if last <= 0:
                mask &= False
            elif self.entry_seqs.size > last:
                mask &= self.columns["seq"] >= int(self.entry_seqs[-last])
        return MetricSeries(metric, self.columns["seq"][mask],
                            self.columns["timestamp"][mask], vals[mask])

    def metrics(self) -> List[str]:
        """Metric names with at least one stored value."""
        return list(self.metric_names)

    def seq_fingerprints(self) -> Dict[int, str]:
        """{store seq: environment-class key} for every covered row ("" for
        untagged reports) — the gate uses it to stratify baselines and to
        detect drift.  Memoized per table."""
        hit = self.cache.get("seq_fingerprints")
        if hit is None:
            vocab = self.vocabs["fingerprint"]
            hit = {int(s): vocab[int(c)]
                   for s, c in zip(self.columns["seq"].tolist(),
                                   self.codes["fingerprint"].tolist())}
            self.cache["seq_fingerprints"] = hit
        return hit

    def duet_pairs(
        self,
        metric: str,
        *,
        success_only: bool = True,
        last_entries: Optional[int] = None,
    ) -> List["_duet.DuetPair"]:
        """Completed duet rounds for one metric, sorted by (candidate seq,
        round).  Semantics mirror :func:`duet.pairs_from_reports` exactly
        (success filtering, runtime fallback, lowest-seq-wins per slot —
        rows are seq-ascending, so duplicate slots from a fencing gap are
        ignored) so both gate paths judge identical pairs."""
        key = ("duet_pairs", metric, success_only, last_entries)
        hit = self.cache.get(key)
        if hit is not None:
            return list(hit)
        vals, mask = self._metric_column(metric, runtime_fallback=True)
        mask = mask.copy()
        if success_only:
            mask &= self.columns["success"]
        empty = self._vocab_idx["duet"].get("")
        if empty is not None:
            mask &= self.codes["duet"] != empty
        if last_entries is not None:
            last = int(last_entries)
            if last <= 0:
                mask &= False
            elif self.entry_seqs.size > last:
                mask &= self.columns["seq"] >= int(self.entry_seqs[-last])
        slots: _duet.Slots = {}
        for i in np.nonzero(mask)[0].tolist():
            did = self.vocabs["duet"][int(self.codes["duet"][i])]
            if not did:
                continue
            role = self.vocabs["duet_role"][int(self.codes["duet_role"][i])]
            slot = slots.setdefault((did, int(self.columns["duet_round"][i])), {})
            slot.setdefault(role, (float(vals[i]), int(self.columns["seq"][i]),
                                   float(self.columns["timestamp"][i])))
        out = _duet.pairs_from_slots(slots)
        self.cache[key] = out
        return list(out)

    def system_groups(
        self, metric: str, *, system: Optional[str] = None
    ) -> List[Tuple[str, np.ndarray]]:
        """(system, values) groups in first-appearance order — the exact
        grouping ``analysis.compare_systems`` builds by dict insertion."""
        key = ("system_groups", metric, system)
        hit = self.cache.get(key)
        if hit is not None:
            return hit
        vals, mask = self._metric_column(metric, runtime_fallback=True)
        if system is not None:
            mask = mask & (self.codes["system"] == self._dim_code("system", system))
        codes = self.codes["system"][mask]
        vals = vals[mask]
        if codes.size == 0:
            out: List[Tuple[str, np.ndarray]] = []
        else:
            uniq, first = np.unique(codes, return_index=True)
            order = np.argsort(first, kind="stable")
            out = [(self.vocabs["system"][int(c)], vals[codes == c])
                   for c in uniq[order]]
        self.cache[key] = out
        return out

    def scaling_points(self, metric: str) -> Dict[int, float]:
        """{nodes: value} with last-write-wins per node count —
        ``PostProcessingOrchestrator.scalability`` parity (no runtime
        fallback: only entries carrying the metric participate)."""
        vals, mask = self._metric_column(metric, runtime_fallback=False)
        nodes = self.columns["nodes"][mask]
        return dict(zip(nodes.tolist(), vals[mask].tolist()))

    def injection_comparison(self, metric: str, knob: str) -> Dict[str, float]:
        """Metric as a function of an injected knob value (Fig. 6).  The
        injection config is dictionary-encoded per row, so the JSON decode
        happens once per *unique* config, not once per report."""
        vals, mask = self._metric_column(metric, runtime_fallback=False)
        codes = self.codes["injection"][mask]
        key_of: Dict[int, str] = {}
        for c in np.unique(codes).tolist():
            inj = json.loads(self.vocabs["injection"][c])
            key_of[c] = str(inj.get("env", {}).get(
                knob, inj.get("overrides", {}).get(knob, "default")))
        out: Dict[str, float] = {}
        for c, v in zip(codes.tolist(), vals[mask].tolist()):
            out[key_of[c]] = v
        return out

    def job_records(self) -> List[Dict[str, Any]]:
        """LLview-style job records (one per row) reconstructed from the
        columns — no report is parsed.  Memoized per table (a fresh outer
        list is returned each call; treat the records as frozen)."""
        hit = self.cache.get("job_records")
        if hit is not None:
            return list(hit)
        cols = self.columns
        n = self.n_rows
        jobs = [self.vocabs["job_id"][c] for c in self.codes["job_id"].tolist()]
        systems = [self.vocabs["system"][c] for c in self.codes["system"].tolist()]
        queues = [self.vocabs["queue"][c] for c in self.codes["queue"].tolist()]
        nodes = cols["nodes"].tolist()
        runtime = cols["runtime"].tolist()
        success = cols["success"].tolist()
        ts = cols["timestamp"].tolist()
        mvals = [v.tolist() for v in self.metric_values]
        mpres = [p.tolist() for p in self.metric_present]
        out = []
        for i in range(n):
            metrics = {m: mvals[j][i]
                       for j, m in enumerate(self.metric_names) if mpres[j][i]}
            metrics.update(self.extras.get(i, {}))
            out.append({
                "jobid": jobs[i],
                "system": systems[i],
                "queue": queues[i],
                "nodes": nodes[i],
                "runtime": runtime[i],
                "state": "COMPLETED" if success[i] else "FAILED",
                "ts": ts[i],
                "metrics": metrics,
            })
        self.cache["job_records"] = out
        return list(out)

    # ---- sidecar persistence ----
    def save(self, path: Path) -> None:
        header = {
            "version": COLUMNS_VERSION,
            "prefix": self.prefix,
            "cover_hash": self.cover_hash,
            "fingerprint": self.fingerprint,
            "vocabs": self.vocabs,
            "metrics": self.metric_names,
            "extras": {str(k): v for k, v in self.extras.items()},
        }
        arrays: Dict[str, np.ndarray] = {
            "header": np.array(json.dumps(header, default=str)),
            "entry_seqs": self.entry_seqs,
            "metric_values": self.metric_values,
            "metric_present": self.metric_present,
        }
        for k, arr in self.columns.items():
            arrays[f"col_{k}"] = arr
        for d, arr in self.codes.items():
            arrays[f"code_{d}"] = arr
        # Binary streaming twin of store._atomic_write (np.savez needs the
        # open file object, so the text helper cannot be reused directly).
        fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    @staticmethod
    def load(path: Path) -> Optional["ColumnTable"]:
        """Parse a sidecar; any inconsistency returns None (-> rebuild)."""
        try:
            with np.load(path, allow_pickle=False) as z:
                header = json.loads(str(z["header"]))
                if header.get("version") != COLUMNS_VERSION:
                    return None
                columns = {k: z[f"col_{k}"] for k in _NUMERIC + _FLAGS}
                codes = {d: z[f"code_{d}"] for d in DIMENSIONS}
                return ColumnTable(
                    prefix=str(header["prefix"]),
                    columns=columns,
                    codes=codes,
                    vocabs={d: list(header["vocabs"][d]) for d in DIMENSIONS},
                    metric_names=list(header["metrics"]),
                    metric_values=z["metric_values"],
                    metric_present=z["metric_present"],
                    extras={int(k): v for k, v in header["extras"].items()},
                    entry_seqs=z["entry_seqs"],
                    cover_hash=str(header["cover_hash"]),
                    fingerprint=_tuplize(header["fingerprint"]),
                )
        except Exception:  # noqa: BLE001 — a bad sidecar must only cost a rebuild
            return None


def _encode(prefix: str, pairs, index: Sequence[IndexEntry],
            fingerprint: Tuple, base: Optional[ColumnTable]) -> ColumnTable:
    """Encode (entry, report) pairs into columns, appended to ``base``."""
    vocabs = ({d: list(base.vocabs[d]) for d in DIMENSIONS} if base
              else {d: [] for d in DIMENSIONS})
    vmaps = {d: {v: i for i, v in enumerate(vocabs[d])} for d in DIMENSIONS}
    metric_names = list(base.metric_names) if base else []
    midx = {m: i for i, m in enumerate(metric_names)}

    def code(dim: str, value: str) -> int:
        c = vmaps[dim].get(value)
        if c is None:
            c = vmaps[dim][value] = len(vocabs[dim])
            vocabs[dim].append(value)
        return c

    cols: Dict[str, list] = {k: [] for k in _NUMERIC + _FLAGS}
    codes: Dict[str, list] = {d: [] for d in DIMENSIONS}
    scatter: Dict[str, List[Tuple[int, float]]] = {}
    extras: Dict[int, Dict[str, Any]] = {}
    base_rows = base.n_rows if base else 0
    row = 0
    for entry, report in pairs:
        inj = json.dumps(report.parameter.get("injections", {}),
                         sort_keys=True, default=str)
        dctx = _duet.context_of(report)
        duet_id = str(dctx["duet_id"]) if dctx else ""
        duet_role = str(dctx.get("role", "")) if dctx else ""
        duet_round = int(dctx.get("round", -1)) if dctx else -1
        fp_key = _fp.key_of(report)
        for d in report.data:
            cols["seq"].append(entry.seq)
            cols["timestamp"].append(report.experiment.timestamp)
            cols["runtime"].append(d.runtime)
            cols["nodes"].append(d.nodes)
            cols["tasks_per_node"].append(d.tasks_per_node)
            cols["threads_per_task"].append(d.threads_per_task)
            cols["duet_round"].append(duet_round)
            cols["success"].append(bool(d.success))
            cols["trusted"].append(bool(report.reporter.chain_of_trust))
            cols["envelope"].append(is_envelope(report))
            codes["system"].append(code("system", report.experiment.system))
            codes["variant"].append(code("variant", report.experiment.variant))
            codes["queue"].append(code("queue", d.queue))
            codes["job_id"].append(code("job_id", d.job_id))
            codes["pipeline"].append(code("pipeline", report.reporter.pipeline_id))
            codes["injection"].append(code("injection", inj))
            codes["duet"].append(code("duet", duet_id))
            codes["duet_role"].append(code("duet_role", duet_role))
            codes["fingerprint"].append(code("fingerprint", fp_key))
            for k, v in d.metrics.items():
                try:
                    fv = float(v)
                except (TypeError, ValueError):
                    # Non-numeric metric: preserved verbatim in the sparse
                    # extras map so job_records stays lossless.
                    extras.setdefault(base_rows + row, {})[k] = v
                    continue
                if type(v) is not float:
                    # int/bool/str-numeric: the float64 column serves the
                    # analyses, but the original typed value also rides in
                    # extras so exports round-trip exactly (5 stays 5, not
                    # 5.0).
                    extras.setdefault(base_rows + row, {})[k] = v
                if k not in midx:
                    midx[k] = len(metric_names)
                    metric_names.append(k)
                scatter.setdefault(k, []).append((row, fv))
            row += 1

    n_new = row
    new_cols = {
        "seq": np.asarray(cols["seq"], dtype=np.int64),
        "timestamp": np.asarray(cols["timestamp"], dtype=np.float64),
        "runtime": np.asarray(cols["runtime"], dtype=np.float64),
        "nodes": np.asarray(cols["nodes"], dtype=np.int64),
        "tasks_per_node": np.asarray(cols["tasks_per_node"], dtype=np.int64),
        "threads_per_task": np.asarray(cols["threads_per_task"], dtype=np.int64),
        "duet_round": np.asarray(cols["duet_round"], dtype=np.int64),
        "success": np.asarray(cols["success"], dtype=bool),
        "trusted": np.asarray(cols["trusted"], dtype=bool),
        "envelope": np.asarray(cols["envelope"], dtype=bool),
    }
    new_codes = {d: np.asarray(codes[d], dtype=np.int32) for d in DIMENSIONS}
    new_vals = np.zeros((len(metric_names), n_new), dtype=np.float64)
    new_pres = np.zeros((len(metric_names), n_new), dtype=bool)
    for m, hits in scatter.items():
        i = midx[m]
        rows = np.fromiter((r for r, _ in hits), dtype=np.int64, count=len(hits))
        new_vals[i, rows] = np.fromiter((v for _, v in hits), dtype=np.float64,
                                        count=len(hits))
        new_pres[i, rows] = True

    if base is not None:
        out_cols = {k: np.concatenate([base.columns[k], new_cols[k]])
                    for k in new_cols}
        out_codes = {d: np.concatenate([base.codes[d], new_codes[d]])
                     for d in DIMENSIONS}
        old_m = len(base.metric_names)
        old_vals, old_pres = base.metric_values, base.metric_present
        if len(metric_names) > old_m:  # metrics first seen in the delta
            pad = (len(metric_names) - old_m, base_rows)
            old_vals = np.concatenate([old_vals, np.zeros(pad, np.float64)])
            old_pres = np.concatenate([old_pres, np.zeros(pad, bool)])
        metric_values = np.concatenate([old_vals, new_vals], axis=1)
        metric_present = np.concatenate([old_pres, new_pres], axis=1)
        extras = {**base.extras, **extras}
    else:
        out_cols, out_codes = new_cols, new_codes
        metric_values, metric_present = new_vals, new_pres

    return ColumnTable(
        prefix=prefix,
        columns=out_cols,
        codes=out_codes,
        vocabs=vocabs,
        metric_names=metric_names,
        metric_values=metric_values,
        metric_present=metric_present,
        extras=extras,
        entry_seqs=np.asarray([e.seq for e in index], dtype=np.int64),
        cover_hash=_cover_hash(index),
        fingerprint=fingerprint,
    )


class ColumnarIndex:
    """Per-store manager of incremental column tables (``store.columnar``).

    Thread-safe; ``stats`` counts cache behavior so tests (and operators)
    can assert the watermark semantics: an append extends, an unchanged
    fingerprint hits, a prune/mutation rebuilds exactly once.
    """

    # Persist an extended table only once this many entries have accumulated
    # past the last written sidecar: rewriting the .npz is O(history), so a
    # 1-row append must not pay full-history disk I/O on every refresh.  The
    # in-memory table is always current; a lagging sidecar just means the
    # next cold start does one small incremental extend from its watermark.
    SAVE_EVERY = 64

    def __init__(self, store: ResultStore):
        self.store = store
        self._mem: Dict[str, ColumnTable] = {}
        self._persisted: Dict[str, int] = {}  # prefix -> n_entries on disk
        self._locks: Dict[str, threading.Lock] = {}
        self._guard = threading.Lock()
        self.stats = {"hits": 0, "incremental": 0, "rebuilds": 0,
                      "sidecar_loads": 0, "sidecar_saves": 0}

    def _prefix_lock(self, prefix: str) -> threading.Lock:
        with self._guard:
            return self._locks.setdefault(prefix, threading.Lock())

    def _sidecar(self, prefix: str) -> Path:
        return self.store.backend.sidecar_path(prefix, SIDECAR_NAME)

    def table(self, prefix: str) -> ColumnTable:
        """The current column table for one prefix (hit / extend / rebuild
        per the module docstring)."""
        backend = self.store.backend
        fp = backend.fingerprint(prefix)
        with self._guard:
            mem = self._mem.get(prefix)
        if mem is not None and mem.fingerprint == fp:
            self.stats["hits"] += 1
            return mem
        with self._prefix_lock(prefix):
            with self._guard:
                mem = self._mem.get(prefix)
            fp = backend.fingerprint(prefix)
            if mem is not None and mem.fingerprint == fp:
                self.stats["hits"] += 1
                return mem
            base = mem
            if base is None:
                base = ColumnTable.load(self._sidecar(prefix))
                if base is not None:
                    self.stats["sidecar_loads"] += 1
                    self._persisted[prefix] = base.n_entries
            table = persist = None
            if base is not None and base.fingerprint == fp:
                table = base  # sidecar written by a finished writer — trust it
            index = self.store.index(prefix) if table is None else None
            if (table is None and base is not None
                    and base.n_entries <= len(index)
                    and backend.appended_only(base.fingerprint, fp)
                    and _cover_hash(index[:base.n_entries]) == base.cover_hash):
                fresh = index[base.n_entries:]
                if fresh:
                    pairs = self.store.fetch_entries(prefix, fresh)
                    table = base.extended(pairs, index, fp)
                    self.stats["incremental"] += 1
                    # Deferred persistence (see SAVE_EVERY).
                    behind = table.n_entries - self._persisted.get(prefix, 0)
                    if behind >= self.SAVE_EVERY:
                        persist = table
                else:
                    table = base.with_fingerprint(fp)
            if table is None:
                pairs = self.store.fetch_entries(prefix, index)
                table = persist = ColumnTable.build(prefix, pairs, index, fp)
                self.stats["rebuilds"] += 1
            # Empty tables are not persisted: a query for a prefix that was
            # never written must not materialize backend state for it.
            if persist is not None and persist.n_entries:
                try:
                    self.save(persist)
                    self._persisted[prefix] = persist.n_entries
                    self.stats["sidecar_saves"] += 1
                except OSError:
                    pass  # read-only deployment: memory cache still serves
            with self._guard:
                self._mem[prefix] = table
            return table

    def save(self, table: ColumnTable) -> None:
        path = self._sidecar(table.prefix)
        path.parent.mkdir(parents=True, exist_ok=True)
        table.save(path)

    def flush(self, prefix: Optional[str] = None) -> None:
        """Force-persist in-memory tables whose sidecar lags (deferred by
        ``SAVE_EVERY``) — e.g. before process shutdown."""
        with self._guard:
            tables = [t for p, t in self._mem.items()
                      if prefix is None or p == prefix]
        for t in tables:
            if t.n_entries and self._persisted.get(t.prefix, 0) != t.n_entries:
                self.save(t)
                self._persisted[t.prefix] = t.n_entries
                self.stats["sidecar_saves"] += 1

    def series(self, prefix: str, metric: str, **kw) -> MetricSeries:
        return self.table(prefix).series(metric, **kw)

    def watermark(self, prefix: str) -> int:
        """Highest store seq covered by the prefix's column table (−1 when
        empty).  The daemon's watch trigger compares this against its saved
        mark: an advanced watermark means new measurements landed upstream.
        Refreshing the table is a pure fingerprint check when unchanged, so
        polling this every tick is cheap."""
        return self.table(prefix).watermark

    def frame(self, prefixes: Optional[Sequence[str]] = None) -> "CampaignFrame":
        return CampaignFrame(self.store, prefixes=prefixes)


class CampaignFrame:
    """Cross-prefix columnar view (paper §IV-F: system-wide analysis over
    the full JUREAP collection).  One scan touches each prefix's column
    table exactly once; warm calls are pure fingerprint checks."""

    def __init__(self, store: ResultStore,
                 prefixes: Optional[Sequence[str]] = None):
        self.store = store
        self._prefixes = list(prefixes) if prefixes is not None else None

    def prefixes(self) -> List[str]:
        if self._prefixes is not None:
            return list(self._prefixes)
        return self.store.prefixes()

    def tables(self) -> Dict[str, ColumnTable]:
        return {p: self.store.columnar.table(p) for p in self.prefixes()}

    def series(self, metric: str, *, include_envelopes: bool = False,
               **kw) -> Dict[str, MetricSeries]:
        """{prefix: series} for every prefix that has any matching points.

        Unlike the single-prefix parity paths, campaign-wide queries skip
        envelope rows by default: a default (all-prefix) frame sweeps the
        baseline/gate bookkeeping prefixes too, and their envelope rows
        (runtime 0.0, mirrored payload numerics) would otherwise pollute
        campaign summaries of e.g. ``runtime``.
        """
        out = {}
        for p, t in self.tables().items():
            s = t.series(metric, include_envelopes=include_envelopes, **kw)
            if s.n:
                out[p] = s
        return out

    def summary(self, metric: str, *, success_only: bool = True,
                **kw) -> Dict[str, Dict[str, float]]:
        """Per-prefix summary statistics of one metric across the campaign —
        the 'metric X across all 70 prefixes' query as one vectorized pass
        (envelope bookkeeping rows excluded; see ``series``)."""
        from repro.core import analysis

        return {p: analysis.summary_stats(s.values)
                for p, s in self.series(metric, success_only=success_only,
                                        **kw).items()}

    def compare_systems(self, selectors: Sequence[Dict[str, str]],
                        metric: str) -> Dict[str, Dict[str, float]]:
        """``analysis.compare_systems`` over many prefixes without report
        objects; selector order and first-appearance grouping match the
        report path exactly."""
        from repro.core import analysis

        groups: Dict[str, List[np.ndarray]] = {}
        for sel in selectors:
            t = self.store.columnar.table(sel["prefix"])
            for sysname, arr in t.system_groups(metric,
                                                system=sel.get("system")):
                groups.setdefault(sysname, []).append(arr)
        return {s: analysis.summary_stats(np.concatenate(arrs))
                for s, arrs in groups.items()}

    def watermarks(self) -> Dict[str, int]:
        """Per-prefix covered store sequence — campaign freshness at a
        glance."""
        return {p: t.watermark for p, t in self.tables().items()}
