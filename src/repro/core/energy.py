"""Energy-aware benchmarking (paper §VI-B, Figs. 8/9) — the jpwr analogue.

The paper obtains energy-to-solution by *injecting* an energy-aware launcher
through the platform configuration, without modifying benchmarks.  Here the
launcher wraps the step callable; on real TPUs it would read PMIC counters,
on this CPU container it combines measured wall time with an analytic chip
power model.  Scope trimming (excluding start-up / wind-down, Fig. 8's black
bars) and the frequency sweep (Fig. 9 sweet-spot search) are implemented
exactly as described.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.hardware import ChipSpec


@dataclasses.dataclass(frozen=True)
class EnergyEstimate:
    step_time_s: float
    power_w: float           # per chip
    energy_j: float          # total over all chips
    util_compute: float
    util_memory: float

    def metrics(self) -> Dict[str, float]:
        return {
            "energy_to_solution_j": self.energy_j,
            "avg_power_w": self.power_w,
            "util_compute": self.util_compute,
            "util_memory": self.util_memory,
        }


def power_model(chip: ChipSpec, util_compute: float, util_memory: float, freq_scale: float = 1.0) -> float:
    """Per-chip power: idle + dynamic compute (~f^3 at fixed voltage scaling
    approximation) + HBM traffic term."""
    uc = min(max(util_compute, 0.0), 1.0)
    um = min(max(util_memory, 0.0), 1.0)
    return (
        chip.power_idle_w
        + chip.power_peak_compute_w * uc * freq_scale**3
        + chip.power_peak_hbm_w * um
    )


def estimate_from_roofline(
    chip: ChipSpec,
    *,
    t_compute: float,
    t_memory: float,
    t_collective: float,
    n_chips: int,
    freq_scale: float = 1.0,
) -> EnergyEstimate:
    """Energy from the three roofline terms (dry-run path).

    Step time = max(terms) with compute time stretched by 1/freq; utilization
    of each resource = its term / step time.
    """
    tc = t_compute / freq_scale
    step = max(tc, t_memory, t_collective, 1e-12)
    uc, um = tc / step, t_memory / step
    p = power_model(chip, uc, um, freq_scale)
    return EnergyEstimate(
        step_time_s=step,
        power_w=p,
        energy_j=p * step * n_chips,
        util_compute=uc,
        util_memory=um,
    )


def frequency_sweep(
    chip: ChipSpec,
    *,
    t_compute: float,
    t_memory: float,
    t_collective: float,
    n_chips: int,
    freqs: Sequence[float] = (0.6, 0.7, 0.8, 0.9, 1.0, 1.1),
) -> Dict[float, EnergyEstimate]:
    """Fig. 9: energy-to-solution across frequency scaling; the minimum is
    the energy sweet spot."""
    return {
        f: estimate_from_roofline(
            chip,
            t_compute=t_compute,
            t_memory=t_memory,
            t_collective=t_collective,
            n_chips=n_chips,
            freq_scale=f,
        )
        for f in freqs
    }


def sweet_spot(sweep: Dict[float, EnergyEstimate]) -> float:
    return min(sweep, key=lambda f: sweep[f].energy_j)


# ---------------------------------------------------------------------------
# Power-trace scope trimming (Fig. 8 black bars)
# ---------------------------------------------------------------------------

def trim_scope(
    trace: Sequence[float],
    *,
    threshold_frac: float = 0.5,
    sustain: int = 3,
) -> Tuple[int, int]:
    """Semi-automatic measurement scope: first/last index where power is
    sustained above ``threshold_frac`` of (peak - idle) above idle.

    Returns (start, end) — callers may adjust manually (the paper keeps a
    human-verification step).  Excluding ramp phases systematically
    *underestimates* energy; we preserve that documented bias.
    """
    t = np.asarray(trace, dtype=np.float64)
    if t.size == 0:
        return 0, 0
    idle, peak = float(np.min(t)), float(np.max(t))
    thr = idle + threshold_frac * (peak - idle)
    above = t >= thr
    start, end = 0, len(t)
    run = 0
    for i, a in enumerate(above):
        run = run + 1 if a else 0
        if run >= sustain:
            start = i - sustain + 1
            break
    run = 0
    for i in range(len(t) - 1, -1, -1):
        run = run + 1 if above[i] else 0
        if run >= sustain:
            end = i + sustain
            break
    return start, max(end, start + 1)


def synth_power_trace(
    chip: ChipSpec,
    *,
    steady_power: float,
    n_samples: int = 64,
    ramp: int = 8,
    seed: int = 0,
) -> List[float]:
    """Synthesize a per-chip power trace with start-up/wind-down ramps —
    used by examples/tests to exercise the Fig. 8 pipeline."""
    rng = np.random.default_rng(seed)
    body = n_samples - 2 * ramp
    up = np.linspace(chip.power_idle_w, steady_power, ramp, endpoint=False)
    mid = steady_power + rng.normal(0, steady_power * 0.02, size=body)
    down = np.linspace(steady_power, chip.power_idle_w, ramp)
    return list(np.concatenate([up, mid, down]))


def scoped_energy(trace: Sequence[float], dt_s: float) -> Dict[str, float]:
    """Energy within the auto-trimmed scope of a power trace."""
    s, e = trim_scope(trace)
    seg = np.asarray(trace[s:e], dtype=np.float64)
    return {
        "scope_start": float(s),
        "scope_end": float(e),
        "scoped_energy_j": float(np.sum(seg) * dt_s),
        "scoped_avg_power_w": float(np.mean(seg)) if seg.size else 0.0,
    }


# ---------------------------------------------------------------------------
# Launcher injection (the jpwr wrapper)
# ---------------------------------------------------------------------------

def energy_launcher(chip: ChipSpec, n_chips: int = 1) -> Callable[[Callable], Callable]:
    """Returns a launcher that wraps a step fn with energy measurement.

    Injected via ``Injections.launcher`` — the benchmark itself is unchanged
    (the paper's key claim for incremental instrumentation).  Metrics land on
    ``wrapped.exacb_metrics`` which the harness folds into the report.
    """

    def launcher(step_fn: Callable) -> Callable:
        def wrapped(*a, **kw):
            t0 = time.perf_counter()
            out = step_fn(*a, **kw)
            dt = time.perf_counter() - t0
            # Wall-clock measured; utilization unknown on CPU -> assume
            # compute-dominated (documented approximation).
            p = power_model(chip, 1.0, 0.3)
            wrapped.exacb_metrics = {
                "energy_to_solution_j": p * dt * n_chips,
                "avg_power_w": p,
                "measured_wall_s": dt,
            }
            return out

        wrapped.exacb_metrics = {}
        wrapped.__name__ = f"energy_launcher({chip.name})"
        return wrapped

    launcher.__name__ = "energy_launcher"
    return launcher
