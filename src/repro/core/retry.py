"""Shared transient-vs-terminal error taxonomy and bounded retry policy.

Every filesystem touch in the execution plane — store appends, queue
claims, heartbeats, reclaim journaling — crosses a trust boundary where a
shared exascale filesystem can return ``EIO`` on a healthy path or
``ENOSPC`` that clears a second later.  Before this module each call site
improvised its own ``except OSError`` policy; now they all share one
taxonomy:

* **transient** — worth retrying with backoff (``EIO``, ``ENOSPC``,
  ``EAGAIN``, ``EINTR``, ``ETIMEDOUT``, ``ESTALE``, ``EBUSY``).  These are
  the storage-fabric hiccups the JUPITER-class production partitions throw.
* **terminal** — protocol signals or real misconfiguration that a retry
  would only mask.  ``EEXIST``/``ENOENT`` are load-bearing here: the queue
  uses ``O_EXCL`` creates and missing-lease checks as its arbitration
  protocol, so blindly retrying them would convert a lost race into a
  livelock.

:func:`call_with_retry` is the one retry loop: bounded attempts,
exponential backoff, and deterministic decorrelated jitter (seeded, so a
chaos replay schedules identical sleeps).  Counters feed the robustness
view in ``daemon-status`` via :func:`retry_counters`.

See ``docs/failure_model.md`` for the full failure taxonomy.
"""

from __future__ import annotations

import dataclasses
import errno
import random
import threading
import time
from typing import Any, Callable, Dict, Optional

#: Errnos worth retrying: storage-fabric and contention hiccups.
TRANSIENT_ERRNOS = frozenset({
    errno.EIO,
    errno.ENOSPC,
    errno.EAGAIN,
    errno.EINTR,
    errno.ETIMEDOUT,
    errno.ESTALE,
    errno.EBUSY,
    errno.EDQUOT,
    errno.ENFILE,
    errno.EMFILE,
})

#: Errnos that are protocol signals (O_EXCL arbitration, missing-lease
#: checks) or genuine misconfiguration — never blind-retried.
TERMINAL_ERRNOS = frozenset({
    errno.ENOENT,
    errno.EEXIST,
    errno.ENOTDIR,
    errno.EISDIR,
    errno.EACCES,
    errno.EPERM,
    errno.EROFS,
    errno.ENAMETOOLONG,
    errno.EINVAL,
})


def is_transient(exc: BaseException) -> bool:
    """True when ``exc`` is an OSError the taxonomy classes as retryable.

    ``FileNotFoundError``/``FileExistsError`` (and anything else carrying a
    terminal errno) answer False even though they subclass OSError — the
    queue uses them as arbitration signals, not failures.
    """
    if not isinstance(exc, OSError):
        return False
    code = exc.errno
    if code in TERMINAL_ERRNOS:
        return False
    return code in TRANSIENT_ERRNOS


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with decorrelated jitter.

    ``delay(attempt, rng)`` for attempt ``k`` (0-based, the delay *after*
    failure ``k+1``) draws uniformly from ``[base·factor^k / 2,
    base·factor^k]``, clamped to ``max_s`` — the classic "equal jitter"
    shape: bounded above for liveness, spread below to decorrelate
    contending workers.
    """

    tries: int = 4          # total attempts (1 initial + tries-1 retries)
    base_s: float = 0.02
    factor: float = 2.0
    max_s: float = 1.0

    def delay(self, attempt: int, rng: Optional[random.Random] = None) -> float:
        ceiling = min(self.max_s, self.base_s * (self.factor ** attempt))
        draw = (rng or random).uniform(ceiling / 2.0, ceiling)
        return draw


#: Default policy for store/queue I/O; small enough that a worker under a
#: dead filesystem fences within a couple of lease ttls.
DEFAULT_POLICY = RetryPolicy()

# Process-wide retry accounting, surfaced by `daemon-status`.  Keyed by the
# caller-supplied label ("store.append", "queue.claim", ...).
_counters_lock = threading.Lock()
_counters: Dict[str, Dict[str, int]] = {}


def _charge(label: str, *, retried: bool, exhausted: bool) -> None:
    with _counters_lock:
        slot = _counters.setdefault(
            label, {"calls": 0, "retries": 0, "exhausted": 0})
        slot["calls"] += 1
        if retried:
            slot["retries"] += 1
        if exhausted:
            slot["exhausted"] += 1


def retry_counters(reset: bool = False) -> Dict[str, Dict[str, int]]:
    """Snapshot (optionally reset) the per-site retry counters."""
    with _counters_lock:
        out = {k: dict(v) for k, v in _counters.items()}
        if reset:
            _counters.clear()
    return out


def call_with_retry(
    fn: Callable[[], Any],
    *,
    label: str = "io",
    policy: RetryPolicy = DEFAULT_POLICY,
    rng: Optional[random.Random] = None,
    classify: Callable[[BaseException], bool] = is_transient,
    sleep: Callable[[float], None] = time.sleep,
) -> Any:
    """Run ``fn`` retrying transient failures under ``policy``.

    Terminal errors propagate immediately; a transient error that survives
    every attempt propagates too (the caller's degraded mode — fencing,
    synthesized failure — takes over).  Each retried call is charged to the
    process-wide counters under ``label``.
    """
    retried = False
    last: Optional[BaseException] = None
    for attempt in range(max(1, policy.tries)):
        try:
            result = fn()
        except BaseException as exc:  # noqa: BLE001 — classified below
            if not classify(exc):
                _charge(label, retried=retried, exhausted=False)
                raise
            last = exc
            retried = True
            if attempt + 1 >= max(1, policy.tries):
                break
            sleep(policy.delay(attempt, rng))
            continue
        _charge(label, retried=retried, exhausted=False)
        return result
    _charge(label, retried=True, exhausted=True)
    assert last is not None
    raise last
