"""DryRunHarness — the production-mesh harness adapter.

Runs ``repro.launch.dryrun`` in a SUBPROCESS (exactly how a CI job would
launch it: the dry-run needs 512 placeholder devices, which must be set
before jax initializes) and converts the JSON record into a protocol Report.
Feature injections map onto the dry-run CLI knobs — the benchmark definition
itself is never edited.
"""

from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path
from typing import Optional

import jax

from repro.core import protocol
from repro.core.harness import BenchmarkSpec, Harness, HarnessCapabilities, Injections
from repro.core.readiness import Readiness


class DryRunHarness(Harness):
    name = "dryrun"

    def capabilities(self) -> HarnessCapabilities:
        # The dry-run subprocess takes env vars and config-knob overrides
        # via CLI flags, but a launcher CALLABLE cannot cross the process
        # boundary — declaring that honestly lets negotiation reject e.g.
        # an energy-launcher injection before the subprocess is spawned.
        return HarnessCapabilities(
            max_readiness=Readiness.REPRODUCIBLE,
            launcher_injection=False,
        )

    def __init__(
        self,
        *,
        repo_root: Optional[Path] = None,
        timeout_s: int = 3600,
        raw_dir: Optional[Path] = None,
    ):
        self.repo_root = Path(repo_root or Path(__file__).resolve().parents[3])
        self.timeout_s = timeout_s
        self.raw_dir = Path(raw_dir) if raw_dir else None
        if self.raw_dir:
            self.raw_dir.mkdir(parents=True, exist_ok=True)

    def spawn_spec(self):
        # All construction state is path/scalar data, so dry-run cells run
        # under spawned process workers: the worker rebuilds the harness and
        # the cell's real work happens in the dry-run SUBPROCESS it launches
        # (process-scope accounting picks the child up via os.times).
        return "repro.core.dryrun_harness:DryRunHarness", {
            "repo_root": str(self.repo_root),
            "timeout_s": self.timeout_s,
            "raw_dir": str(self.raw_dir) if self.raw_dir else None,
        }

    def run(self, spec: BenchmarkSpec, injections: Optional[Injections] = None) -> protocol.Report:
        inj = injections or Injections()
        multi_pod = "2pods" in spec.system
        with tempfile.TemporaryDirectory() as td:
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", spec.arch, "--shape", spec.shape, "--out", td,
            ]
            if multi_pod:
                cmd.append("--multi-pod")
            for knob, flag in (
                ("strategy", "--strategy"), ("remat", "--remat"),
                ("microbatches", "--microbatches"), ("opt_state_dtype", "--opt-state"),
                ("global_batch", "--global-batch"),
            ):
                if knob in inj.overrides:
                    cmd += [flag, str(inj.overrides[knob])]
            env = dict(os.environ)
            env["PYTHONPATH"] = str(self.repo_root / "src")
            env.update(inj.env)
            proc = subprocess.run(
                cmd, capture_output=True, text=True, timeout=self.timeout_s, env=env,
                cwd=self.repo_root,
            )
            tag = "2pod" if multi_pod else "1pod"
            rec_path = Path(td) / f"{spec.arch}.{spec.shape}.{tag}.json"
            if not rec_path.exists():
                raise RuntimeError(
                    f"dry-run produced no record (rc={proc.returncode}):\n"
                    f"{proc.stderr[-2000:]}"
                )
            rec = json.loads(rec_path.read_text())
            if self.raw_dir:
                suffix = ""
                if inj.overrides:
                    suffix = "." + "_".join(
                        f"{k}-{v}" for k, v in sorted(inj.overrides.items())
                    )
                (self.raw_dir / f"{spec.arch}.{spec.shape}.{tag}{suffix}.json").write_text(
                    json.dumps(rec, indent=2)
                )
        if rec.get("status") == "error":
            raise RuntimeError(f"dry-run cell failed: {rec.get('error')}")

        report = protocol.new_report(
            system=spec.system,
            variant=spec.effective_variant(),
            usecase=spec.shape,
            software_version=jax.__version__,
            parameter={
                "arch": spec.arch,
                "scale": "production-dryrun",
                "strategy": rec.get("strategy"),
                "knobs": rec.get("knobs", {}),
                "injections": inj.describe(),
            },
        )
        if rec.get("status") == "skipped":
            report.parameter["skipped"] = rec.get("reason", "")
            return report
        if rec.get("status") != "ok":
            entry = protocol.DataEntry(
                success=False, runtime=0.0,
                metrics={"error": rec.get("error", "unknown")},
            )
            report.data.append(entry)
            return report

        rl = rec["roofline"]
        digest = hashlib.sha256(
            json.dumps(rec["roofline"], sort_keys=True).encode()
        ).hexdigest()[:16]
        entry = protocol.DataEntry(
            success=True,
            runtime=rec["compile_s"],
            nodes=512 if multi_pod else 256,
            tasks_per_node=1,
            queue="dryrun",
            job_id=f"dryrun-{spec.cell}",
            metrics={
                "hlo_flops": rl["hlo_flops"],
                "hlo_bytes": rl["hlo_bytes"],
                "collective_bytes": rl["collective_bytes"],
                "t_compute": rl["t_compute"],
                "t_memory": rl["t_memory"],
                "t_collective": rl["t_collective"],
                "dominant": rl["dominant"],
                "useful_ratio": rl["useful_ratio"],
                "model_flops": rl["model_flops"],
                "roofline_fraction": rl["roofline_fraction"],
                "step_time_bound_s": rl["step_time_bound_s"],
                "hbm_required": rl["hbm_required"],
                "fits": rl["fits"],
                "artifact_digest": digest,
                "seed": spec.seed,
            },
        )
        report.data.append(entry)
        return report
