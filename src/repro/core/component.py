"""Typed component API — declared input schemas + the component registry.

The paper's user-facing contract is the reusable CI/CD component with
declared ``inputs:`` (§II-C, §V-A).  This module is the declaration layer
that turns that contract into an enforced protocol instead of a convention:

* :class:`InputSpec` — one declared input: name, type, default, required,
  ``choices``, deprecated aliases (warn + map), help text.
* :class:`ComponentSchema` — a versioned component's full input schema.
  ``validate()`` coerces a raw ``inputs:`` mapping into an immutable
  :class:`ComponentInputs`; unknown keys and type mismatches are hard
  :class:`PipelineError`\\ s *naming the component and the field* — a typo
  can never silently fall back to a default again.
* :class:`ComponentRegistry` — where orchestrators self-register their
  schemas (and runners).  Versioning follows the paper's schema-evolution
  discipline: unknown majors are rejected, while registered **migration
  shims** keep old-major documents (``execution@v3``) running against the
  current schema (``execution@v4``).

Orchestrators register themselves on import (see ``repro.core.orchestrator``)
into the process-wide :data:`REGISTRY`; the CI/CD layer
(``repro.core.cicd``) and the :class:`repro.core.api.Campaign` facade
resolve every component reference through it.
"""

from __future__ import annotations

import dataclasses
import difflib
import warnings
from collections.abc import Mapping
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple


class PipelineError(ValueError):
    """A pipeline document or component invocation is invalid.

    Defined here (not in ``cicd``) because schema validation is the layer
    that raises it; ``repro.core.cicd`` re-exports it for compatibility.
    """


class _Missing:
    def __repr__(self) -> str:  # pragma: no cover — repr only
        return "<missing>"


#: Sentinel for "no default": the input is simply absent after validation
#: (``"key" in inputs`` is False), unlike an explicit ``default=None``.
MISSING = _Missing()


def _type_name(t: Any) -> str:
    if isinstance(t, tuple):
        return " | ".join(_type_name(x) for x in t)
    return t if isinstance(t, str) else t.__name__


@dataclasses.dataclass(frozen=True)
class InputSpec:
    """Declaration of one component input.

    ``type`` is a python type (``str``/``int``/``float``/``bool``/``list``/
    ``dict``), a tuple of alternatives, or the string ``"any"``.  ``aliases``
    are deprecated spellings: accepted with a ``DeprecationWarning`` and
    mapped onto the canonical name.  ``wrap_scalar`` lets a list-typed input
    accept a bare scalar (``metrics: step_time_s``) by wrapping it.
    """

    name: str
    type: Any = str
    default: Any = MISSING
    required: bool = False
    choices: Tuple[Any, ...] = ()
    aliases: Tuple[str, ...] = ()
    help: str = ""
    element: Any = None        # element type for list inputs (None = any)
    wrap_scalar: bool = False

    @property
    def types(self) -> Tuple[Any, ...]:
        return self.type if isinstance(self.type, tuple) else (self.type,)

    def describe(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"name": self.name, "type": _type_name(self.type)}
        if self.default is not MISSING:
            out["default"] = self.default if not isinstance(self.default, tuple) \
                else list(self.default)
        if self.required:
            out["required"] = True
        if self.choices:
            out["choices"] = list(self.choices)
        if self.aliases:
            out["deprecated_aliases"] = list(self.aliases)
        if self.help:
            out["help"] = self.help
        return out


#: The one shared parallelism declaration — every component that dispatches
#: through the campaign scheduler reuses this spec, so the default worker
#: count lives in exactly one place (see :func:`resolve_parallelism`).
PARALLELISM = InputSpec(
    "parallelism", int, default=1,
    help="bounded scheduler worker-pool size; 1 = serial (seed behavior)",
)

#: Worker-pool size for the distributed execution plane.  No default: when
#: absent, ``parallelism`` governs.  When present it wins — a pipeline
#: declaring ``workers: 4`` means 4 workers regardless of ``parallelism``.
WORKERS = InputSpec(
    "workers", int,
    help="execution-plane worker count; overrides 'parallelism' when given",
)

#: How cells are dispatched: ``thread`` keeps the in-process scheduler pool
#: (seed behavior); ``process`` drains the campaign through the broker +
#: spawned worker processes (lease-reclaimed work queue, true CPU
#: parallelism, crash recovery).
WORKER_MODE = InputSpec(
    "worker_mode", str, default="thread", choices=("thread", "process"),
    help="cell dispatch: in-process thread pool, or broker + process workers",
)


def resolve_parallelism(inputs: Mapping, override: Optional[int] = None) -> int:
    """One resolution rule for every dispatch path: an explicit argument
    wins, else the declared ``workers`` input, else ``parallelism``, else
    the shared default; always clamped to >= 1."""
    if override is not None:
        return max(1, int(override))
    workers = inputs.get(WORKERS.name)
    if workers is not None:
        return max(1, int(workers))
    return max(1, int(inputs.get(PARALLELISM.name, PARALLELISM.default)))


def resolve_worker_mode(inputs: Mapping, override: Optional[str] = None) -> str:
    """Same resolution rule for the dispatch mode; validates the value so a
    programmatic override obeys the declared choices too."""
    mode = override if override is not None else str(
        inputs.get(WORKER_MODE.name, WORKER_MODE.default))
    if mode not in WORKER_MODE.choices:
        raise PipelineError(
            f"bad worker_mode {mode!r} (want one of {list(WORKER_MODE.choices)})")
    return mode


class ComponentInputs(Mapping):
    """Validated, coerced, immutable component inputs.

    Behaves as a read-only mapping (so every existing ``inputs.get(...)``
    call site keeps working) and remembers which component reference it was
    validated for.  ``namespace("mad")`` collects dotted tuning keys
    (``mad.z_threshold: 6``) into a plain parameter dict.
    """

    __slots__ = ("_data", "component")

    def __init__(self, data: Dict[str, Any], component: str = ""):
        self._data = dict(data)
        self.component = component

    def __getitem__(self, key: str) -> Any:
        return self._data[key]

    def __iter__(self) -> Iterator[str]:
        return iter(self._data)

    def __len__(self) -> int:
        return len(self._data)

    def namespace(self, ns: str) -> Dict[str, Any]:
        pre = ns + "."
        return {k[len(pre):]: v for k, v in self._data.items()
                if k.startswith(pre)}

    def __repr__(self) -> str:
        return f"ComponentInputs({self.component}, {self._data!r})"


def _coerce(value: Any, spec: InputSpec, ref: str) -> Any:
    if value is None:
        return None
    for t in spec.types:
        if t == "any":
            return value
        if t is bool and isinstance(value, bool):
            return value
        if isinstance(value, bool):
            continue  # bool is an int subclass; never coerce it silently
        if t is int and isinstance(value, int):
            return int(value)
        if t is float and isinstance(value, (int, float)):
            return float(value)
        if t is str and isinstance(value, str):
            return value
        if t is dict and isinstance(value, Mapping):
            return dict(value)
        if t is list and isinstance(value, (list, tuple)):
            if spec.element is None:
                return list(value)
            espec = InputSpec(spec.name, spec.element)
            return [_coerce(v, espec, ref) for v in value]
    if list in spec.types and spec.wrap_scalar and not isinstance(value, (list, tuple)):
        return _coerce([value], spec, ref)
    raise PipelineError(
        f"{ref}: input {spec.name!r} expects {_type_name(spec.type)}, "
        f"got {type(value).__name__} {value!r}"
    )


@dataclasses.dataclass(frozen=True)
class ComponentSchema:
    """A versioned component's declared input schema."""

    name: str
    version: int
    inputs: Tuple[InputSpec, ...] = ()
    open_namespaces: Tuple[str, ...] = ()  # dotted keys `<ns>.<param>` pass
    description: str = ""

    @property
    def ref(self) -> str:
        return f"{self.name}@v{self.version}"

    def spec(self, name: str) -> Optional[InputSpec]:
        for s in self.inputs:
            if s.name == name:
                return s
        return None

    def _known_keys(self) -> List[str]:
        keys = [s.name for s in self.inputs]
        keys += [a for s in self.inputs for a in s.aliases]
        return keys

    def validate(self, raw: Mapping, *, require: bool = True,
                 ref: Optional[str] = None) -> ComponentInputs:
        """Coerce ``raw`` into a :class:`ComponentInputs`.

        Hard :class:`PipelineError` (naming ``ref`` and the field) on
        unknown keys, type mismatches, bad choices, or — when ``require``
        is set, the pipeline-dispatch path — missing required inputs.
        ``require=False`` is the library path: an orchestrator constructed
        directly receives its identity (spec, selectors, ...) as method
        arguments, so required-ness is not enforced, but typos and type
        errors still are.
        """
        ref = ref or self.ref
        if isinstance(raw, ComponentInputs):
            return raw
        by_name = {s.name: s for s in self.inputs}
        by_alias = {a: s for s in self.inputs for a in s.aliases}
        out: Dict[str, Any] = {}
        for key, value in dict(raw).items():
            if "." in key and key.split(".", 1)[0] in self.open_namespaces:
                out[key] = value
                continue
            spec = by_name.get(key)
            if spec is None:
                spec = by_alias.get(key)
                if spec is None:
                    hint = difflib.get_close_matches(key, self._known_keys(), 1)
                    did = f" (did you mean {hint[0]!r}?)" if hint else ""
                    raise PipelineError(f"{ref}: unknown input {key!r}{did}")
                if spec.name in raw:
                    raise PipelineError(
                        f"{ref}: both {spec.name!r} and its deprecated alias "
                        f"{key!r} given")
                warnings.warn(
                    f"{ref}: input {key!r} is deprecated, use {spec.name!r}",
                    DeprecationWarning, stacklevel=3)
            value = _coerce(value, spec, ref)
            if spec.choices and value is not None and value not in spec.choices:
                raise PipelineError(
                    f"{ref}: input {spec.name!r} must be one of "
                    f"{list(spec.choices)}, got {value!r}")
            out[spec.name] = value
        for spec in self.inputs:
            if spec.name in out:
                continue
            if spec.required and require:
                raise PipelineError(f"{ref}: required input {spec.name!r} missing")
            if spec.default is not MISSING:
                out[spec.name] = _coerce(spec.default, spec, ref) \
                    if spec.default is not None else None
        return ComponentInputs(out, component=ref)

    def describe(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "component": self.ref,
            "inputs": [s.describe() for s in self.inputs],
        }
        if self.open_namespaces:
            out["open_namespaces"] = list(self.open_namespaces)
        if self.description:
            out["description"] = self.description
        return out


def merge_schemas(name: str, version: int, *schemas: ComponentSchema,
                  description: str = "") -> ComponentSchema:
    """Union of several schemas (first declaration of a name wins) — used
    for orchestrators whose sub-components share a construction surface."""
    seen: Dict[str, InputSpec] = {}
    for sch in schemas:
        for s in sch.inputs:
            seen.setdefault(s.name, s)
    namespaces = tuple(dict.fromkeys(
        ns for sch in schemas for ns in sch.open_namespaces))
    return ComponentSchema(name, version, tuple(seen.values()), namespaces,
                           description)


def coerce_inputs(schema: ComponentSchema, inputs: Mapping) -> ComponentInputs:
    """Orchestrator-construction path: pass validated inputs through
    untouched (they may come from a superset schema, e.g. feature-injection
    inputs driving the inner execution orchestrator); validate raw dicts
    against ``schema`` without enforcing dispatch-only required fields."""
    if isinstance(inputs, ComponentInputs):
        return inputs
    return schema.validate(inputs, require=False)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ComponentContext:
    """What a component runner gets to act on (registry → scheduler →
    store wiring lives in ``cicd.run_pipeline`` / the ``Campaign`` facade)."""

    store: Any
    harness: Any = None
    harness_factory: Optional[Callable[[Mapping], Any]] = None

    def harness_for(self, inputs: Mapping) -> Any:
        return self.harness_factory(inputs) if self.harness_factory else self.harness


@dataclasses.dataclass(frozen=True)
class ResolvedComponent:
    """A component reference resolved through the registry: the declared
    ref (what the document said), the target schema (possibly a newer
    major), the migration shim chain, and the runner."""

    ref: str
    schema: ComponentSchema
    runner: Optional[Callable[[ComponentInputs, ComponentContext], Any]]
    migrate: Callable[[Dict[str, Any]], Dict[str, Any]]
    target_version: int

    def parse(self, raw: Mapping, *, require: bool = True) -> ComponentInputs:
        if isinstance(raw, ComponentInputs):
            return raw
        return self.schema.validate(self.migrate(dict(raw)),
                                    require=require, ref=self.ref)

    def run(self, inputs: Mapping, ctx: ComponentContext) -> Any:
        if self.runner is None:
            raise PipelineError(f"{self.ref} has no registered runner")
        return self.runner(self.parse(inputs), ctx)


class ComponentRegistry:
    """Versioned component schemas + runners + migration shims.

    ``resolve("execution", 3)`` follows the registered v3→v4 shim and
    returns the v4 schema with the migration pre-composed, so a v3 document
    keeps running while new documents target v4 — and a genuinely unknown
    name or major is a hard :class:`PipelineError`.
    """

    def __init__(self) -> None:
        self._components: Dict[Tuple[str, int], Tuple[ComponentSchema, Optional[Callable]]] = {}
        self._migrations: Dict[Tuple[str, int], Tuple[int, Callable]] = {}

    def register(self, schema: ComponentSchema,
                 runner: Optional[Callable] = None) -> ComponentSchema:
        key = (schema.name, schema.version)
        if key in self._components:
            raise ValueError(f"component {schema.ref} already registered")
        self._components[key] = (schema, runner)
        return schema

    def register_migration(self, name: str, from_version: int, to_version: int,
                           migrate: Callable[[Dict[str, Any]], Dict[str, Any]]) -> None:
        if (name, to_version) not in self._components and \
                (name, to_version) not in self._migrations:
            raise ValueError(
                f"cannot migrate {name}@v{from_version} to unregistered "
                f"{name}@v{to_version}")
        if (name, from_version) in self._components or \
                (name, from_version) in self._migrations:
            raise ValueError(f"{name}@v{from_version} already registered")
        self._migrations[(name, from_version)] = (to_version, migrate)

    def names(self) -> List[str]:
        return sorted({n for n, _ in self._components} |
                      {n for n, _ in self._migrations})

    def versions(self, name: str) -> List[int]:
        """Every major accepted for ``name`` — registered directly or via shim."""
        return sorted({v for n, v in self._components if n == name} |
                      {v for n, v in self._migrations if n == name})

    def resolve(self, name: str, version: int) -> ResolvedComponent:
        ref = f"{name}@v{version}"
        shims: List[Callable] = []
        v = version
        for _ in range(len(self._migrations) + 1):
            direct = self._components.get((name, v))
            if direct is not None:
                schema, runner = direct
                if not shims:
                    return ResolvedComponent(ref, schema, runner, dict, v)

                def migrate(raw: Dict[str, Any], _shims=tuple(shims)) -> Dict[str, Any]:
                    for fn in _shims:
                        raw = fn(dict(raw))
                    return raw

                return ResolvedComponent(ref, schema, runner, migrate, v)
            step = self._migrations.get((name, v))
            if step is None:
                break
            v, fn = step
            shims.append(fn)
        if name not in self.names():
            hint = difflib.get_close_matches(name, self.names(), 1)
            did = f" (did you mean {hint[0]!r}?)" if hint else ""
            raise PipelineError(f"unknown component {name!r}{did}")
        raise PipelineError(
            f"{ref} unsupported (have v{self.versions(name)})")

    def parse_inputs(self, name: str, version: int, raw: Mapping,
                     *, require: bool = True) -> ComponentInputs:
        return self.resolve(name, version).parse(raw, require=require)

    def describe(self) -> List[Dict[str, Any]]:
        """Registry listing for ``python -m repro components``: one entry
        per accepted component reference, shims included."""
        out = [schema.describe()
               for schema, _ in (self._components[k]
                                 for k in sorted(self._components))]
        for (name, v), (to_v, _) in sorted(self._migrations.items()):
            target = self.resolve(name, v)
            out.append({
                "component": f"{name}@v{v}",
                "migrates_to": f"{name}@v{target.target_version}",
                "inputs": [s.describe() for s in target.schema.inputs],
            })
        return out


#: Process-wide default registry.  Orchestrators self-register here on
#: import; ``cicd`` and the ``Campaign`` facade resolve against it.
REGISTRY = ComponentRegistry()
