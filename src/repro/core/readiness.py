"""Incremental adoption levels (paper contribution #2):

    runnability  →  instrumentability  →  reproducibility

A benchmark onboards at RUNNABLE (it executes and reports success/runtime),
matures to INSTRUMENTED (structured roofline/performance metrics), and
finally REPRODUCIBLE (complete provenance + deterministic artifact digests
so a re-run can be verified bit-for-bit).  Levels are *validated from the
protocol document itself* — rigor is enforced by the protocol, not by trust
(paper §I-C, §VI-A).
"""

from __future__ import annotations

import enum
from typing import List, Tuple

from repro.core.protocol import Report


class Readiness(enum.IntEnum):
    FAILED = 0
    RUNNABLE = 1
    INSTRUMENTED = 2
    REPRODUCIBLE = 3


def parse_level(value) -> Readiness:
    """Coerce a declared readiness requirement (enum, int, or name — the
    form a ``require_readiness:`` component input arrives in) to a level.
    ``None``/``"none"`` mean "no requirement" (FAILED, the zero level)."""
    if isinstance(value, Readiness):
        return value
    if isinstance(value, bool):
        raise ValueError(f"bad readiness level {value!r}")
    if isinstance(value, int):
        return Readiness(value)
    name = str(value or "none").strip().upper()
    if name == "NONE":
        return Readiness.FAILED
    try:
        return Readiness[name]
    except KeyError:
        raise ValueError(
            f"bad readiness level {value!r} "
            f"(want one of {[r.name.lower() for r in Readiness]})") from None


# Metrics every INSTRUMENTED report must carry (roofline instrumentation).
INSTRUMENTED_METRICS = (
    "hlo_flops",
    "hlo_bytes",
    "collective_bytes",
    "t_compute",
    "t_memory",
    "t_collective",
)

# Fields every REPRODUCIBLE report must carry in addition.
REPRODUCIBLE_METRICS = ("artifact_digest", "seed")


def classify(report: Report) -> Tuple[Readiness, List[str]]:
    """Highest readiness level the report satisfies, plus the gaps blocking
    the next level (actionable onboarding feedback)."""
    gaps: List[str] = []
    if not report.data:
        return Readiness.FAILED, ["no data entries"]
    if not all(d.success for d in report.data):
        return Readiness.FAILED, ["one or more executions failed"]
    if not all(d.runtime > 0 for d in report.data):
        return Readiness.FAILED, ["missing runtime"]

    level = Readiness.RUNNABLE

    missing = sorted(
        {m for d in report.data for m in INSTRUMENTED_METRICS if m not in d.metrics}
    )
    if missing:
        gaps.extend(f"metric missing for INSTRUMENTED: {m}" for m in missing)
        return level, gaps
    level = Readiness.INSTRUMENTED

    missing = sorted(
        {m for d in report.data for m in REPRODUCIBLE_METRICS if m not in d.metrics}
    )
    if not report.reporter.complete():
        missing.append("reporter provenance incomplete")
    if not report.reporter.chain_of_trust:
        missing.append("chain of trust broken (externally injected data)")
    if missing:
        gaps.extend(f"blocking REPRODUCIBLE: {m}" for m in missing)
        return level, gaps
    return Readiness.REPRODUCIBLE, []


def verify_reproduction(a: Report, b: Report) -> bool:
    """Two REPRODUCIBLE runs of the same cell must agree on artifact digests."""
    da = {i: e.metrics.get("artifact_digest") for i, e in enumerate(a.data)}
    db = {i: e.metrics.get("artifact_digest") for i, e in enumerate(b.data)}
    return da == db and all(v is not None for v in da.values())
