"""Kernel block-size autotuning: sweep, classify, promote, cache.

``autotune@v1`` sweeps a pallas kernel's block-knob grid through
``KernelHarness`` cells (each point is a feature-injection override),
classifies every point with the roofline vocabulary, then promotes the
fastest config twice over:

* into the **autotune cache** — a JSON file keyed by
  ``(kernel, shape key, dtype, hardware-fingerprint key)`` that the
  kernels' ``ops.py`` entry points consult for their *default* blocks
  (opt-in via the ``EXACB_AUTOTUNE_CACHE`` environment variable, so a
  bare ``flash_attention(q, k, v)`` call stays dependency-free), and
* into the **regression gate** — confirmation runs of the winner are
  pinned as the ``kernel_latency_s`` baseline, so later sweeps defend
  the tuned latency instead of chasing a drifting rolling window.

The fingerprint component of the cache key is what makes the cache safe
to ship around: an entry tuned on one machine (or under one governor /
library stack) is invisible on another — lookups compare the *full*
canonical fingerprint key, not a truncated hash.

A re-run with an unchanged key is an incremental no-op (the exaCB
watermark idiom applied to tuning): the sweep is skipped and the cached
winner reported, unless ``force: true``.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import os
from pathlib import Path
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

from repro.core import fingerprint
from repro.core.component import (
    PARALLELISM,
    ComponentContext,
    ComponentInputs,
    ComponentSchema,
    InputSpec,
    PipelineError,
)

CACHE_BASENAME = "autotune_cache.json"
CACHE_ENV = "EXACB_AUTOTUNE_CACHE"


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------

def _entry_key(kernel: str, shape: str, dtype: str, fp_key: str) -> str:
    import hashlib

    fp16 = hashlib.sha256(fp_key.encode()).hexdigest()[:16] if fp_key else "nofp"
    return f"{kernel}|{shape}|{dtype}|{fp16}"


class AutotuneCache:
    """One JSON file of promoted block configs; atomic writes, full-key
    fingerprint verification on lookup (hash collisions cannot alias)."""

    def __init__(self, path: str | Path):
        self.path = Path(path)

    def load(self) -> Dict[str, Any]:
        try:
            data = json.loads(self.path.read_text())
        except (OSError, ValueError):
            return {"version": 1, "entries": {}}
        if not isinstance(data, dict) or not isinstance(data.get("entries"), dict):
            return {"version": 1, "entries": {}}
        return data

    def lookup(self, kernel: str, shape: str, dtype: str, fp_key: str) -> Optional[Dict[str, Any]]:
        entry = self.load()["entries"].get(_entry_key(kernel, shape, dtype, fp_key))
        if entry is None:
            return None
        if entry.get("fingerprint_key", "") != fp_key:
            return None  # hash-bucket collision or hand-edited file: distrust
        return dict(entry)

    def put(self, kernel: str, shape: str, dtype: str, fp_key: str,
            config: Dict[str, int], **extra: Any) -> Dict[str, Any]:
        from repro.core.store import _atomic_write

        data = self.load()
        key = _entry_key(kernel, shape, dtype, fp_key)
        prev = data["entries"].get(key, {})
        entry = {
            "kernel": kernel,
            "shape": shape,
            "dtype": dtype,
            "fingerprint_key": fp_key,
            "config": {k: int(v) for k, v in config.items()},
            "updates": int(prev.get("updates", 0)) + 1,
            **extra,
        }
        data["entries"][key] = entry
        self.path.parent.mkdir(parents=True, exist_ok=True)
        _atomic_write(self.path, json.dumps(data, indent=1, sort_keys=True))
        return entry


# -- ops.py-facing lookup ----------------------------------------------------
# Kernel entry points call `cached_blocks(...)` on every invocation with
# unresolved (None) block arguments, so the lookup has to be cheap: the
# fingerprint key is computed once per process, and cache files are
# re-parsed only when their mtime changes.

_FP_KEY: Optional[str] = None
_FILE_CACHE: Dict[str, Tuple[int, Dict[str, Any]]] = {}


def _current_fp_key() -> str:
    global _FP_KEY
    if _FP_KEY is None:
        _FP_KEY = fingerprint.key(fingerprint.capture())
    return _FP_KEY


def cached_blocks(kernel: str, shape: str, dtype: str,
                  path: Optional[str | Path] = None) -> Optional[Dict[str, int]]:
    """Promoted block config for (kernel, shape, dtype) on *this* hardware,
    or None.  ``path`` defaults to ``$EXACB_AUTOTUNE_CACHE``; unset means
    autotuned defaults are off."""
    p = str(path) if path else os.environ.get(CACHE_ENV, "")
    if not p:
        return None
    try:
        mtime = os.stat(p).st_mtime_ns
    except OSError:
        return None
    cached = _FILE_CACHE.get(p)
    if cached is None or cached[0] != mtime:
        data = AutotuneCache(p).load()
        _FILE_CACHE[p] = (mtime, data)
    else:
        data = cached[1]
    entry = data["entries"].get(_entry_key(kernel, shape, dtype, _current_fp_key()))
    if entry is None or entry.get("fingerprint_key", "") != _current_fp_key():
        return None
    cfg = entry.get("config")
    return {k: int(v) for k, v in cfg.items()} if isinstance(cfg, dict) else None


def reset_runtime_caches() -> None:
    """Drop the per-process fingerprint + file memos (tests, forked envs)."""
    global _FP_KEY
    _FP_KEY = None
    _FILE_CACHE.clear()


# ---------------------------------------------------------------------------
# autotune@v1 component
# ---------------------------------------------------------------------------

_SWEEP_KNOBS = ("block_q", "block_k", "chunk", "block_w")

AUTOTUNE_SCHEMA = ComponentSchema(
    "autotune", 1,
    (
        InputSpec("kernel", str, required=True,
                  choices=("flash_attention", "rglru", "ssd")),
        InputSpec("prefix", str, default="autotune"),
        InputSpec("system", str, default="local", aliases=("machine",)),
        InputSpec("arch", str, default="kernel"),
        InputSpec("shape", str, default="",
                  help="cell shape label; defaults to the kernel shape key"),
        InputSpec("seed", int, default=0),
        InputSpec("record", bool, default=True),
        InputSpec("dtype", str, default="float32"),
        InputSpec("batch", int, default=1),
        InputSpec("heads", int, default=2),
        InputSpec("seq", int, default=128),
        InputSpec("head_dim", int, default=16),
        InputSpec("width", int, default=64),
        InputSpec("state", int, default=16),
        InputSpec("calls", int, default=3),
        InputSpec("warmup", int, default=1),
        InputSpec("interpret", bool,
                  help="force pallas interpret mode (default: auto off-TPU)"),
        InputSpec("block_q", list, default=(), element=int, wrap_scalar=True),
        InputSpec("block_k", list, default=(), element=int, wrap_scalar=True),
        InputSpec("chunk", list, default=(), element=int, wrap_scalar=True),
        InputSpec("block_w", list, default=(), element=int, wrap_scalar=True),
        InputSpec("confirm", int, default=3,
                  help="confirmation runs of the winner; their latencies are "
                       "pinned as the kernel_latency_s baseline"),
        InputSpec("baseline", bool, default=True,
                  help="pin the winner as the gate baseline"),
        InputSpec("cache", str, default="",
                  help=f"cache file path (default <store>/{CACHE_BASENAME})"),
        InputSpec("force", bool, default=False,
                  help="re-sweep even when the cache already holds this key"),
        PARALLELISM,
    ),
    description="sweep a pallas kernel's block grid, classify each point "
                "with roofline terms, promote the winner into the autotune "
                "cache and as a pinned latency baseline",
)


def _grid(inputs: Mapping[str, Any], knobs: Iterable[str]) -> List[Dict[str, int]]:
    axes = [(k, [int(v) for v in inputs.get(k) or ()]) for k in knobs]
    axes = [(k, vals) for k, vals in axes if vals]
    if not axes:
        raise PipelineError(
            f"autotune: no block values to sweep; give at least one of "
            f"{list(knobs)} a list of candidates")
    names = [k for k, _ in axes]
    return [dict(zip(names, combo))
            for combo in itertools.product(*(vals for _, vals in axes))]


def run_autotune(inputs: ComponentInputs, ctx: ComponentContext) -> Dict[str, Any]:
    # Local imports: autotune is registered at orchestrator import time, and
    # the heavy deps (jax via the harness, the orchestrator itself) must not
    # load just to validate a document.
    from repro.core.harness import BenchmarkSpec, Injections
    from repro.core.orchestrator import ExecutionOrchestrator
    from repro.core.regression import BaselineManager
    from repro.core.roofline import kernel_terms
    from repro.harnesses.kernel import KERNEL_KNOBS, KernelHarness
    from repro.hardware import TPU_V5E

    kernel = inputs["kernel"]
    prefix = inputs.get("prefix") or "autotune"
    record = bool(inputs.get("record", True))
    dims = {k: int(inputs[k]) for k in
            ("batch", "heads", "seq", "head_dim", "width", "state")}
    harness = KernelHarness(
        kernel=kernel, dtype=inputs["dtype"], calls=int(inputs["calls"]),
        warmup=int(inputs["warmup"]), interpret=inputs.get("interpret"),
        use_cache=False, **dims)
    skey = harness.shape_key()
    dtype = inputs["dtype"]
    fp_key = fingerprint.key(fingerprint.capture())
    cache_path = Path(inputs.get("cache") or Path(ctx.store.root) / CACHE_BASENAME)
    cache = AutotuneCache(cache_path)

    base = {
        "component": "autotune",
        "kernel": kernel,
        "shape": skey,
        "dtype": dtype,
        "cache": {"path": str(cache_path)},
    }

    existing = cache.lookup(kernel, skey, dtype, fp_key)
    if existing is not None and not bool(inputs.get("force", False)):
        return {
            **base,
            "skipped": "cache-hit",
            "points": [],
            "winner": {"config": existing["config"],
                       "latency_s": existing.get("latency_s")},
            "cache": {**base["cache"], "hit": True, "updated": False},
        }

    grid = _grid(inputs, KERNEL_KNOBS[kernel])
    spec = BenchmarkSpec(
        arch=inputs.get("arch") or "kernel",
        shape=inputs.get("shape") or skey,
        system=inputs.get("system") or "local",
        seed=int(inputs.get("seed", 0)),
    )
    ex = ExecutionOrchestrator(
        inputs={"prefix": prefix, "record": record},
        harness=harness, store=ctx.store)

    points: List[Dict[str, Any]] = []
    errors: List[str] = []
    for cfg in grid:
        label = ".".join(f"{k}{v}" for k, v in sorted(cfg.items()))
        pt_spec = dataclasses.replace(spec, variant=f"{kernel}.{label}")
        res = ex.run_cell(pt_spec, injections=Injections(overrides=dict(cfg)))
        if res.error or res.report is None:
            errors.append(f"{label}: {res.error or 'no report'}")
            continue
        m = res.report.data[-1].metrics
        points.append({
            "config": cfg,
            "latency_s": float(m["kernel_latency_s"]),
            "achieved_flops": float(m.get("achieved_flops", 0.0)),
            "achieved_bytes_per_s": float(m.get("achieved_bytes_per_s", 0.0)),
            **kernel_terms(float(m.get("hlo_flops", 0.0)),
                           float(m.get("hlo_bytes", 0.0)), TPU_V5E),
        })

    if not points:
        return {**base, "points": [], "winner": None,
                "error": "all sweep points failed: " + "; ".join(errors)}

    best = min(points, key=lambda p: p["latency_s"])

    # Confirmation runs at the winning config: a spread for the pinned
    # baseline that reflects run-to-run noise, not the one lucky sample.
    confirm_n = max(0, int(inputs.get("confirm", 3)))
    confirm: List[float] = [best["latency_s"]]
    for i in range(confirm_n):
        c_spec = dataclasses.replace(
            spec, variant=f"{kernel}.winner", seed=spec.seed + 1 + i)
        res = ex.run_cell(c_spec, injections=Injections(overrides=dict(best["config"])))
        if not res.error and res.report is not None:
            confirm.append(float(res.report.data[-1].metrics["kernel_latency_s"]))

    entry = cache.put(
        kernel, skey, dtype, fp_key,
        best["config"],
        latency_s=best["latency_s"],
        dominant=best["dominant"],
        source=prefix,
    )

    baseline_info: Optional[Dict[str, Any]] = None
    if record and bool(inputs.get("baseline", True)):
        mgr = BaselineManager(ctx.store)
        mgr.pin(prefix, "kernel_latency_s", values=confirm,
                commit=f"autotune:{kernel}:{skey}")
        baseline_info = {
            "pinned": True,
            "source_prefix": prefix,
            "metric": "kernel_latency_s",
            "n_values": len(confirm),
        }

    out = {
        **base,
        "points": points,
        "winner": {"config": best["config"], "latency_s": best["latency_s"],
                   "dominant": best["dominant"], "confirm": confirm},
        "cache": {**base["cache"], "hit": False, "updated": True,
                  "updates": entry["updates"], "fingerprint_key": fp_key},
        "baseline": baseline_info,
    }
    if errors:
        out["point_errors"] = errors
    return out
