"""Lightweight analysis over protocol reports (paper §IV-F, §V-C).

exaCB guarantees the storage format and ships the analyses its experiments
need: time-series with regression detection (Figs. 3/4), machine comparison
(Fig. 5), feature-injection comparison (Fig. 6), strong/weak scaling with
efficiency bands (Figs. 5/7).  Heavier analysis is expected to live in
downstream tools; these functions are deliberately dependency-free
(numpy only) and pure, so they run identically inside or outside a full
exaCB workflow.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.protocol import Report


def to_series(reports: Sequence[Report], metric: str) -> List[Tuple[float, float]]:
    """(timestamp, value) points for one metric across reports.

    This is the report-object reference path; the columnar fast path
    (``ColumnTable.series(metric).time_points()``) produces the identical
    list without materializing reports.
    """
    pts = []
    for r in reports:
        for d in r.data:
            if metric in d.metrics:
                pts.append((r.experiment.timestamp, float(d.metrics[metric])))
            elif metric == "runtime":
                pts.append((r.experiment.timestamp, d.runtime))
    return sorted(pts)


def summary_stats(values) -> Dict[str, float]:
    """The Fig. 5 per-group statistics row.  Shared by the report-object and
    columnar paths so both produce bit-identical floats."""
    v = np.asarray(values, dtype=np.float64)
    return {
        "n": int(v.size),
        "median": float(np.median(v)),
        "mean": float(np.mean(v)),
        "min": float(np.min(v)),
        "max": float(np.max(v)),
    }


@dataclasses.dataclass
class Regression:
    index: int
    timestamp: float
    value: float
    baseline: float
    sigma: float

    @property
    def relative(self) -> float:
        if self.baseline:
            return (self.value - self.baseline) / self.baseline
        # Zero baseline: any deviation is an infinite relative change, not a
        # silent 0.0 that downstream gates would read as "no regression".
        if self.value == self.baseline:
            return 0.0
        return math.copysign(math.inf, self.value - self.baseline)


def detect_regressions(
    series: Sequence[Tuple[float, float]],
    *,
    window: int = 8,
    z_threshold: float = 4.0,
    min_rel: float = 0.05,
) -> List[Regression]:
    """Change-point detection over a metric time-series (Fig. 4 semantics).

    Each point is compared against the median/MAD of the trailing window; a
    point is flagged when it deviates by more than ``z_threshold`` robust
    sigmas AND ``min_rel`` relatively (guards against ultra-low-variance
    series flagging measurement noise).

    Fully vectorized, two-stage: a conservative rolling min/max prescreen
    first discards every candidate that provably cannot clear the relative
    bar (the median lies inside the window's range, so
    ``dev/|median| <= dev_ub/amin``), then the exact median/MAD test runs
    only on the survivors — O(n·window) cheap comparisons plus O(survivors)
    median work, instead of a Python loop with two medians per point.  The
    flagged set is identical to the seed's per-point loop by construction
    (the prescreen is a necessary condition of the exact test, padded by an
    epsilon so borderline candidates are always judged exactly).
    ``series`` may be ``[(timestamp, value), ...]`` or a columnar
    ``MetricSeries`` (whose arrays are consumed without conversion).
    """
    out: List[Regression] = []
    window = max(1, int(window))
    if hasattr(series, "values"):  # columnar MetricSeries — already arrays
        vals = np.asarray(series.values, dtype=np.float64)
        times = np.asarray(series.timestamps, dtype=np.float64)
    else:
        vals = np.array([v for _, v in series], dtype=np.float64)
        times = None
    if vals.size <= window:  # empty/singleton/short series: nothing to judge
        return out
    # Candidate i (i >= window) is judged against vals[i-window:i]; rolling
    # window extremes come from `window` shifted flat minimum/maximum passes
    # — an order of magnitude faster than a short-axis reduction over a
    # sliding-window view.
    m = vals.size - window  # number of candidates
    cand = vals[window:]
    wmin = vals[:m].copy()
    wmax = vals[:m].copy()
    for k in range(1, window):
        np.minimum(wmin, vals[k:k + m], out=wmin)
        np.maximum(wmax, vals[k:k + m], out=wmax)
    dev_ub = np.maximum(np.abs(cand - wmin), np.abs(cand - wmax))
    amin = np.where((wmin <= 0) & (wmax >= 0), 0.0,
                    np.minimum(np.abs(wmin), np.abs(wmax)))
    maybe = (amin == 0) | (dev_ub * (1.0 + 1e-9) >= min_rel * amin)
    surv = np.nonzero(maybe)[0]
    if surv.size == 0:
        return out
    # Exact median/MAD judging only for the survivors.
    swins = np.lib.stride_tricks.sliding_window_view(vals, window)[surv]
    med = np.median(swins, axis=1)
    mad = np.median(np.abs(swins - med[:, None]), axis=1)
    sigma = np.maximum(1.4826 * mad, 1e-12)
    dev = np.abs(cand[surv] - med)
    z = dev / sigma
    with np.errstate(divide="ignore", invalid="ignore"):
        rel = dev / np.abs(med)
    flagged = (z > z_threshold) & ((med == 0) | (rel > min_rel))
    for k in np.nonzero(flagged)[0].tolist():
        i = int(surv[k]) + window
        out.append(
            Regression(
                index=i,
                timestamp=float(times[i]) if times is not None else series[i][0],
                value=float(vals[i]),
                baseline=float(med[k]),
                sigma=float(z[k]),
            )
        )
    return out


def compare_systems(
    reports: Sequence[Report], metric: str
) -> Dict[str, Dict[str, float]]:
    """Per-system summary statistics of one metric (Fig. 5 table).

    Report-object reference path; the columnar twin is
    ``CampaignFrame.compare_systems`` / ``ColumnTable.system_groups``.
    """
    by_sys: Dict[str, List[float]] = {}
    for r in reports:
        for d in r.data:
            v = d.metrics.get(metric, d.runtime if metric == "runtime" else None)
            if v is not None:
                by_sys.setdefault(r.experiment.system, []).append(float(v))
    return {s: summary_stats(v) for s, v in by_sys.items()}


def strong_scaling(
    points: Dict[int, float], *, band: float = 0.8
) -> Dict[int, Dict[str, float]]:
    """Strong-scaling efficiency vs the smallest node count (Fig. 5 bands).

    ``points``: {nodes: runtime}.  Efficiency = t0·n0 / (t·n).
    """
    if not points:
        return {}
    keys = sorted(points)
    nodes = np.array(keys, dtype=np.float64)
    t = np.array([points[k] for k in keys], dtype=np.float64)
    n0, t0 = nodes[0], t[0]
    ok = t > 0
    with np.errstate(divide="ignore", invalid="ignore"):
        eff = np.where(ok, (t0 * n0) / (t * nodes), 0.0)
        speedup = np.where(ok, t0 / t, 0.0)
    return {
        k: {
            "runtime": float(rt),
            "speedup": float(s),
            "efficiency": float(e),
            "within_band": bool(e >= band),
        }
        for k, rt, s, e in zip(keys, t.tolist(), speedup.tolist(), eff.tolist())
    }


def weak_scaling(
    points: Dict[int, float], *, band: float = 0.8
) -> Dict[int, Dict[str, float]]:
    """Weak-scaling efficiency (Fig. 7): ideal is constant runtime."""
    if not points:
        return {}
    keys = sorted(points)
    t = np.array([points[k] for k in keys], dtype=np.float64)
    t0 = t[0]
    with np.errstate(divide="ignore", invalid="ignore"):
        eff = np.where(t > 0, t0 / t, 0.0)
    return {
        k: {"runtime": float(rt), "efficiency": float(e),
            "within_band": bool(e >= band)}
        for k, rt, e in zip(keys, t.tolist(), eff.tolist())
    }


def injection_comparison(
    reports: Sequence[Report], metric: str, knob: str
) -> Dict[str, float]:
    """Metric as a function of an injected knob value (Fig. 6 semantics)."""
    out: Dict[str, float] = {}
    for r in reports:
        inj = r.parameter.get("injections", {})
        key = str(inj.get("env", {}).get(knob, inj.get("overrides", {}).get(knob, "default")))
        for d in r.data:
            if metric in d.metrics:
                out[key] = float(d.metrics[metric])
    return out


# ---- report emitters (markdown / CSV; Table I column order) ----

TABLE_I_COLUMNS = (
    "system", "version", "queue", "variant", "jobid", "nodes",
    "taskspernode", "threadspertasks", "runtime", "success",
)


def to_rows(reports: Sequence[Report]) -> List[Dict[str, object]]:
    rows = []
    for r in reports:
        for d in r.data:
            row: Dict[str, object] = {
                "system": r.experiment.system,
                "version": r.experiment.software_version,
                "queue": d.queue,
                "variant": r.experiment.variant,
                "jobid": d.job_id,
                "nodes": d.nodes,
                "taskspernode": d.tasks_per_node,
                "threadspertasks": d.threads_per_task,
                "runtime": d.runtime,
                "success": d.success,
            }
            row.update({f"additional_{k}": v for k, v in d.metrics.items()})
            rows.append(row)
    return rows


def to_csv(reports: Sequence[Report]) -> str:
    rows = to_rows(reports)
    if not rows:
        return ",".join(TABLE_I_COLUMNS) + "\n"
    cols = list(TABLE_I_COLUMNS) + sorted(
        {k for row in rows for k in row} - set(TABLE_I_COLUMNS)
    )
    lines = [",".join(cols)]
    for row in rows:
        lines.append(",".join(str(row.get(c, "")) for c in cols))
    return "\n".join(lines) + "\n"


def to_markdown(table: Dict[str, Dict[str, float]], title: str = "") -> str:
    if not table:
        return f"### {title}\n(no data)\n"
    cols = sorted({k for v in table.values() for k in v})
    lines = []
    if title:
        lines.append(f"### {title}")
    lines.append("| key | " + " | ".join(cols) + " |")
    lines.append("|---|" + "---|" * len(cols))
    for k, v in table.items():
        cells = []
        for c in cols:
            x = v.get(c, "")
            cells.append(f"{x:.4g}" if isinstance(x, float) else str(x))
        lines.append(f"| {k} | " + " | ".join(cells) + " |")
    return "\n".join(lines) + "\n"
