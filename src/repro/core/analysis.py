"""Lightweight analysis over protocol reports (paper §IV-F, §V-C).

exaCB guarantees the storage format and ships the analyses its experiments
need: time-series with regression detection (Figs. 3/4), machine comparison
(Fig. 5), feature-injection comparison (Fig. 6), strong/weak scaling with
efficiency bands (Figs. 5/7).  Heavier analysis is expected to live in
downstream tools; these functions are deliberately dependency-free
(numpy only) and pure, so they run identically inside or outside a full
exaCB workflow.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.protocol import Report


def to_series(reports: Sequence[Report], metric: str) -> List[Tuple[float, float]]:
    """(timestamp, value) points for one metric across reports."""
    pts = []
    for r in reports:
        for d in r.data:
            if metric in d.metrics:
                pts.append((r.experiment.timestamp, float(d.metrics[metric])))
            elif metric == "runtime":
                pts.append((r.experiment.timestamp, d.runtime))
    return sorted(pts)


@dataclasses.dataclass
class Regression:
    index: int
    timestamp: float
    value: float
    baseline: float
    sigma: float

    @property
    def relative(self) -> float:
        if self.baseline:
            return (self.value - self.baseline) / self.baseline
        # Zero baseline: any deviation is an infinite relative change, not a
        # silent 0.0 that downstream gates would read as "no regression".
        if self.value == self.baseline:
            return 0.0
        return math.copysign(math.inf, self.value - self.baseline)


def detect_regressions(
    series: Sequence[Tuple[float, float]],
    *,
    window: int = 8,
    z_threshold: float = 4.0,
    min_rel: float = 0.05,
) -> List[Regression]:
    """Change-point detection over a metric time-series (Fig. 4 semantics).

    Each point is compared against the median/MAD of the trailing window; a
    point is flagged when it deviates by more than ``z_threshold`` robust
    sigmas AND ``min_rel`` relatively (guards against ultra-low-variance
    series flagging measurement noise).
    """
    out: List[Regression] = []
    window = max(1, int(window))
    vals = np.array([v for _, v in series], dtype=np.float64)
    if vals.size <= window:  # empty/singleton/short series: nothing to judge
        return out
    for i in range(window, len(vals)):
        base = vals[i - window : i]
        med = float(np.median(base))
        mad = float(np.median(np.abs(base - med)))
        sigma = max(1.4826 * mad, 1e-12)
        dev = abs(vals[i] - med)
        if dev / sigma > z_threshold and (med == 0 or dev / abs(med) > min_rel):
            out.append(
                Regression(
                    index=i,
                    timestamp=series[i][0],
                    value=float(vals[i]),
                    baseline=med,
                    sigma=dev / sigma,
                )
            )
    return out


def compare_systems(
    reports: Sequence[Report], metric: str
) -> Dict[str, Dict[str, float]]:
    """Per-system summary statistics of one metric (Fig. 5 table)."""
    by_sys: Dict[str, List[float]] = {}
    for r in reports:
        for d in r.data:
            v = d.metrics.get(metric, d.runtime if metric == "runtime" else None)
            if v is not None:
                by_sys.setdefault(r.experiment.system, []).append(float(v))
    return {
        s: {
            "n": len(v),
            "median": float(np.median(v)),
            "mean": float(np.mean(v)),
            "min": float(np.min(v)),
            "max": float(np.max(v)),
        }
        for s, v in by_sys.items()
    }


def strong_scaling(
    points: Dict[int, float], *, band: float = 0.8
) -> Dict[int, Dict[str, float]]:
    """Strong-scaling efficiency vs the smallest node count (Fig. 5 bands).

    ``points``: {nodes: runtime}.  Efficiency = t0·n0 / (t·n).
    """
    if not points:
        return {}
    n0 = min(points)
    t0 = points[n0]
    out = {}
    for n, t in sorted(points.items()):
        eff = (t0 * n0) / (t * n) if t > 0 else 0.0
        out[n] = {
            "runtime": t,
            "speedup": t0 / t if t > 0 else 0.0,
            "efficiency": eff,
            "within_band": eff >= band,
        }
    return out


def weak_scaling(
    points: Dict[int, float], *, band: float = 0.8
) -> Dict[int, Dict[str, float]]:
    """Weak-scaling efficiency (Fig. 7): ideal is constant runtime."""
    if not points:
        return {}
    n0 = min(points)
    t0 = points[n0]
    out = {}
    for n, t in sorted(points.items()):
        eff = t0 / t if t > 0 else 0.0
        out[n] = {"runtime": t, "efficiency": eff, "within_band": eff >= band}
    return out


def injection_comparison(
    reports: Sequence[Report], metric: str, knob: str
) -> Dict[str, float]:
    """Metric as a function of an injected knob value (Fig. 6 semantics)."""
    out: Dict[str, float] = {}
    for r in reports:
        inj = r.parameter.get("injections", {})
        key = str(inj.get("env", {}).get(knob, inj.get("overrides", {}).get(knob, "default")))
        for d in r.data:
            if metric in d.metrics:
                out[key] = float(d.metrics[metric])
    return out


# ---- report emitters (markdown / CSV; Table I column order) ----

TABLE_I_COLUMNS = (
    "system", "version", "queue", "variant", "jobid", "nodes",
    "taskspernode", "threadspertasks", "runtime", "success",
)


def to_rows(reports: Sequence[Report]) -> List[Dict[str, object]]:
    rows = []
    for r in reports:
        for d in r.data:
            row: Dict[str, object] = {
                "system": r.experiment.system,
                "version": r.experiment.software_version,
                "queue": d.queue,
                "variant": r.experiment.variant,
                "jobid": d.job_id,
                "nodes": d.nodes,
                "taskspernode": d.tasks_per_node,
                "threadspertasks": d.threads_per_task,
                "runtime": d.runtime,
                "success": d.success,
            }
            row.update({f"additional_{k}": v for k, v in d.metrics.items()})
            rows.append(row)
    return rows


def to_csv(reports: Sequence[Report]) -> str:
    rows = to_rows(reports)
    if not rows:
        return ",".join(TABLE_I_COLUMNS) + "\n"
    cols = list(TABLE_I_COLUMNS) + sorted(
        {k for row in rows for k in row} - set(TABLE_I_COLUMNS)
    )
    lines = [",".join(cols)]
    for row in rows:
        lines.append(",".join(str(row.get(c, "")) for c in cols))
    return "\n".join(lines) + "\n"


def to_markdown(table: Dict[str, Dict[str, float]], title: str = "") -> str:
    if not table:
        return f"### {title}\n(no data)\n"
    cols = sorted({k for v in table.values() for k in v})
    lines = []
    if title:
        lines.append(f"### {title}")
    lines.append("| key | " + " | ".join(cols) + " |")
    lines.append("|---|" + "---|" * len(cols))
    for k, v in table.items():
        cells = []
        for c in cols:
            x = v.get(c, "")
            cells.append(f"{x:.4g}" if isinstance(x, float) else str(x))
        lines.append(f"| {k} | " + " | ".join(cells) + " |")
    return "\n".join(lines) + "\n"
