"""Append-only result store — the ``exacb.data`` orphan-branch analogue
(paper §IV-E / §V-A1 ``record: true``).

The store is split into a thin query/cache layer (``ResultStore``) over a
pluggable persistence backend:

* ``DirBackend``   — the original file-per-report layout: reports are JSON
  files named by monotonic sequence + content digest under
  ``<root>/<prefix>/``.  Sequence numbers are allocated via exclusive claim
  files so concurrent writers (scheduler workers, parallel CI jobs) can
  append to one prefix without clobbering each other.
* ``JsonlBackend`` — compact one-file-per-prefix layout
  (``<root>/<prefix>.jsonl``): one envelope line per report, appended under
  an exclusive file lock, with a sidecar offset index so queries can seek
  straight to matching records.

Both backends maintain a *manifest index* of per-report metadata (sequence,
digest, variant, system, timestamp, trust) so ``query()``/``latest()`` only
parse the records a filter actually selects, and ``ResultStore`` keeps an
mtime/size-invalidated cache of parsed reports so repeated queries over an
unchanged prefix re-parse nothing.

On top of the report cache, ``ResultStore.columnar`` exposes the incremental
columnar metrics plane (``repro.core.columnar``): per-prefix numpy column
arrays persisted as a compact sidecar next to each backend's data (the
``sidecar_path`` hook), extended in O(delta) on append and rebuilt once when
a prefix is pruned or mutated (the ``appended_only`` hook decides which).

Writes are atomic, never mutated, and digest-verified on read — so partially
failed pipelines cannot corrupt earlier results (the paper's resilience
argument for splitting execution from post-processing).  Externally produced
data can be ingested via an injection hook; such reports are marked
``chain_of_trust=False``.
"""

from __future__ import annotations

import dataclasses
import errno
import json
import os
import re
import tempfile
import threading
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.core import chaos
from repro.core.protocol import ProtocolError, Report
from repro.core.retry import call_with_retry

_REPORT_RE = re.compile(r"^(\d{8})\.([0-9a-f]{16})\.json$")
_CLAIM_RE = re.compile(r"^(\d{8})\.claim$")
_MANIFEST = "_manifest.jsonl"
_APPEND_RETRIES = 256


class StoreError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class IndexEntry:
    """Manifest-index row: enough metadata to filter without parsing the
    report, plus the locator needed to fetch it."""

    key: str            # backend locator: filename (dir) / "seq:offset:length" (jsonl)
    seq: int
    digest: str
    variant: str
    system: str
    timestamp: float
    trusted: bool

    def matches(
        self,
        *,
        variant: Optional[str] = None,
        system: Optional[str] = None,
        since: Optional[float] = None,
        until: Optional[float] = None,
        trusted_only: bool = False,
    ) -> bool:
        if variant is not None and self.variant != variant:
            return False
        if system is not None and self.system != system:
            return False
        if since is not None and self.timestamp < since:
            return False
        if until is not None and self.timestamp > until:
            return False
        if trusted_only and not self.trusted:
            return False
        return True


def _entry_for(report: Report, key: str, seq: int, digest: str) -> IndexEntry:
    return IndexEntry(
        key=key,
        seq=seq,
        digest=digest,
        variant=report.experiment.variant,
        system=report.experiment.system,
        timestamp=report.experiment.timestamp,
        trusted=report.reporter.chain_of_trust,
    )


def _entry_line(e: IndexEntry) -> str:
    return json.dumps(dataclasses.asdict(e), sort_keys=True) + "\n"


class StoreBackend:
    """Persistence interface: everything ``ResultStore`` needs from a layout."""

    name = "abstract"

    def __init__(self, root: str | Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._locks: Dict[str, threading.Lock] = {}
        self._locks_guard = threading.Lock()

    def _prefix_lock(self, prefix: str) -> threading.Lock:
        # Per-prefix: appends to independent prefixes (multi-system
        # campaigns) must not serialize against each other.
        with self._locks_guard:
            return self._locks.setdefault(_safe(prefix), threading.Lock())

    def append(self, prefix: str, report: Report) -> Path:
        raise NotImplementedError

    def scan(self, prefix: str) -> List[IndexEntry]:
        """Manifest index for one prefix, in sequence order (rebuilt from the
        raw records when missing or inconsistent)."""
        raise NotImplementedError

    def fetch(self, prefix: str, entries: List[IndexEntry]) -> Dict[str, Report]:
        """Parse + digest-verify the named records; corrupt ones are skipped
        (a bad record must not take down analyses of the rest)."""
        raise NotImplementedError

    def prefixes(self) -> List[str]:
        raise NotImplementedError

    def fingerprint(self, prefix: str) -> Tuple:
        """Cheap token that changes whenever the prefix's content changes
        (creation, append, or in-place tamper)."""
        raise NotImplementedError

    def retained(self, old_fp: Tuple, new_fp: Tuple,
                 parsed: Dict[str, Report]) -> Dict[str, Report]:
        """Subset of a stale parsed-report cache still valid under the new
        fingerprint.  Default: nothing (full re-parse on any change)."""
        return {}

    def sidecar_path(self, prefix: str, name: str) -> Path:
        """Where a derived per-prefix sidecar (e.g. the columnar index) is
        persisted for this layout.  Sidecars must never collide with the
        record/manifest namespace — ``scan``/``fingerprint`` ignore them."""
        raise NotImplementedError

    def appended_only(self, old_fp: Tuple, new_fp: Tuple) -> bool:
        """True when the fingerprint transition can only have *appended*
        records (every record covered by ``old_fp`` is untouched).  This is
        what lets incremental consumers (the columnar plane) extend instead
        of rebuild; a prune or in-place mutation must return False."""
        return False


class DirBackend(StoreBackend):
    """File-per-report layout (the seed's on-disk format, unchanged)."""

    name = "dir"

    def _dir(self, prefix: str) -> Path:
        return self.root / _safe(prefix)

    # ---- write path ----
    def append(self, prefix: str, report: Report) -> Path:
        chaos.trip("store.append")
        d = self._dir(prefix)
        d.mkdir(parents=True, exist_ok=True)
        digest = report.digest()
        payload = report.to_json(indent=2)
        # Concurrency-safe sequence allocation, three layers deep: the
        # in-process lock covers scheduler workers, the directory flock
        # covers concurrent processes (POSIX), and the O_EXCL claim file is
        # the retry-on-collision arbiter for writers outside either lock —
        # two writers racing the directory listing get distinct sequences
        # instead of silently clobbering.
        with self._prefix_lock(d.name):
            lock_fd = os.open(d / ".lock", os.O_CREAT | os.O_RDWR, 0o644)
            try:
                _flock(lock_fd)
                for _ in range(_APPEND_RETRIES):
                    seq = self._next_seq(d)
                    claim = d / f"{seq:08d}.claim"
                    try:
                        fd = os.open(claim, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                    except FileExistsError:
                        continue
                    os.close(fd)
                    try:
                        path = d / f"{seq:08d}.{digest}.json"
                        cut = chaos.torn("store.append", len(payload))
                        if cut is not None:
                            # Emulate a filesystem without atomic rename: the
                            # truncated bytes land at the *final* path before
                            # the write errors out.  The read path skips the
                            # digest-mismatched file; a retried append simply
                            # allocates the next sequence.
                            path.write_text(payload[:cut])
                            raise OSError(
                                errno.EIO, f"chaos: torn write {path.name}")
                        _atomic_write(path, payload)
                        self._append_manifest(
                            d, _entry_for(report, path.name, seq, digest)
                        )
                        return path
                    finally:
                        claim.unlink(missing_ok=True)
            finally:
                _funlock(lock_fd)
                os.close(lock_fd)
        raise StoreError(f"could not allocate a sequence in {d} "
                         f"after {_APPEND_RETRIES} attempts")

    def _next_seq(self, d: Path) -> int:
        seqs = [
            int(m.group(1))
            for p in d.iterdir()
            if (m := _REPORT_RE.match(p.name) or _CLAIM_RE.match(p.name))
        ]
        return (max(seqs) + 1) if seqs else 0

    def _append_manifest(self, d: Path, entry: IndexEntry) -> None:
        # Caller holds the append locks; O_APPEND keeps foreign writers safe.
        fd = os.open(d / _MANIFEST, os.O_CREAT | os.O_WRONLY | os.O_APPEND, 0o644)
        try:
            os.write(fd, _entry_line(entry).encode())
        finally:
            os.close(fd)

    # ---- read path ----
    def scan(self, prefix: str) -> List[IndexEntry]:
        d = self._dir(prefix)
        if not d.exists():
            return []
        files = sorted(p.name for p in d.iterdir() if _REPORT_RE.match(p.name))
        manifest = self._read_manifest(d)
        if set(manifest) != set(files):
            manifest = self._rebuild_manifest(d, files)
        return sorted((manifest[f] for f in files), key=lambda e: (e.seq, e.key))

    def _read_manifest(self, d: Path) -> Dict[str, IndexEntry]:
        out: Dict[str, IndexEntry] = {}
        try:
            text = (d / _MANIFEST).read_text()
        except OSError:
            return out
        for line in text.splitlines():
            try:
                entry = IndexEntry(**json.loads(line))
            except (TypeError, ValueError):
                continue
            out[entry.key] = entry
        return out

    def _rebuild_manifest(self, d: Path, files: List[str]) -> Dict[str, IndexEntry]:
        out: Dict[str, IndexEntry] = {}
        for name in files:
            m = _REPORT_RE.match(name)
            try:
                report = Report.from_json((d / name).read_text())
            except (OSError, ProtocolError, json.JSONDecodeError):
                # Unreadable now; index it so fetch() gets to skip it loudly.
                out[name] = IndexEntry(name, int(m.group(1)), m.group(2),
                                       "", "", 0.0, False)
                continue
            out[name] = _entry_for(report, name, int(m.group(1)), m.group(2))
        with self._prefix_lock(d.name):
            _atomic_write(d / _MANIFEST, "".join(_entry_line(e) for e in out.values()))
        return out

    def fetch(self, prefix: str, entries: List[IndexEntry]) -> Dict[str, Report]:
        d = self._dir(prefix)
        out: Dict[str, Report] = {}
        for e in entries:
            try:
                report = Report.from_json((d / e.key).read_text())
            except (OSError, ProtocolError, json.JSONDecodeError):
                continue
            if report.digest() != e.key.split(".")[1]:
                continue
            out[e.key] = report
        return out

    def prefixes(self) -> List[str]:
        # Underscore directories are store-internal state, not report
        # prefixes — the execution plane keeps its work queues under
        # ``<root>/_queue/`` and a whole-store scan must not read them.
        return sorted(p.name for p in self.root.iterdir()
                      if p.is_dir() and not p.name.startswith("_"))

    def fingerprint(self, prefix: str) -> Tuple:
        # os.scandir: one directory pass, cheap per-entry stats — this runs
        # on every query, so it is the store's hottest read path.
        try:
            it = os.scandir(self._dir(prefix))
        except FileNotFoundError:
            return ()
        with it:
            out = [
                (de.name, st.st_size, st.st_mtime_ns)
                for de in it
                if _REPORT_RE.match(de.name)
                for st in (de.stat(),)
            ]
        out.sort()
        return tuple(out)

    def retained(self, old_fp: Tuple, new_fp: Tuple,
                 parsed: Dict[str, Report]) -> Dict[str, Report]:
        # Report files are immutable: a cached parse stays valid as long as
        # the file's (name, size, mtime) is unchanged — appends of *new*
        # files don't invalidate the siblings.
        stable = {t[0] for t in set(old_fp) & set(new_fp)}
        return {k: r for k, r in parsed.items() if k in stable}

    def sidecar_path(self, prefix: str, name: str) -> Path:
        # Leading underscore keeps it out of _REPORT_RE (scan/fingerprint).
        return self._dir(prefix) / f"_{name}"

    def appended_only(self, old_fp: Tuple, new_fp: Tuple) -> bool:
        # Append-only iff every previously fingerprinted report file is
        # stat-identical — a deleted or touched file forces a rebuild.
        return set(old_fp).issubset(set(new_fp))


class JsonlBackend(StoreBackend):
    """Compact one-file-per-prefix layout with a sidecar offset index."""

    name = "jsonl"

    def __init__(self, root: str | Path):
        super().__init__(root)
        # prefix -> (last seq, covered bytes): lets append skip re-reading
        # the sidecar when nothing else wrote since (checked against fstat).
        self._tail: Dict[str, Tuple[int, int]] = {}

    def _data(self, prefix: str) -> Path:
        return self.root / f"{_safe(prefix)}.jsonl"

    def _idx(self, prefix: str) -> Path:
        return self.root / f"{_safe(prefix)}.jsonl.idx"

    # ---- write path ----
    def append(self, prefix: str, report: Report) -> Path:
        chaos.trip("store.append")
        data = self._data(prefix)
        digest = report.digest()
        doc = report.to_dict()
        with self._prefix_lock(prefix):
            # O_RDWR (not O_WRONLY): the torn-tail check preads the last byte.
            fd = os.open(data, os.O_CREAT | os.O_RDWR | os.O_APPEND, 0o644)
            try:
                _flock(fd)
                size = os.fstat(fd).st_size
                tail = self._tail.get(prefix)
                if tail is not None and tail[1] == size:
                    seq = tail[0] + 1  # nothing else wrote since — O(1) path
                else:
                    entries = self._load_index(prefix)
                    seq = (entries[-1].seq + 1) if entries else 0
                offset = size
                line = json.dumps(
                    {"seq": seq, "digest": digest, "report": doc}, sort_keys=True
                ).encode() + b"\n"
                # A torn tail (crash mid-append) may lack its newline: start
                # a fresh line so this record stays seekable AND scannable.
                if size > 0 and os.pread(fd, 1, size - 1) != b"\n":
                    os.write(fd, b"\n")
                    offset = size + 1
                cut = chaos.torn("store.append", len(line))
                if cut is not None:
                    # Crash-mid-append emulation: a partial envelope line
                    # with no newline — exactly the torn tail the index
                    # rebuild and the next append already know how to skip.
                    os.write(fd, line[:cut])
                    self._tail.pop(prefix, None)
                    raise OSError(errno.EIO, "chaos: torn jsonl append")
                os.write(fd, line)
                entry = _entry_for(report, f"{seq}:{offset}:{len(line)}", seq, digest)
                with open(self._idx(prefix), "a") as f:
                    f.write(_entry_line(entry))
                self._tail[prefix] = (seq, offset + len(line))
            finally:
                _funlock(fd)
                os.close(fd)
        return data

    # ---- read path ----
    def _load_index(self, prefix: str) -> List[IndexEntry]:
        data = self._data(prefix)
        if not data.exists():
            return []
        size = data.stat().st_size
        entries: List[IndexEntry] = []
        marker = 0  # "covered" watermark written after a rebuild
        try:
            for line in self._idx(prefix).read_text().splitlines():
                try:
                    doc = json.loads(line)
                    if "covered" in doc:
                        marker = max(marker, int(doc["covered"]))
                        continue
                    entries.append(IndexEntry(**doc))
                except (TypeError, ValueError):
                    entries, marker = [], 0
                    break
        except OSError:
            pass
        covered = marker
        if entries:
            _, off, length = entries[-1].key.split(":")
            covered = max(covered, int(off) + int(length))
        if covered != size:
            entries = self._rebuild_index(prefix)
        return entries

    def _rebuild_index(self, prefix: str) -> List[IndexEntry]:
        entries: List[IndexEntry] = []
        offset = 0
        with open(self._data(prefix), "rb") as f:
            for raw in f:
                length = len(raw)
                try:
                    env = json.loads(raw)
                    report = Report.from_dict(env["report"])
                    entries.append(_entry_for(
                        report, f"{env['seq']}:{offset}:{length}",
                        int(env["seq"]), str(env["digest"]),
                    ))
                except (KeyError, TypeError, ValueError, ProtocolError):
                    pass  # torn/corrupt line — skipped, later records survive
                offset += length
        # The watermark records how far this rebuild looked: with a corrupt
        # line in the file, entry spans alone can never cover the full size,
        # and without it every subsequent scan would re-rebuild forever.
        lines = [_entry_line(e) for e in entries]
        lines.append(json.dumps({"covered": offset}) + "\n")
        _atomic_write(self._idx(prefix), "".join(lines))
        return entries

    def scan(self, prefix: str) -> List[IndexEntry]:
        with self._prefix_lock(prefix):
            return sorted(self._load_index(prefix), key=lambda e: e.seq)

    def fetch(self, prefix: str, entries: List[IndexEntry]) -> Dict[str, Report]:
        out: Dict[str, Report] = {}
        try:
            f = open(self._data(prefix), "rb")
        except OSError:
            return out
        with f:
            for e in entries:
                _, off, length = e.key.split(":")
                f.seek(int(off))
                raw = f.read(int(length))
                try:
                    env = json.loads(raw)
                    report = Report.from_dict(env["report"])
                except (KeyError, TypeError, ValueError, ProtocolError):
                    continue
                if report.digest() != env.get("digest"):
                    continue
                out[e.key] = report
        return out

    def prefixes(self) -> List[str]:
        return sorted(p.name[: -len(".jsonl")] for p in self.root.iterdir()
                      if p.name.endswith(".jsonl"))

    def fingerprint(self, prefix: str) -> Tuple:
        # Single stat, no exists() pre-check: this runs on every warm query
        # and every columnar-table hit, so one syscall matters.
        try:
            st = self._data(prefix).stat()
        except OSError:
            return ()
        return (st.st_size, st.st_mtime_ns)

    def retained(self, old_fp: Tuple, new_fp: Tuple,
                 parsed: Dict[str, Report]) -> Dict[str, Report]:
        # Envelope lines are immutable once written: a pure append only ever
        # grows the file, so every previously parsed record stays valid and
        # a warm query after an append re-parses only the new tail.  A
        # same-size mtime change or a shrink can be a rewrite — drop all.
        # Trade-off (mirrors DirBackend's stat-identity trust): size growth
        # is taken as append evidence, so an out-of-band mid-file rewrite
        # that also grows the file can keep stale in-memory parses for this
        # process's lifetime — a fresh process re-parses (and digest-checks)
        # everything, and the columnar plane independently re-verifies the
        # covered region via its cover hash.
        if old_fp and new_fp and new_fp[0] > old_fp[0]:
            return dict(parsed)
        return {}

    def sidecar_path(self, prefix: str, name: str) -> Path:
        # ``.jsonl.<name>`` — prefixes() only lists names ending in .jsonl.
        return self.root / f"{_safe(prefix)}.jsonl.{name}"

    def appended_only(self, old_fp: Tuple, new_fp: Tuple) -> bool:
        # The single data file only grows under append; any transition that
        # is not a strict size increase may be a prune/rewrite.
        return not old_fp or bool(new_fp and new_fp[0] > old_fp[0])


_BACKENDS = {"dir": DirBackend, "jsonl": JsonlBackend}


class ResultStore:
    """Query/cache layer over a pluggable backend.

    ``ResultStore(root)`` keeps the seed's file-per-report layout;
    ``ResultStore(root, backend="jsonl")`` selects the compact layout.  A
    pre-built ``StoreBackend`` instance is also accepted.
    """

    def __init__(self, root: str | Path = "", backend: str | StoreBackend = "dir"):
        if isinstance(backend, StoreBackend):
            self.backend = backend
        else:
            try:
                self.backend = _BACKENDS[backend](root)
            except KeyError:
                raise StoreError(
                    f"unknown store backend {backend!r} (have {sorted(_BACKENDS)})"
                ) from None
        self.root = getattr(self.backend, "root", Path(root))
        # prefix -> (fingerprint, index, {key: parsed report})
        self._cache: Dict[str, Tuple[Tuple, List[IndexEntry], Dict[str, Report]]] = {}
        self._cache_lock = threading.Lock()
        self._columnar = None

    # ---- write path ----
    def append(self, prefix: str, report: Report) -> Path:
        """Atomically persist one report; returns its path.  Safe to call
        from concurrent scheduler workers sharing one prefix.

        Transient I/O failures (the shared taxonomy in
        ``repro.core.retry``) are retried with bounded backoff; both
        backends leave no *indexed* state behind on a failed attempt, so a
        retry is a clean re-append.  A failure that survives every retry
        propagates — the worker's degraded mode (self-fence) takes over.
        """
        report.validate()
        return call_with_retry(
            lambda: self.backend.append(prefix, report), label="store.append")

    def ingest_external(self, prefix: str, doc: dict) -> Path:
        """Injection hook for externally provided data (§IV-E).

        The resulting chain of trust is not guaranteed — mark it so.
        """
        report = Report.from_dict(doc)
        report.reporter.chain_of_trust = False
        return self.append(prefix, report)

    # ---- read path ----
    def prefixes(self) -> List[str]:
        return self.backend.prefixes()

    def read(self, path: Path) -> Report:
        """Parse + verify one report file (file-per-report layout)."""
        text = path.read_text()
        report = Report.from_json(text)
        want = path.name.split(".")[1]
        got = report.digest()
        if want != got:
            raise StoreError(f"integrity failure for {path}: {want} != {got}")
        return report

    def _indexed(self, prefix: str) -> Tuple[List[IndexEntry], Dict[str, Report]]:
        """Manifest index + parsed-report cache, invalidated whenever the
        backend fingerprint (names/sizes/mtimes) changes."""
        fp = self.backend.fingerprint(prefix)
        with self._cache_lock:
            cached = self._cache.get(prefix)
            if cached is not None and cached[0] == fp:
                return cached[1], cached[2]
        index = self.backend.scan(prefix)
        with self._cache_lock:
            parsed: Dict[str, Report] = {}
            if cached is not None:
                parsed = self.backend.retained(cached[0], fp, cached[2])
            self._cache[prefix] = (fp, index, parsed)
            return index, parsed

    def query_with_entries(
        self,
        prefix: str,
        *,
        variant: Optional[str] = None,
        system: Optional[str] = None,
        since: Optional[float] = None,
        until: Optional[float] = None,
        trusted_only: bool = False,
        last: Optional[int] = None,
    ) -> List[Tuple[IndexEntry, Report]]:
        """Like ``query`` but pairs each report with its manifest entry, so
        consumers (regression gating, change-point naming) see the store
        *sequence* a result landed at.

        ``last=N`` keeps only the newest N matching entries — the slice
        happens on the index before any record is fetched, so tailing a long
        history parses O(N) reports, not O(history).
        """
        index, parsed = self._indexed(prefix)
        wanted = [e for e in index if e.matches(
            variant=variant, system=system, since=since, until=until,
            trusted_only=trusted_only,
        )]
        if last is not None:
            wanted = wanted[-max(0, int(last)):] if last > 0 else []
        return self._fetch(prefix, wanted, parsed)

    def index(self, prefix: str) -> List[IndexEntry]:
        """The (cached) manifest index for one prefix, in sequence order —
        metadata only, no report is parsed."""
        return self._indexed(prefix)[0]

    def fetch_entries(
        self, prefix: str, entries: List[IndexEntry]
    ) -> List[Tuple[IndexEntry, Report]]:
        """Parse the named entries through the warm-report cache; corrupt
        records are dropped (same contract as ``query``).  This is the fetch
        primitive the columnar plane uses to pull exactly the delta past its
        watermark."""
        _, parsed = self._indexed(prefix)
        return self._fetch(prefix, entries, parsed)

    def _fetch(
        self, prefix: str, entries: List[IndexEntry], parsed: Dict[str, Report]
    ) -> List[Tuple[IndexEntry, Report]]:
        missing = [e for e in entries if e.key not in parsed]
        if missing:
            fetched = self.backend.fetch(prefix, missing)
            with self._cache_lock:
                parsed.update(fetched)
        return [(e, parsed[e.key]) for e in entries if e.key in parsed]

    def query(self, prefix: str, **kw) -> List[Report]:
        return [r for _, r in self.query_with_entries(prefix, **kw)]

    def latest(self, prefix: str, **kw) -> Optional[Report]:
        rs = self.query(prefix, **kw)
        return rs[-1] if rs else None

    # ---- columnar metrics plane ----
    @property
    def columnar(self):
        """The incremental columnar index over this store (lazily built;
        see ``repro.core.columnar``)."""
        if self._columnar is None:
            from repro.core.columnar import ColumnarIndex  # avoid cycle

            with self._cache_lock:
                if self._columnar is None:
                    self._columnar = ColumnarIndex(self)
        return self._columnar

    def metric_series(self, prefix: str, metric: str, **kw):
        """Vectorized ``(seq, timestamp, value)`` arrays for one metric —
        the columnar fast path (``repro.core.columnar.MetricSeries``)."""
        return self.columnar.table(prefix).series(metric, **kw)


def _atomic_write(path: Path, payload: str) -> None:
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(payload)
        os.replace(tmp, path)  # atomic on POSIX
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _flock(fd: int) -> None:
    try:
        import fcntl

        fcntl.flock(fd, fcntl.LOCK_EX)
    except (ImportError, OSError):  # non-POSIX: in-process lock still holds
        pass


def _funlock(fd: int) -> None:
    try:
        import fcntl

        fcntl.flock(fd, fcntl.LOCK_UN)
    except (ImportError, OSError):
        pass


def _safe(prefix: str) -> str:
    ok = "".join(c if (c.isalnum() or c in ".-_") else "_" for c in prefix)
    if not ok:
        raise StoreError("empty store prefix")
    return ok
