"""Append-only result store — the ``exacb.data`` orphan-branch analogue
(paper §IV-E / §V-A1 ``record: true``).

Reports are written as individual JSON files named by monotonic sequence +
content digest under ``<root>/<prefix>/``.  Writes are atomic (tmp+rename),
never mutated, and verified on read — so partially-failed pipelines cannot
corrupt earlier results (the paper's resilience argument for splitting
execution from post-processing).  Externally produced data can be ingested
via an injection hook; such reports are marked ``chain_of_trust=False``.
"""

from __future__ import annotations

import json
import os
import tempfile
import time
from pathlib import Path
from typing import Iterator, List, Optional

from repro.core.protocol import ProtocolError, Report


class StoreError(RuntimeError):
    pass


class ResultStore:
    def __init__(self, root: str | Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    # ---- write path ----
    def append(self, prefix: str, report: Report) -> Path:
        """Atomically persist one report; returns its path."""
        report.validate()
        d = self.root / _safe(prefix)
        d.mkdir(parents=True, exist_ok=True)
        seq = self._next_seq(d)
        digest = report.digest()
        path = d / f"{seq:08d}.{digest}.json"
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(report.to_json(indent=2))
            os.replace(tmp, path)  # atomic on POSIX
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return path

    def ingest_external(self, prefix: str, doc: dict) -> Path:
        """Injection hook for externally provided data (§IV-E).

        The resulting chain of trust is not guaranteed — mark it so.
        """
        report = Report.from_dict(doc)
        report.reporter.chain_of_trust = False
        return self.append(prefix, report)

    # ---- read path ----
    def prefixes(self) -> List[str]:
        return sorted(p.name for p in self.root.iterdir() if p.is_dir())

    def read(self, path: Path) -> Report:
        text = path.read_text()
        report = Report.from_json(text)
        want = path.name.split(".")[1]
        got = report.digest()
        if want != got:
            raise StoreError(f"integrity failure for {path}: {want} != {got}")
        return report

    def query(
        self,
        prefix: str,
        *,
        variant: Optional[str] = None,
        system: Optional[str] = None,
        since: Optional[float] = None,
        until: Optional[float] = None,
        trusted_only: bool = False,
    ) -> List[Report]:
        d = self.root / _safe(prefix)
        if not d.exists():
            return []
        out = []
        for p in sorted(d.glob("*.json")):
            try:
                r = self.read(p)
            except (ProtocolError, StoreError, json.JSONDecodeError):
                # A corrupt record must not take down analyses of the rest.
                continue
            if variant is not None and r.experiment.variant != variant:
                continue
            if system is not None and r.experiment.system != system:
                continue
            ts = r.experiment.timestamp
            if since is not None and ts < since:
                continue
            if until is not None and ts > until:
                continue
            if trusted_only and not r.reporter.chain_of_trust:
                continue
            out.append(r)
        return out

    def latest(self, prefix: str, **kw) -> Optional[Report]:
        rs = self.query(prefix, **kw)
        return rs[-1] if rs else None

    def _next_seq(self, d: Path) -> int:
        seqs = [int(p.name.split(".")[0]) for p in d.glob("*.json")]
        return (max(seqs) + 1) if seqs else 0


def _safe(prefix: str) -> str:
    ok = "".join(c if (c.isalnum() or c in ".-_") else "_" for c in prefix)
    if not ok:
        raise StoreError("empty store prefix")
    return ok
