"""The ``Campaign`` facade — exaCB's single documented entry point.

Everything a continuous-benchmarking campaign needs sits behind one object:
the component registry (typed, versioned schemas), the campaign scheduler,
the result store, and the regression gates.  The ``python -m repro`` CLI
(``run`` / ``validate`` / ``components``) is a thin wrapper over this class,
and so is any library use::

    from repro.core.api import Campaign

    c = Campaign("exacb_data")
    c.validate("examples/pipelines/smoke.yml")   # schema-check, no execution
    results = c.run("examples/pipelines/smoke.yml", parallelism=2)
    print(c.report()["markdown"])                # cross-prefix summary
    verdict = c.gate("ci.smoke", metrics=["step_time_s"])

See ``docs/component_api.md`` for the full contract (schemas, registry,
migration shims, harness capability negotiation).
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

from repro.core import cicd
from repro.core.component import (
    REGISTRY,
    ComponentContext,
    ComponentRegistry,
    PipelineError,
)
from repro.core.harness import Harness
from repro.core.store import ResultStore


def _pipeline_text(pipeline: Union[str, Path]) -> str:
    """A path (existing file) or a literal document (anything with a
    newline / JSON braces) — the CLI and tests use both freely."""
    s = str(pipeline)
    if "\n" not in s and not s.lstrip().startswith("{"):
        p = Path(s)
        if not p.exists():
            raise PipelineError(f"pipeline file not found: {s}")
        return p.read_text()
    return s


class Campaign:
    """Registry → scheduler → store → gates behind one object."""

    def __init__(
        self,
        store: Union[str, Path, ResultStore] = "exacb_data",
        *,
        backend: str = "dir",
        harness: Optional[Harness] = None,
        harness_factory: Optional[Callable[[Dict[str, Any]], Harness]] = None,
        parallelism: Optional[int] = None,
        registry: Optional[ComponentRegistry] = None,
    ):
        self._store_spec = store
        self._backend = backend
        self._store: Optional[ResultStore] = \
            store if isinstance(store, ResultStore) else None
        self.harness = harness
        self.harness_factory = harness_factory
        self.parallelism = parallelism
        self.registry = registry or REGISTRY

    @property
    def store(self) -> ResultStore:
        """Created lazily so read-only entry points (``validate``,
        ``components``) never touch the filesystem."""
        if self._store is None:
            self._store = ResultStore(self._store_spec, backend=self._backend)
        return self._store

    # ------------------------------------------------------------ pipelines
    def validate(self, pipeline: Union[str, Path]) -> List[Dict[str, Any]]:
        """Schema-check a pipeline document without executing anything.
        Returns one summary per component (resolved version, coerced inputs,
        DAG edges); raises ``PipelineError`` naming the offending component
        and field."""
        return cicd.validate_pipeline(_pipeline_text(pipeline),
                                      registry=self.registry)

    def run(self, pipeline: Union[str, Path], *,
            parallelism: Optional[int] = None,
            workers: Optional[int] = None,
            worker_mode: Optional[str] = None) -> List[Dict[str, Any]]:
        """Parse, validate, and dispatch a pipeline document through the
        component DAG and the campaign scheduler.  ``worker_mode="process"``
        (or any component declaring it) drains producer cells through the
        broker + spawned worker pool instead of the in-process threads."""
        calls = cicd.parse_pipeline_text(_pipeline_text(pipeline),
                                         registry=self.registry)
        return cicd.run_pipeline(
            calls,
            store=self.store,
            harness=self.harness,
            harness_factory=self.harness_factory,
            parallelism=parallelism if parallelism is not None else self.parallelism,
            registry=self.registry,
            workers=workers,
            worker_mode=worker_mode,
        )

    # ----------------------------------------------------------- components
    def components(self) -> List[Dict[str, Any]]:
        """Registry listing: every accepted component reference with its
        declared inputs (types, defaults, choices, deprecated aliases) —
        migration shims included."""
        return self.registry.describe()

    def component(self, name: str, version: int, inputs: Dict[str, Any],
                  **extra_inputs: Any) -> Any:
        """Run one component invocation directly (no document needed)."""
        resolved = self.registry.resolve(name, version)
        # Same harness default as cicd.run_pipeline, so a facade without an
        # explicit harness behaves identically to `python -m repro run`.
        harness = self.harness
        if harness is None and self.harness_factory is None:
            from repro.core.harness import ExecHarness

            harness = ExecHarness(steps=2, batch=2, seq=16)
        ctx = ComponentContext(store=self.store, harness=harness,
                               harness_factory=self.harness_factory)
        return resolved.run({**dict(inputs), **extra_inputs}, ctx)

    # ---------------------------------------------------------- collections
    def run_collection(
        self,
        system: Union[str, Sequence[str]],
        *,
        archs: Optional[List[str]] = None,
        shapes: Optional[List[str]] = None,
        prefix: str = "collection",
        require_readiness=None,
        parallelism: Optional[int] = None,
        workers: Optional[int] = None,
        worker_mode: Optional[str] = None,
        record: bool = True,
    ):
        """Expand the benchmark collection for ``system`` and run every cell
        through the execution orchestrator (failure-isolated, streamed into
        the store).  Requires a ``harness`` on the facade.
        ``worker_mode="process"`` drains the cells through the broker +
        spawned worker pool (the harness must declare a ``spawn_spec``)."""
        from repro.core import registry as collection_registry
        from repro.core.orchestrator import ExecutionOrchestrator

        if self.harness is None:
            raise PipelineError("Campaign.run_collection needs a harness")
        specs = collection_registry.collection(
            system, archs=archs, shapes=shapes,
            require_readiness=require_readiness)
        inputs: Dict[str, Any] = {
            "prefix": prefix, "record": record,
            "parallelism": parallelism or self.parallelism or 1,
        }
        if workers is not None:
            inputs["workers"] = workers
        if worker_mode is not None:
            inputs["worker_mode"] = worker_mode
        ex = ExecutionOrchestrator(
            inputs=inputs,
            harness=self.harness,
            store=self.store,
        )
        return ex.run_collection(specs)

    # ---------------------------------------------------------------- gates
    def gate(self, source_prefix: str, **inputs: Any) -> Dict[str, Any]:
        """Run a regression gate over one prefix's stored history; inputs
        follow the ``gate@v1`` schema."""
        return self.component("gate", 1,
                              {"source_prefix": source_prefix, **inputs})

    def report(self, metric: str = "step_time_s",
               prefixes: Optional[List[str]] = None) -> Dict[str, Any]:
        """Cross-prefix campaign summary (the ``campaign-report@v1``
        component) in one columnar scan."""
        inputs: Dict[str, Any] = {"metric": metric}
        if prefixes:
            inputs["prefixes"] = list(prefixes)
        return self.component("campaign-report", 1, inputs)


def main(argv=None) -> int:
    """``python -m repro`` — run / validate / components."""
    import argparse

    ap = argparse.ArgumentParser(
        prog="repro",
        description="exaCB campaign entry point (typed component API)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    run = sub.add_parser("run", help="run a pipeline document")
    run.add_argument("pipeline")
    run.add_argument("--store", default="exacb_data")
    run.add_argument("--store-backend", default="dir", choices=("dir", "jsonl"))
    run.add_argument("--parallelism", type=int, default=None)
    run.add_argument("--workers", type=int, default=None,
                     help="execution-plane worker count")
    run.add_argument("--worker-mode", default=None,
                     choices=("thread", "process"),
                     help="process = broker + spawned worker pool with "
                          "lease-reclaimed crash recovery")
    run.add_argument("--gate", action="store_true",
                     help="enforce regression gates (exit 3 on regression)")
    run.add_argument("--gate-report", default="gate_report.json")

    val = sub.add_parser("validate",
                         help="schema-check a pipeline document, no execution")
    val.add_argument("pipeline")

    sub.add_parser("components",
                   help="list every registered component with its schema")

    aut = sub.add_parser(
        "autotune",
        help="sweep a pallas kernel's block grid, promote the winner into "
             "the autotune cache and as a pinned latency baseline")
    aut.add_argument("--kernel", required=True,
                     choices=("flash_attention", "rglru", "ssd"))
    aut.add_argument("--store", default="exacb_data")
    aut.add_argument("--store-backend", default="dir", choices=("dir", "jsonl"))
    aut.add_argument("--prefix", default=None,
                     help="store prefix (default: autotune.<kernel>)")
    for knob in ("block-q", "block-k", "chunk", "block-w"):
        aut.add_argument(f"--{knob}", default=None,
                         help=f"comma-separated {knob.replace('-', '_')} "
                              "candidates")
    for dim, dv in (("batch", 1), ("heads", 2), ("seq", 128),
                    ("head-dim", 16), ("width", 64), ("state", 16)):
        aut.add_argument(f"--{dim}", type=int, default=dv)
    aut.add_argument("--dtype", default="float32")
    aut.add_argument("--calls", type=int, default=3)
    aut.add_argument("--warmup", type=int, default=1)
    aut.add_argument("--confirm", type=int, default=3)
    aut.add_argument("--cache", default="",
                     help="cache file (default: <store>/autotune_cache.json)")
    aut.add_argument("--interpret", action="store_true",
                     help="force pallas interpret mode")
    aut.add_argument("--no-baseline", action="store_true",
                     help="skip pinning the winner as the gate baseline")
    aut.add_argument("--force", action="store_true",
                     help="re-sweep even on a cache hit")

    def _daemon_common(p):
        p.add_argument("documents", nargs="+",
                       help="pipeline documents to watch (schedule@v1 "
                            "declares each document's refresh policy)")
        p.add_argument("--store", default="exacb_data")
        p.add_argument("--store-backend", default="dir",
                       choices=("dir", "jsonl"))
        p.add_argument("--state", default=None,
                       help="daemon state file (default: "
                            "<store>/daemon_state.json)")
        p.add_argument("--target-lag", type=float, default=None,
                       help="override every document's target_lag (seconds)")

    dmn = sub.add_parser(
        "daemon",
        help="continuous service: re-execute cells on declarative triggers "
             "(lag / downstream / watermark), resuming from the store")
    _daemon_common(dmn)
    dmn.add_argument("--interval", type=float, default=None,
                     help="override the tick interval (seconds)")
    dmn.add_argument("--workers", type=int, default=2)
    dmn.add_argument("--worker-mode", default="thread",
                     choices=("thread", "process"),
                     help="refresh dispatch: in-process scheduler, or "
                          "broker + spawned worker pool")
    dmn.add_argument("--max-ticks", type=int, default=None,
                     help="exit cleanly after N ticks (CI / smoke mode)")

    dst = sub.add_parser(
        "daemon-status",
        help="per-document lag / last-refresh / next-due / queue-depth "
             "from the state file and store (no running daemon needed)")
    _daemon_common(dst)
    dst.add_argument("--json", action="store_true", dest="as_json",
                     help="machine-readable output")
    dst.add_argument("--clear-quarantine", nargs="?", const="", default=None,
                     dest="clear_quarantine", metavar="CELL",
                     help="lift quarantine before reporting: pass a cell key "
                          "to clear one cell, or no value to clear every "
                          "quarantined cell")
    dst.add_argument("--suspend", default=None, metavar="DOC",
                     help="park one document's schedule (path or basename): "
                          "persisted in the state file and skipped by every "
                          "staleness scan until resumed")
    dst.add_argument("--resume", default=None, metavar="DOC",
                     help="lift a suspension set with --suspend")

    args = ap.parse_args(argv)
    if args.cmd == "run":
        # Delegate to the cicd CLI so gate-report/exit-code semantics stay
        # in exactly one place.
        cicd_args = [args.pipeline, "--store", args.store,
                     "--store-backend", args.store_backend]
        if args.parallelism is not None:
            cicd_args += ["--parallelism", str(args.parallelism)]
        if args.workers is not None:
            cicd_args += ["--workers", str(args.workers)]
        if args.worker_mode is not None:
            cicd_args += ["--worker-mode", args.worker_mode]
        if args.gate:
            cicd_args += ["--gate", "--gate-report", args.gate_report]
        return cicd.main(cicd_args)
    if args.cmd == "validate":
        # Same delegation as `run`: one implementation of the INVALID/OK
        # reporting and exit codes, in cicd.main.
        return cicd.main([args.pipeline, "--validate"])
    if args.cmd == "daemon":
        from repro.core.daemon import CampaignDaemon

        try:
            daemon = CampaignDaemon(
                args.store, args.documents,
                backend=args.store_backend,
                state_path=args.state,
                workers=args.workers,
                worker_mode=args.worker_mode,
                target_lag=args.target_lag,
                interval=args.interval,
                max_ticks=args.max_ticks,
            )
        except (OSError, PipelineError) as e:
            import sys
            print(f"daemon: {e}", file=sys.stderr)
            return 1
        return daemon.run()
    if args.cmd == "autotune":
        import sys

        def _ints(s):
            return [int(v) for v in s.split(",") if v.strip()] if s else []

        inputs = {
            "kernel": args.kernel,
            "prefix": args.prefix or f"autotune.{args.kernel}",
            "block_q": _ints(args.block_q), "block_k": _ints(args.block_k),
            "chunk": _ints(args.chunk), "block_w": _ints(args.block_w),
            "batch": args.batch, "heads": args.heads, "seq": args.seq,
            "head_dim": args.head_dim, "width": args.width,
            "state": args.state, "dtype": args.dtype,
            "calls": args.calls, "warmup": args.warmup,
            "confirm": args.confirm, "cache": args.cache,
            "baseline": not args.no_baseline, "force": args.force,
        }
        if args.interpret:
            inputs["interpret"] = True
        try:
            out = Campaign(args.store, backend=args.store_backend).component(
                "autotune", 1, inputs)
        except PipelineError as e:
            print(f"autotune: {e}", file=sys.stderr)
            return 1
        print(json.dumps(out, indent=2, default=str))
        return 1 if out.get("error") else 0
    if args.cmd == "daemon-status":
        from repro.core.daemon import CampaignDaemon, daemon_status, render_status

        try:
            wants_daemon = (args.clear_quarantine is not None
                            or args.suspend or args.resume)
            if wants_daemon:
                daemon = CampaignDaemon(
                    args.store, args.documents,
                    backend=args.store_backend,
                    state_path=args.state,
                    target_lag=args.target_lag,
                )
                if args.clear_quarantine is not None:
                    for key in daemon.clear_quarantine(
                            args.clear_quarantine or None):
                        print(f"cleared quarantine: {key}")
                if args.suspend:
                    for path in daemon.suspend(args.suspend):
                        print(f"suspended: {path}")
                if args.resume:
                    for path in daemon.resume(args.resume):
                        print(f"resumed: {path}")
            status = daemon_status(
                args.store, args.documents,
                backend=args.store_backend,
                state_path=args.state,
                target_lag=args.target_lag,
            )
        except (OSError, PipelineError) as e:
            import sys
            print(f"daemon-status: {e}", file=sys.stderr)
            return 1
        if args.as_json:
            print(json.dumps(status, indent=2, default=str))
        else:
            print(render_status(status))
        return 0
    print(json.dumps(Campaign().components(), indent=2, default=str))
    return 0
