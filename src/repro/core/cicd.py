"""Declarative CI/CD pipeline layer (paper §II-C, §V-A).

The paper's user-facing interface is a ``.gitlab-ci.yml`` that includes
reusable components::

    include:
      - component: execution@v3
        inputs:
          prefix:  "jedi.strong.tiny"
          variant: "large-intensity"
          machine: "jedi"
          jube_file: "simple.yaml"

This module is the runner for that interface: a pipeline document (JSON, or
the built-in minimal YAML subset — no external deps) is parsed into component
invocations and dispatched to the orchestrators.  Components are versioned
(``execution@v3``); unknown majors are rejected, matching the paper's
schema-evolution discipline.  Analysis components (``time-series``,
``machine-comparison``, ``scalability``, ``gate``) read the store through
the incremental columnar plane (``repro.core.columnar``) by default; pass
``columnar: false`` in a component's inputs for the report-object reference
path.  The cross-prefix ``campaign-report`` is columnar-native — the
``CampaignFrame`` one-scan query *is* the feature, so it has no report path.

    PYTHONPATH=src python -m repro.core.cicd examples/pipelines/collection.yml
"""

from __future__ import annotations

import dataclasses
import functools
import json
import re
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.harness import BenchmarkSpec, ExecHarness, Harness, Injections
from repro.core.orchestrator import (
    ExecutionOrchestrator,
    FeatureInjectionOrchestrator,
    GateOrchestrator,
    PostProcessingOrchestrator,
)
from repro.core.scheduler import CampaignScheduler, Task
from repro.core.store import ResultStore

SUPPORTED = {
    "execution": (3,),
    "feature-injection": (3,),
    "time-series": (3,),
    "machine-comparison": (3,),
    "scalability": (3,),
    "gate": (1,),
    "campaign-report": (1,),
}

# ``cicd --gate`` exit code when a gate component reports a regression —
# distinct from 1 (component/infrastructure error) so CI can tell "the
# benchmark got slower" from "the pipeline broke".
EXIT_REGRESSION = 3


class PipelineError(ValueError):
    pass


@dataclasses.dataclass
class ComponentCall:
    name: str
    version: int
    inputs: Dict[str, Any]


# ---------------------------------------------------------------------------
# Minimal YAML-subset parser (mappings, lists of mappings, scalars) — enough
# for the paper's pipeline examples without a yaml dependency.
# ---------------------------------------------------------------------------

def _parse_scalar(s: str) -> Any:
    s = s.strip()
    # Quoting forces string: '"true"' / '"123"' stay strings, so coercion
    # must be decided BEFORE the quotes come off.
    if len(s) >= 2 and s[0] == s[-1] and s[0] in "\"'":
        return s[1:-1]
    if s.startswith("[") and s.endswith("]"):
        inner = s[1:-1].strip()
        return [_parse_scalar(x) for x in inner.split(",")] if inner else []
    if s.lower() in ("true", "false"):
        return s.lower() == "true"
    if re.fullmatch(r"[-+]?\d+", s):
        return int(s)
    # Floats: leading-dot (.5), trailing-dot (1.), and exponent (1e-3) forms.
    if re.fullmatch(r"[-+]?(\d+\.\d*|\.\d+|\d+)([eE][+-]?\d+)?", s):
        return float(s)
    return s


def parse_pipeline_text(text: str) -> List[ComponentCall]:
    """Parse a pipeline document (JSON or the YAML subset)."""
    text_stripped = text.strip()
    if text_stripped.startswith("{"):
        doc = json.loads(text_stripped)
        return _from_doc(doc)
    calls: List[ComponentCall] = []
    cur: Optional[Tuple[str, int]] = None
    inputs: Dict[str, Any] = {}
    in_inputs = False
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].rstrip()
        if not line.strip():
            continue
        if line.strip() in ("include:",):
            continue
        m = re.match(r"\s*-\s*component:\s*(\S+)", line)
        if m:
            if cur:
                calls.append(ComponentCall(cur[0], cur[1], inputs))
            cur = _split_component(m.group(1))
            inputs, in_inputs = {}, False
            continue
        if re.match(r"\s*inputs:\s*$", line):
            in_inputs = True
            continue
        # Dots in input keys carry detector tuning (``mad.z_threshold: 6``).
        m = re.match(r"\s*([\w.\-]+):\s*(.+)$", line)
        if m and in_inputs:
            inputs[m.group(1)] = _parse_scalar(m.group(2))
            continue
        if line.strip():
            raise PipelineError(f"unparseable pipeline line: {raw!r}")
    if cur:
        calls.append(ComponentCall(cur[0], cur[1], inputs))
    if not calls:
        raise PipelineError("pipeline contains no component invocations")
    return calls


def _split_component(ref: str) -> Tuple[str, int]:
    m = re.fullmatch(r"([\w\-]+)@v(\d+)(?:\.\d+)*", ref)
    if not m:
        raise PipelineError(f"bad component reference {ref!r} (want name@vN)")
    name, major = m.group(1), int(m.group(2))
    if name not in SUPPORTED:
        raise PipelineError(f"unknown component {name!r}")
    if major not in SUPPORTED[name]:
        raise PipelineError(f"{name}@v{major} unsupported (have v{SUPPORTED[name]})")
    return name, major


def _from_doc(doc: Dict[str, Any]) -> List[ComponentCall]:
    calls = []
    for item in doc.get("include", []):
        name, major = _split_component(item["component"])
        calls.append(ComponentCall(name, major, dict(item.get("inputs", {}))))
    if not calls:
        raise PipelineError("pipeline contains no component invocations")
    return calls


# ---------------------------------------------------------------------------
# Dispatch — components form a DAG (post-processing reads the prefixes that
# execution components write) and run through the campaign scheduler.
# ---------------------------------------------------------------------------

_PRODUCERS = ("execution", "feature-injection")


def _consumed_prefixes(call: ComponentCall) -> List[str]:
    """Store prefixes a component reads — its upstream edges."""
    inp = call.inputs
    if call.name in ("time-series", "scalability", "gate"):
        return [inp["source_prefix"]] if "source_prefix" in inp else []
    if call.name == "machine-comparison":
        out = []
        for sel in inp.get("selector", []):
            out.append(sel if isinstance(sel, str) else sel.get("prefix"))
        return [p for p in out if p]
    if call.name == "campaign-report":
        return [p for p in inp.get("prefixes", []) if p]
    return []


def component_dag(calls: List[ComponentCall]) -> List[List[int]]:
    """Dependency edges: ``deps[i]`` = indices call *i* must wait for.

    A post-processing component depends on every earlier component that
    produces a prefix it consumes; producers are mutually independent, so a
    collection's executions fan out across the worker pool while each
    analysis still sees all of its upstream reports.  A ``campaign-report``
    without an explicit ``prefixes`` input reads the *whole* store, so it
    waits for every earlier producer.
    """
    produced: Dict[str, List[int]] = {}
    producers: List[int] = []
    deps: List[List[int]] = []
    for i, call in enumerate(calls):
        if call.name == "campaign-report" and not call.inputs.get("prefixes"):
            mine = list(producers)
        else:
            mine = sorted({j for p in _consumed_prefixes(call)
                           for j in produced.get(p, [])})
        deps.append(mine)
        if call.name in _PRODUCERS:
            # Mirror ExecutionOrchestrator.prefix: no explicit input means
            # the cell records under "default" — still a produced prefix.
            produced.setdefault(call.inputs.get("prefix") or "default", []).append(i)
            producers.append(i)
    return deps


def _run_component(
    call: ComponentCall,
    *,
    store: ResultStore,
    harness: Harness,
    harness_factory: Optional[Callable[[Dict[str, Any]], Harness]],
) -> Dict[str, Any]:
    inp = call.inputs
    if call.name == "execution":
        h = harness_factory(inp) if harness_factory else harness
        ex = ExecutionOrchestrator(inputs=inp, harness=h, store=store)
        spec = BenchmarkSpec(
            arch=inp["arch"],
            shape=inp.get("usecase", inp.get("shape", "train_4k")),
            system=inp.get("machine", "cpu-smoke"),
            variant=inp.get("variant", ""),
        )
        res = ex.run_cell(spec)
        return {
            "component": "execution",
            "cell": spec.cell,
            "readiness": int(res.readiness),
            "error": res.error,
        }
    if call.name == "feature-injection":
        h = harness_factory(inp) if harness_factory else harness
        ex = ExecutionOrchestrator(inputs=inp, harness=h, store=store)
        fi = FeatureInjectionOrchestrator(execution=ex, inputs=inp)
        spec = BenchmarkSpec(
            arch=inp["arch"],
            shape=inp.get("usecase", "train_4k"),
            system=inp.get("machine", "cpu-smoke"),
        )
        inj = Injections()
        if "in_command" in inp:  # paper: env-var injection string
            for assign in str(inp["in_command"]).replace("export ", "").split(";"):
                if "=" in assign:
                    k, v = assign.split("=", 1)
                    inj.env[k.strip()] = v.strip()
        for k in ("remat", "microbatches", "strategy", "opt_state_dtype"):
            if k in inp:
                inj.overrides[k] = inp[k]
        res = fi.run(spec, inj)
        return {
            "component": "feature-injection",
            "cell": spec.cell,
            "readiness": int(res.readiness),
            "error": res.error,
        }
    if call.name == "time-series":
        pp = PostProcessingOrchestrator(store=store, inputs=inp)
        out = pp.time_series(
            source_prefix=inp["source_prefix"],
            data_labels=list(inp.get("data_labels", ["step_time_s"])),
            pipeline=list(inp.get("pipeline", [])),
        )
        return {
            "component": "time-series",
            "points": {k: len(v) for k, v in out["series"].items()},
            "regressions": {k: len(v) for k, v in out["regressions"].items()},
        }
    if call.name == "machine-comparison":
        pp = PostProcessingOrchestrator(store=store, inputs=inp)
        out = pp.machine_comparison(
            selectors=[{"prefix": p} for p in inp.get("selector", [])],
            metric=inp.get("metric", "step_time_s"),
        )
        return {"component": "machine-comparison", "table": out["table"]}
    if call.name == "scalability":
        pp = PostProcessingOrchestrator(store=store, inputs=inp)
        out = pp.scalability(
            source_prefix=inp["source_prefix"],
            metric=inp.get("metric", "step_time_s"),
            mode=inp.get("mode", "strong"),
        )
        return {"component": "scalability", "table": out["table"]}
    if call.name == "gate":
        return GateOrchestrator(store=store, inputs=inp).run()
    if call.name == "campaign-report":
        from repro.core import analysis
        from repro.core.columnar import CampaignFrame

        metric = inp.get("metric", "step_time_s")
        frame = CampaignFrame(store, prefixes=inp.get("prefixes") or None)
        table = frame.summary(metric)
        return {
            "component": "campaign-report",
            "metric": metric,
            "prefixes": len(table),
            "table": table,
            "watermarks": frame.watermarks(),
            "markdown": analysis.to_markdown(
                table, f"campaign summary: {metric}"),
        }
    raise PipelineError(call.name)  # pragma: no cover — guarded by _split_component


def run_pipeline(
    calls: List[ComponentCall],
    *,
    store: ResultStore,
    harness: Optional[Harness] = None,
    harness_factory: Optional[Callable[[Dict[str, Any]], Harness]] = None,
    parallelism: Optional[int] = None,
) -> List[Dict[str, Any]]:
    """Dispatch the component DAG through the scheduler; returns one summary
    per call, in call order.

    ``parallelism`` bounds the worker pool.  When omitted, the largest
    ``parallelism:`` input declared by any component applies (default 1 —
    serial, the seed behavior).  A component that raises is isolated into a
    ``{"component", "error"}`` summary; downstream components still run over
    whatever results reached the store.
    """
    harness = harness or ExecHarness(steps=2, batch=2, seq=16)
    if parallelism is None:
        parallelism = max(
            [int(c.inputs.get("parallelism", 1)) for c in calls], default=1
        )
    deps = component_dag(calls)
    tasks = [
        Task(
            key=f"{i:04d}.{call.name}",
            fn=functools.partial(
                _run_component, call,
                store=store, harness=harness, harness_factory=harness_factory,
            ),
            deps=frozenset(f"{j:04d}.{calls[j].name}" for j in deps[i]),
        )
        for i, call in enumerate(calls)
    ]
    done = CampaignScheduler(parallelism=parallelism, name="pipeline").run_tasks(tasks)
    results = []
    for i, call in enumerate(calls):
        tr = done[f"{i:04d}.{call.name}"]
        if tr.error is not None:
            results.append({"component": call.name, "error": tr.error})
        else:
            results.append(tr.value)
    return results


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("pipeline", help="pipeline file (.yml subset or .json)")
    ap.add_argument("--store", default="exacb_data")
    ap.add_argument("--store-backend", default="dir", choices=("dir", "jsonl"))
    ap.add_argument("--parallelism", type=int, default=None,
                    help="worker pool bound (default: max parallelism input)")
    ap.add_argument("--gate", action="store_true",
                    help="enforce regression gates: exit 3 when any gate "
                         "component reports a regression, and write the gate "
                         "report (JSON + markdown twin)")
    ap.add_argument("--gate-report", default="gate_report.json",
                    help="gate report path used with --gate; a .md summary "
                         "suitable for a PR comment lands next to it")
    args = ap.parse_args(argv)
    calls = parse_pipeline_text(Path(args.pipeline).read_text())
    results = run_pipeline(
        calls,
        store=ResultStore(args.store, backend=args.store_backend),
        parallelism=args.parallelism,
    )
    print(json.dumps(results, indent=2, default=str))
    component_error = any(r.get("error") for r in results)
    if not args.gate:
        return 0 if not component_error else 1

    from repro.core import regression

    summaries = [r for r in results
                 if r.get("component") == "gate" and "status" in r]
    status = regression.worst(s["status"] for s in summaries)
    # Infrastructure failure trumps the gate verdict: a crashed component
    # means the store may be missing results a gate needed to judge.
    exit_code = 1 if component_error else (
        EXIT_REGRESSION if status == regression.FAIL else 0)
    md = regression.gate_markdown(summaries)
    report = {
        "status": status,
        "exit_code": exit_code,
        "pipeline": str(args.pipeline),
        "store": str(args.store),
        "gates": [g for s in summaries for g in s["gates"]],
        "markdown": md,
    }
    path = Path(args.gate_report)
    path.write_text(
        json.dumps(regression.json_safe(report), indent=2, default=str) + "\n")
    path.with_suffix(".md").write_text(md + "\n")
    print(md)
    return exit_code


if __name__ == "__main__":
    import sys

    sys.exit(main())
