"""Declarative CI/CD pipeline layer (paper §II-C, §V-A).

The paper's user-facing interface is a ``.gitlab-ci.yml`` that includes
reusable components::

    include:
      - component: execution@v3
        inputs:
          prefix:  "jedi.strong.tiny"
          variant: "large-intensity"
          machine: "jedi"
          jube_file: "simple.yaml"

This module is the runner for that interface: a pipeline document (JSON, or
the built-in minimal YAML subset — no external deps) is parsed into component
invocations, validated against the declared input schemas in the component
registry (``repro.core.component``; orchestrators self-register on import),
and dispatched through the registered runners.  Components are versioned
(``execution@v4``); unknown majors are rejected while migration shims keep
older documents (``execution@v3``) running, matching the paper's
schema-evolution discipline — and unknown input keys or type mismatches are
hard errors at parse time (``--validate`` schema-checks a document without
executing it).  Analysis components (``time-series``,
``machine-comparison``, ``scalability``, ``gate``) read the store through
the incremental columnar plane (``repro.core.columnar``) by default; pass
``columnar: false`` in a component's inputs for the report-object reference
path.  The cross-prefix ``campaign-report`` is columnar-native — the
``CampaignFrame`` one-scan query *is* the feature, so it has no report path.

    PYTHONPATH=src python -m repro.core.cicd examples/pipelines/collection.yml
"""

from __future__ import annotations

import dataclasses
import functools
import json
import re
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import orchestrator as _orchestrator  # registers components
from repro.core.component import (
    REGISTRY,
    ComponentContext,
    ComponentInputs,
    ComponentRegistry,
    PipelineError,
    resolve_parallelism,
)
from repro.core.harness import ExecHarness, Harness
from repro.core.scheduler import CampaignScheduler, Task
from repro.core.store import ResultStore

# ``cicd --gate`` exit code when a gate component reports a regression —
# distinct from 1 (component/infrastructure error) so CI can tell "the
# benchmark got slower" from "the pipeline broke".
EXIT_REGRESSION = 3


@dataclasses.dataclass
class ComponentCall:
    """One parsed component invocation.  ``version`` is the major the
    document declared (a v3 reference stays ``version=3`` even though the
    registry runs it through the v3→v4 shim); ``inputs`` are already
    validated/coerced/migrated ``ComponentInputs``."""

    name: str
    version: int
    inputs: Dict[str, Any]

    @property
    def ref(self) -> str:
        return f"{self.name}@v{self.version}"


# ---------------------------------------------------------------------------
# Minimal YAML-subset parser (mappings, lists of mappings, scalars) — enough
# for the paper's pipeline examples without a yaml dependency.
# ---------------------------------------------------------------------------

def _split_inline_list(inner: str) -> List[str]:
    """Split an inline-list body on commas, quote-aware: a comma inside a
    quoted element (``["a,b", "c"]``) is content, not a separator."""
    parts: List[str] = []
    buf: List[str] = []
    quote: Optional[str] = None
    for ch in inner:
        if quote is not None:
            buf.append(ch)
            if ch == quote:
                quote = None
        elif ch in "\"'":
            quote = ch
            buf.append(ch)
        elif ch == ",":
            parts.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    parts.append("".join(buf))
    return parts


def _parse_scalar(s: str) -> Any:
    s = s.strip()
    # Quoting forces string: '"true"' / '"123"' stay strings, so coercion
    # must be decided BEFORE the quotes come off.
    if len(s) >= 2 and s[0] == s[-1] and s[0] in "\"'":
        return s[1:-1]
    if s.startswith("[") and s.endswith("]"):
        inner = s[1:-1].strip()
        return [_parse_scalar(x) for x in _split_inline_list(inner)] if inner else []
    if s.lower() in ("true", "false"):
        return s.lower() == "true"
    if re.fullmatch(r"[-+]?\d+", s):
        return int(s)
    # Floats: leading-dot (.5), trailing-dot (1.), and exponent (1e-3) forms.
    if re.fullmatch(r"[-+]?(\d+\.\d*|\.\d+|\d+)([eE][+-]?\d+)?", s):
        return float(s)
    return s


def parse_pipeline_text(
    text: str, *, registry: Optional[ComponentRegistry] = None
) -> List[ComponentCall]:
    """Parse a pipeline document (JSON or the YAML subset) and validate every
    component invocation through the registry: unknown components/majors,
    unknown input keys, and type mismatches are hard ``PipelineError``\\ s at
    parse time — before anything executes (the paper's schema-evolution
    discipline applied to the whole document, not just the version tag)."""
    registry = registry or REGISTRY
    text_stripped = text.strip()
    if text_stripped.startswith("{"):
        doc = json.loads(text_stripped)
        return _from_doc(doc, registry)
    calls: List[ComponentCall] = []
    cur: Optional[Tuple[str, int]] = None
    inputs: Dict[str, Any] = {}
    in_inputs = False
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].rstrip()
        if not line.strip():
            continue
        if line.strip() in ("include:",):
            continue
        m = re.match(r"\s*-\s*component:\s*(\S+)", line)
        if m:
            if cur:
                calls.append(_validated_call(cur[0], cur[1], inputs, registry))
            cur = _split_component(m.group(1), registry)
            inputs, in_inputs = {}, False
            continue
        if re.match(r"\s*inputs:\s*$", line):
            in_inputs = True
            continue
        # Dots in input keys carry detector tuning (``mad.z_threshold: 6``).
        m = re.match(r"\s*([\w.\-]+):\s*(.+)$", line)
        if m and in_inputs:
            inputs[m.group(1)] = _parse_scalar(m.group(2))
            continue
        if line.strip():
            raise PipelineError(f"unparseable pipeline line: {raw!r}")
    if cur:
        calls.append(_validated_call(cur[0], cur[1], inputs, registry))
    if not calls:
        raise PipelineError("pipeline contains no component invocations")
    return calls


def _validated_call(name: str, version: int, inputs: Dict[str, Any],
                    registry: ComponentRegistry) -> ComponentCall:
    return ComponentCall(
        name, version, registry.parse_inputs(name, version, inputs))


def _split_component(ref: str, registry: ComponentRegistry) -> Tuple[str, int]:
    m = re.fullmatch(r"([\w\-]+)@v(\d+)(?:\.\d+)*", ref)
    if not m:
        raise PipelineError(f"bad component reference {ref!r} (want name@vN)")
    name, major = m.group(1), int(m.group(2))
    registry.resolve(name, major)  # unknown name/major is a hard error
    return name, major


def _from_doc(doc: Dict[str, Any], registry: ComponentRegistry) -> List[ComponentCall]:
    calls = []
    for item in doc.get("include", []):
        name, major = _split_component(item["component"], registry)
        calls.append(_validated_call(
            name, major, dict(item.get("inputs", {})), registry))
    if not calls:
        raise PipelineError("pipeline contains no component invocations")
    return calls


# ---------------------------------------------------------------------------
# Dispatch — components form a DAG (post-processing reads the prefixes that
# execution components write) and run through the campaign scheduler.
# ---------------------------------------------------------------------------

_PRODUCERS = ("execution", "feature-injection")

# Components whose reports land under their `prefix` input — the edge set
# the DAG orders consumers behind.  `autotune` writes sweep cells + a pinned
# baseline but is not broker-drainable (its sweep loop IS the component),
# so it is a prefix writer without being a _PRODUCER.
_PREFIX_WRITERS = _PRODUCERS + ("autotune",)


def _consumed_prefixes(call: ComponentCall) -> List[str]:
    """Store prefixes a component reads — its upstream edges."""
    inp = call.inputs
    if call.name in ("time-series", "scalability", "gate"):
        return [inp["source_prefix"]] if "source_prefix" in inp else []
    if call.name == "machine-comparison":
        out = []
        for sel in inp.get("selector", []):
            out.append(sel if isinstance(sel, str) else sel.get("prefix"))
        return [p for p in out if p]
    if call.name == "campaign-report":
        return [p for p in inp.get("prefixes", []) if p]
    return []


def component_dag(calls: List[ComponentCall]) -> List[List[int]]:
    """Dependency edges: ``deps[i]`` = indices call *i* must wait for.

    A post-processing component depends on every earlier component that
    produces a prefix it consumes; producers are mutually independent, so a
    collection's executions fan out across the worker pool while each
    analysis still sees all of its upstream reports.  A ``campaign-report``
    without an explicit ``prefixes`` input reads the *whole* store, so it
    waits for every earlier producer.
    """
    produced: Dict[str, List[int]] = {}
    producers: List[int] = []
    deps: List[List[int]] = []
    for i, call in enumerate(calls):
        if call.name == "campaign-report" and not call.inputs.get("prefixes"):
            mine = list(producers)
        else:
            mine = sorted({j for p in _consumed_prefixes(call)
                           for j in produced.get(p, [])})
        deps.append(mine)
        if call.name in _PREFIX_WRITERS:
            # Mirror ExecutionOrchestrator.prefix: no explicit input means
            # the cell records under "default" — still a produced prefix.
            produced.setdefault(call.inputs.get("prefix") or "default", []).append(i)
            producers.append(i)
    return deps


def _run_component(
    call: ComponentCall,
    *,
    store: ResultStore,
    harness: Harness,
    harness_factory: Optional[Callable[[Dict[str, Any]], Harness]],
    registry: Optional[ComponentRegistry] = None,
) -> Dict[str, Any]:
    """Resolve the call through the registry (following migration shims) and
    dispatch its runner with validated inputs."""
    resolved = (registry or REGISTRY).resolve(call.name, call.version)
    ctx = ComponentContext(
        store=store, harness=harness, harness_factory=harness_factory)
    return resolved.run(call.inputs, ctx)


def run_pipeline(
    calls: List[ComponentCall],
    *,
    store: ResultStore,
    harness: Optional[Harness] = None,
    harness_factory: Optional[Callable[[Dict[str, Any]], Harness]] = None,
    parallelism: Optional[int] = None,
    registry: Optional[ComponentRegistry] = None,
    workers: Optional[int] = None,
    worker_mode: Optional[str] = None,
) -> List[Dict[str, Any]]:
    """Dispatch the component DAG through the scheduler; returns one summary
    per call, in call order.

    ``parallelism``/``workers`` bound the worker pool.  When omitted, the
    largest ``workers:``/``parallelism:`` input declared by any component
    applies (default 1 — serial, the seed behavior).  ``worker_mode``
    (argument, or any component declaring ``worker_mode: process``) selects
    the dispatch plane: ``thread`` runs everything through the in-process
    scheduler; ``process`` drains every *producer* cell — executions and
    individual sweep points alike — through the broker + spawned worker
    pool first, then runs the consumers (analyses, gates) in-process over
    the persisted results.  A component that raises is isolated into a
    ``{"component", "error"}`` summary; downstream components still run
    over whatever results reached the store.
    """
    harness = harness or ExecHarness(steps=2, batch=2, seq=16)
    if worker_mode is None:
        declared = {str(c.inputs.get("worker_mode", "thread")) for c in calls}
        worker_mode = "process" if "process" in declared else "thread"
    if parallelism is None:
        parallelism = max([resolve_parallelism(c.inputs) for c in calls],
                          default=1)
    pool = workers if workers is not None else parallelism
    if worker_mode == "process":
        if harness_factory is not None:
            raise PipelineError(
                "worker_mode 'process' cannot combine with a harness_factory "
                "callable (workers rebuild the harness from its spawn_spec)")
        return _run_pipeline_process(
            calls, store=store, harness=harness, workers=pool,
            registry=registry)
    parallelism = pool
    deps = component_dag(calls)
    tasks = [
        Task(
            key=f"{i:04d}.{call.name}",
            fn=functools.partial(
                _run_component, call,
                store=store, harness=harness, harness_factory=harness_factory,
                registry=registry,
            ),
            deps=frozenset(f"{j:04d}.{calls[j].name}" for j in deps[i]),
            meta=call.ref,
        )
        for i, call in enumerate(calls)
    ]
    done = CampaignScheduler(parallelism=parallelism, name="pipeline").run_tasks(tasks)
    results = []
    for i, call in enumerate(calls):
        tr = done[f"{i:04d}.{call.name}"]
        if tr.error is not None:
            results.append({"component": call.name, "component_ref": call.ref,
                            "error": tr.error})
        else:
            results.append(tr.value)
    return results


def _run_pipeline_process(
    calls: List[ComponentCall],
    *,
    store: ResultStore,
    harness: Harness,
    workers: int,
    registry: Optional[ComponentRegistry] = None,
) -> List[Dict[str, Any]]:
    """Process-mode pipeline dispatch: producers drain through the broker's
    worker pool (one queue cell per execution / per sweep point), consumers
    run in-process afterwards — the broker barrier subsumes every
    producer→consumer DAG edge; consumer→consumer edges (an analysis over a
    prefix an in-process `autotune` sweep writes) are kept."""
    from repro.core import workers as workers_mod  # lazy: heavy import chain

    summaries: List[Optional[Dict[str, Any]]] = [None] * len(calls)
    payloads: List[Dict[str, Any]] = []
    owners: Dict[int, List[int]] = {}
    for ci, call in enumerate(calls):
        if call.name not in _PRODUCERS:
            continue
        try:
            cell_payloads, _ = workers_mod.pipeline_payloads([call])
        except PipelineError as e:  # isolated, like a thread-mode task error
            summaries[ci] = {"component": call.name, "component_ref": call.ref,
                             "error": str(e)}
            continue
        owners[ci] = list(range(len(payloads), len(payloads) + len(cell_payloads)))
        for p in cell_payloads:
            p["call_index"] = ci
        payloads.extend(cell_payloads)

    results_by_idx: Dict[int, Dict[str, Any]] = {}
    if payloads:
        broker = workers_mod.CampaignBroker(store, workers=workers, name="pipeline")
        results_by_idx = broker.run(payloads, harness=harness)

    for ci, idxs in owners.items():
        call = calls[ci]
        spec = _orchestrator.spec_from_inputs(call.inputs)
        cells = [workers_mod.result_to_cell(spec, results_by_idx.get(j))
                 for j in idxs]
        if call.name == "execution" or (len(cells) == 1
                                        and not call.inputs.get("values")):
            summaries[ci] = _orchestrator._cell_summary(call.name, spec, cells[0])
        else:
            errors = [c.error for c in cells if c.error]
            summaries[ci] = {
                "component": call.name,
                "cell": spec.cell,
                "points": len(cells),
                "readiness": [int(c.readiness) for c in cells],
                "error": "; ".join(errors) if errors else None,
            }

    deps = component_dag(calls)
    consumer_ids = [ci for ci in range(len(calls)) if summaries[ci] is None]
    tasks = [
        Task(
            key=f"{ci:04d}.{calls[ci].name}",
            fn=functools.partial(
                _run_component, calls[ci],
                store=store, harness=harness, harness_factory=None,
                registry=registry,
            ),
            # Producer edges are already satisfied by the broker barrier;
            # only consumer→consumer edges survive (e.g. a gate reading the
            # prefix an in-process `autotune` sweep writes).
            deps=frozenset(f"{j:04d}.{calls[j].name}" for j in deps[ci]
                           if j in set(consumer_ids)),
            meta=calls[ci].ref,
        )
        for ci in consumer_ids
    ]
    done = CampaignScheduler(
        parallelism=min(4, max(1, workers)), name="pipeline.consumers"
    ).run_tasks(tasks)
    for ci in consumer_ids:
        tr = done[f"{ci:04d}.{calls[ci].name}"]
        if tr.error is not None:
            summaries[ci] = {"component": calls[ci].name,
                             "component_ref": calls[ci].ref, "error": tr.error}
        else:
            summaries[ci] = tr.value
    return summaries  # type: ignore[return-value] — every slot filled above


def validate_pipeline(
    text: str, *, registry: Optional[ComponentRegistry] = None
) -> List[Dict[str, Any]]:
    """Schema-check a pipeline document without executing anything: parse,
    resolve every component through the registry (shims included), validate
    and coerce every input.  Returns one summary per call — or raises
    ``PipelineError`` naming the offending component and field."""
    registry = registry or REGISTRY
    calls = parse_pipeline_text(text, registry=registry)
    deps = component_dag(calls)
    return [
        {
            "component": call.ref,
            "resolved": f"{call.name}@v{registry.resolve(call.name, call.version).target_version}",
            "inputs": {k: v for k, v in call.inputs.items()},
            "depends_on": [calls[j].ref for j in deps[i]],
        }
        for i, call in enumerate(calls)
    ]


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("pipeline", help="pipeline file (.yml subset or .json)")
    ap.add_argument("--store", default="exacb_data")
    ap.add_argument("--store-backend", default="dir", choices=("dir", "jsonl"))
    ap.add_argument("--parallelism", type=int, default=None,
                    help="worker pool bound (default: max parallelism input)")
    ap.add_argument("--workers", type=int, default=None,
                    help="execution-plane worker count (overrides "
                         "--parallelism and any declared inputs)")
    ap.add_argument("--worker-mode", default=None, choices=("thread", "process"),
                    help="thread: in-process scheduler pool (default); "
                         "process: broker + spawned worker processes with "
                         "lease-reclaimed crash recovery")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check the pipeline document (components, "
                         "versions, input names and types) and exit without "
                         "executing anything")
    ap.add_argument("--gate", action="store_true",
                    help="enforce regression gates: exit 3 when any gate "
                         "component reports a regression, and write the gate "
                         "report (JSON + markdown twin)")
    ap.add_argument("--gate-report", default="gate_report.json",
                    help="gate report path used with --gate; a .md summary "
                         "suitable for a PR comment lands next to it")
    args = ap.parse_args(argv)
    import sys

    try:
        text = Path(args.pipeline).read_text()
    except OSError as e:
        print(f"{args.pipeline}: {e}", file=sys.stderr)
        return 1
    if args.validate:
        try:
            summary = validate_pipeline(text)
        except PipelineError as e:
            print(f"{args.pipeline}: INVALID: {e}", file=sys.stderr)
            return 1
        print(json.dumps(summary, indent=2, default=str))
        print(f"{args.pipeline}: OK ({len(summary)} components)",
              file=sys.stderr)
        return 0
    calls = parse_pipeline_text(text)
    results = run_pipeline(
        calls,
        store=ResultStore(args.store, backend=args.store_backend),
        parallelism=args.parallelism,
        workers=args.workers,
        worker_mode=args.worker_mode,
    )
    print(json.dumps(results, indent=2, default=str))
    component_error = any(r.get("error") for r in results)
    if not args.gate:
        return 0 if not component_error else 1

    from repro.core import regression

    summaries = [r for r in results
                 if r.get("component") == "gate" and "status" in r]
    status = regression.worst(s["status"] for s in summaries)
    # Infrastructure failure trumps the gate verdict: a crashed component
    # means the store may be missing results a gate needed to judge.
    exit_code = 1 if component_error else (
        EXIT_REGRESSION if status == regression.FAIL else 0)
    md = regression.gate_markdown(summaries)
    report = {
        "status": status,
        "exit_code": exit_code,
        "pipeline": str(args.pipeline),
        "store": str(args.store),
        "gates": [g for s in summaries for g in s["gates"]],
        "markdown": md,
    }
    path = Path(args.gate_report)
    path.write_text(
        json.dumps(regression.json_safe(report), indent=2, default=str) + "\n")
    path.with_suffix(".md").write_text(md + "\n")
    print(md)
    return exit_code


if __name__ == "__main__":
    import sys

    sys.exit(main())
