"""Environment fingerprints: who actually ran this measurement, and how.

CI runners are noisy neighbors — CPU frequency scaling alone is documented
to cause ~49% variance on the workloads this repo gates — so every report
records the environment it was produced under.  A fingerprint has two
kinds of fields:

* **Key fields** (:data:`KEY_FIELDS`) describe the *environment class*:
  hostname, machine, CPU count, frequency governor, cgroup CPU quota, and
  key library versions.  They are stable across the invocations of one
  campaign on one runner, and two measurements are only directly
  comparable when their key fields agree.  :func:`key` canonicalizes them
  into a single string that the columnar plane dictionary-encodes as a
  dimension, and :func:`drift` names the fields on which two fingerprints
  disagree.
* **Volatile observations** — current frequency, load average, thermal
  reading — change between invocations by nature.  They are recorded for
  forensics (why was this run slow?) but never participate in the key, so
  they can never flag drift.

Every probe degrades gracefully: a missing or unreadable ``/sys`` or
``/proc`` entry yields ``None`` for that field, never an exception, so
capture works identically in containers, on macOS, and under restricted
CI sandboxes.  The sysfs/procfs roots are parameters so tests can point
capture at a fabricated tree.
"""

from __future__ import annotations

import functools
import json
import os
import platform
import socket
import sys
from typing import Any, Dict, List, Optional, Union

from repro.core.protocol import Report

#: Fields that define the environment *class* — :func:`key` and
#: :func:`drift` look only at these.  Everything else captured is a
#: volatile observation.
KEY_FIELDS = (
    "hostname", "machine", "cpu_count", "governor", "cgroup_cpu_max",
    "python", "numpy", "jax",
)

#: Parameter slot the full structured fingerprint is stored under.
PARAMETER = "env_fingerprint"

#: Parameter slot listing the drifted key fields when a run's environment
#: no longer matches the campaign reference.
DRIFT_PARAMETER = "fingerprint_drift"

#: Libraries whose versions participate in the key (a silently upgraded
#: numpy is a different measurement environment).
_KEY_LIBRARIES = ("numpy", "jax")


def _read_text(path: str) -> Optional[str]:
    try:
        with open(path, "r", encoding="utf-8", errors="replace") as fh:
            return fh.read().strip()
    except OSError:
        return None


def _read_int(path: str) -> Optional[int]:
    raw = _read_text(path)
    if raw is None:
        return None
    try:
        return int(raw.split()[0])
    except (ValueError, IndexError):
        return None


@functools.lru_cache(maxsize=1)
def _library_versions() -> Dict[str, Optional[str]]:
    # importlib.metadata reads dist-info without importing the library, and
    # the answer cannot change within one interpreter — cache it so capture
    # stays cheap enough to run once per cell invocation.
    try:
        from importlib import metadata
    except ImportError:  # pragma: no cover - py3.7 only
        return {name: None for name in _KEY_LIBRARIES}
    out: Dict[str, Optional[str]] = {}
    for name in _KEY_LIBRARIES:
        try:
            out[name] = metadata.version(name)
        except Exception:
            out[name] = None
    return out


def capture(*, sysfs_root: str = "/sys", proc_root: str = "/proc") -> Dict[str, Any]:
    """Probe the current environment; unreadable probes yield ``None``."""
    fp: Dict[str, Any] = {}
    try:
        fp["hostname"] = socket.gethostname()
    except OSError:
        fp["hostname"] = None
    fp["machine"] = platform.machine() or None
    fp["cpu_count"] = os.cpu_count()
    fp["python"] = platform.python_version()
    fp.update(_library_versions())

    cpufreq = os.path.join(sysfs_root, "devices", "system", "cpu", "cpu0", "cpufreq")
    fp["governor"] = _read_text(os.path.join(cpufreq, "scaling_governor"))
    fp["cpu_freq_khz"] = _read_int(os.path.join(cpufreq, "scaling_cur_freq"))
    fp["cpu_freq_max_khz"] = _read_int(os.path.join(cpufreq, "scaling_max_freq"))

    # cgroup v2 CPU quota ("max 100000" or "200000 100000"); the quota is a
    # key field — a re-limited container is a different machine in effect.
    fp["cgroup_cpu_max"] = _read_text(os.path.join(sysfs_root, "fs", "cgroup", "cpu.max"))

    thermal = _read_int(os.path.join(
        sysfs_root, "class", "thermal", "thermal_zone0", "temp"))
    fp["thermal_c"] = thermal / 1000.0 if thermal is not None else None

    try:
        fp["loadavg_1m"] = round(os.getloadavg()[0], 3)
    except (OSError, AttributeError):
        fp["loadavg_1m"] = None
    # proc_root is accepted for symmetry/testing even though loadavg comes
    # from the libc call; keep a direct probe as fallback when it failed.
    if fp["loadavg_1m"] is None:
        raw = _read_text(os.path.join(proc_root, "loadavg"))
        if raw:
            try:
                fp["loadavg_1m"] = float(raw.split()[0])
            except (ValueError, IndexError):
                pass
    return fp


def key(fp: Optional[Dict[str, Any]]) -> str:
    """Canonical string over :data:`KEY_FIELDS` — the stratification class.

    Empty string when nothing was captured, so untagged legacy reports
    keep an empty key and never participate in drift decisions.
    """
    if not fp:
        return ""
    fields = {k: fp[k] for k in KEY_FIELDS if fp.get(k) is not None}
    if not fields:
        return ""
    return json.dumps(fields, sort_keys=True, separators=(",", ":"))


def _as_fields(fp: Union[str, Dict[str, Any], None]) -> Dict[str, Any]:
    if not fp:
        return {}
    if isinstance(fp, str):
        try:
            doc = json.loads(fp)
        except ValueError:
            return {"_raw": fp}
        return doc if isinstance(doc, dict) else {"_raw": fp}
    return {k: v for k, v in fp.items() if k in KEY_FIELDS}


def drift(a: Union[str, Dict[str, Any], None],
          b: Union[str, Dict[str, Any], None]) -> List[str]:
    """Key fields on which two fingerprints (dicts or :func:`key` strings)
    disagree.  Empty/absent fingerprints never drift — there is nothing to
    compare against."""
    fa, fb = _as_fields(a), _as_fields(b)
    if not fa or not fb:
        return []
    out = []
    for name in KEY_FIELDS + ("_raw",):
        if fa.get(name) != fb.get(name):
            out.append(name)
    return out


def stamp(report: Report, fp: Dict[str, Any]) -> None:
    """Record a fingerprint on a report: flat strings into the protocol
    envelope (``reporter.environment``) and the structured dict into
    ``parameter["env_fingerprint"]`` for the columnar/gate planes."""
    for k, v in fp.items():
        if v is not None:
            report.reporter.environment[k] = str(v)
    report.parameter[PARAMETER] = dict(fp)


def key_of(report: Report) -> str:
    """The fingerprint key a report was stamped with ("" when untagged)."""
    fp = report.parameter.get(PARAMETER)
    return key(fp) if isinstance(fp, dict) else ""
