"""Monitoring-system export (paper §IV-F: "aggregated results can further be
exported to external monitoring and visualization systems, such as Grafana
or LLview").

Exporters over the result store, all served by the incremental columnar
plane (``store.columnar``): one cached column-table fetch per prefix feeds
every exporter, so a combined export (``write_exports``) no longer issues
independent full ``store.query()`` scans per format — warm exports parse no
report at all.

* ``grafana_table`` — Grafana's simple-JSON table datasource format
  (columns + rows) for one metric over one prefix.
* ``llview_jobs``  — LLview-style job-records list (one record per data
  entry with the Table-I fields + metrics), reconstructed from columns.
* ``campaign_table`` — per-prefix summary of one metric across the whole
  campaign (a :class:`repro.core.columnar.CampaignFrame` in one scan).

Plus ``ascii_timeseries``: a dependency-free terminal sparkline/plot used by
the examples and the post-processing reports (the paper's Figs. 3/4 as
text), and ``ascii_timeseries_report`` which renders a stored prefix with
regression flags straight from the columnar series.
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import analysis
from repro.core.columnar import CampaignFrame
from repro.core.store import ResultStore


def _grafana_payload(metric: str,
                     series: Sequence[Tuple[float, float]]) -> Dict[str, Any]:
    return {
        "columns": [
            {"text": "Time", "type": "time"},
            {"text": metric, "type": "number"},
        ],
        "rows": [[int(ts * 1000), v] for ts, v in series],
        "type": "table",
    }


def grafana_table(
    store: ResultStore, prefix: str, metric: str, *, since: Optional[float] = None
) -> Dict[str, Any]:
    return _grafana_payload(
        metric,
        store.columnar.table(prefix).series(metric, since=since).time_points(),
    )


def llview_jobs(store: ResultStore, prefix: str) -> List[Dict[str, Any]]:
    """LLview job records for one prefix.

    The records are memoized on the columnar table (the outer list is fresh
    per call, the record dicts are shared) — treat them as read-only; copy
    before mutating.
    """
    return store.columnar.table(prefix).job_records()


def campaign_table(
    store: ResultStore, metric: str, *, prefixes: Optional[Sequence[str]] = None
) -> Dict[str, Any]:
    """Campaign-wide export: one metric summarized over every prefix (the
    paper's 70-application JUREAP view) in a single columnar scan."""
    frame = CampaignFrame(store, prefixes=prefixes)
    table = frame.summary(metric)
    return {
        "metric": metric,
        "prefixes": sorted(table),
        "table": table,
        "watermarks": frame.watermarks(),
        "generated_at": time.time(),
    }


def write_exports(store: ResultStore, prefix: str, metric: str, outdir) -> Dict[str, str]:
    from pathlib import Path

    d = Path(outdir)
    d.mkdir(parents=True, exist_ok=True)
    # One columnar fetch serves all formats (and its sidecar persists, so
    # the next export process starts warm too).
    table = store.columnar.table(prefix)
    g = d / f"grafana.{prefix}.{metric}.json"
    l = d / f"llview.{prefix}.json"
    a = d / f"ascii.{prefix}.{metric}.txt"
    series = table.series(metric).time_points()
    g.write_text(json.dumps(_grafana_payload(metric, series), indent=2))
    l.write_text(json.dumps(table.job_records(), indent=2, default=str))
    a.write_text(ascii_timeseries(
        series, title=f"{prefix}:{metric}",
        regressions=[r.index for r in analysis.detect_regressions(series)],
    ))
    return {"grafana": str(g), "llview": str(l), "ascii": str(a)}


# ---------------------------------------------------------------------------
# Terminal rendering (Figs. 3/4 as text)
# ---------------------------------------------------------------------------

_BARS = "▁▂▃▄▅▆▇█"


def ascii_timeseries(
    series: Sequence[Tuple[float, float]],
    *,
    title: str = "",
    width: int = 64,
    regressions: Sequence[int] = (),
) -> str:
    if not series:
        return f"{title}: (no data)\n"
    vals = [v for _, v in series][-width:]
    lo, hi = min(vals), max(vals)
    rng = (hi - lo) or 1.0
    marks = set(regressions)
    offset = len(series) - len(vals)
    cells = []
    for i, v in enumerate(vals):
        idx = int((v - lo) / rng * (len(_BARS) - 1))
        ch = _BARS[idx]
        cells.append(f"!{ch}" if (i + offset) in marks else ch)
    lines = []
    if title:
        lines.append(title)
    lines.append("".join(cells))
    lines.append(f"min={lo:.4g} max={hi:.4g} n={len(series)}"
                 + (f" regressions@{sorted(marks)}" if marks else ""))
    return "\n".join(lines) + "\n"


def ascii_timeseries_report(
    store: ResultStore, prefix: str, metric: str, *,
    width: int = 64, detector: Optional[Dict[str, Any]] = None,
) -> str:
    """Render a stored metric straight from the columnar series, regression
    flags included — the one-call terminal twin of the Fig. 3/4 plots."""
    ms = store.columnar.table(prefix).series(metric)
    series = ms.time_points()
    regs = analysis.detect_regressions(series, **(detector or {}))
    return ascii_timeseries(series, title=f"{prefix}:{metric}", width=width,
                            regressions=[r.index for r in regs])
