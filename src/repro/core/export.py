"""Monitoring-system export (paper §IV-F: "aggregated results can further be
exported to external monitoring and visualization systems, such as Grafana
or LLview").

Two exporters over the result store:

* ``grafana_table`` — Grafana's simple-JSON table datasource format
  (columns + rows) for one metric over one prefix.
* ``llview_jobs``  — LLview-style job-records list (one record per data
  entry with the Table-I fields + metrics).

Plus ``ascii_timeseries``: a dependency-free terminal sparkline/plot used by
the examples and the post-processing reports (the paper's Figs. 3/4 as text).
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import analysis
from repro.core.store import ResultStore


def grafana_table(
    store: ResultStore, prefix: str, metric: str, *, since: Optional[float] = None
) -> Dict[str, Any]:
    reports = store.query(prefix, since=since)
    series = analysis.to_series(reports, metric)
    return {
        "columns": [
            {"text": "Time", "type": "time"},
            {"text": metric, "type": "number"},
        ],
        "rows": [[int(ts * 1000), v] for ts, v in series],
        "type": "table",
    }


def llview_jobs(store: ResultStore, prefix: str) -> List[Dict[str, Any]]:
    out = []
    for r in store.query(prefix):
        for d in r.data:
            out.append({
                "jobid": d.job_id,
                "system": r.experiment.system,
                "queue": d.queue,
                "nodes": d.nodes,
                "runtime": d.runtime,
                "state": "COMPLETED" if d.success else "FAILED",
                "ts": r.experiment.timestamp,
                "metrics": dict(d.metrics),
            })
    return out


def write_exports(store: ResultStore, prefix: str, metric: str, outdir) -> Dict[str, str]:
    from pathlib import Path

    d = Path(outdir)
    d.mkdir(parents=True, exist_ok=True)
    g = d / f"grafana.{prefix}.{metric}.json"
    l = d / f"llview.{prefix}.json"
    g.write_text(json.dumps(grafana_table(store, prefix, metric), indent=2))
    l.write_text(json.dumps(llview_jobs(store, prefix), indent=2, default=str))
    return {"grafana": str(g), "llview": str(l)}


# ---------------------------------------------------------------------------
# Terminal rendering (Figs. 3/4 as text)
# ---------------------------------------------------------------------------

_BARS = "▁▂▃▄▅▆▇█"


def ascii_timeseries(
    series: Sequence[Tuple[float, float]],
    *,
    title: str = "",
    width: int = 64,
    regressions: Sequence[int] = (),
) -> str:
    if not series:
        return f"{title}: (no data)\n"
    vals = [v for _, v in series][-width:]
    lo, hi = min(vals), max(vals)
    rng = (hi - lo) or 1.0
    marks = set(regressions)
    offset = len(series) - len(vals)
    cells = []
    for i, v in enumerate(vals):
        idx = int((v - lo) / rng * (len(_BARS) - 1))
        ch = _BARS[idx]
        cells.append(f"!{ch}" if (i + offset) in marks else ch)
    lines = []
    if title:
        lines.append(title)
    lines.append("".join(cells))
    lines.append(f"min={lo:.4g} max={hi:.4g} n={len(series)}"
                 + (f" regressions@{sorted(marks)}" if marks else ""))
    return "\n".join(lines) + "\n"
