"""Duet-run vocabulary: paired baseline/candidate measurements.

A duet cell runs as interleaved A/B/A/B invocations of the *same* cell on
the *same* worker — role ``baseline`` then role ``candidate``, repeated
for ``rounds`` rounds under one shared ``duet_id``.  Because both roles of
a round execute back-to-back on one machine, multiplicative environmental
noise (frequency scaling, a noisy neighbor, thermal throttling) hits both
sides of the pair almost equally and divides out of the per-round
(candidate − baseline) delta — which is exactly the series the paired
gate judges instead of absolute values.

This module owns only the vocabulary: the parameter tag stamped on each
report, and the :class:`DuetPair` extraction shared by the columnar plane
(:meth:`ColumnTable.duet_pairs`) and the raw-report fallback
(:func:`pairs_from_reports`), so both gate paths see byte-identical
pairs.  It deliberately imports nothing above the protocol layer.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.protocol import Report

ROLE_BASELINE = "baseline"
ROLE_CANDIDATE = "candidate"
ROLES = (ROLE_BASELINE, ROLE_CANDIDATE)

#: Parameter slot the duet tag is stored under on each report.
PARAMETER = "duet"


def tag(duet_id: str, role: str, round_idx: int, rounds: int) -> Dict[str, Any]:
    """The parameter payload stamped on one duet invocation's report."""
    return {"duet_id": str(duet_id), "role": str(role),
            "round": int(round_idx), "rounds": int(rounds)}


def context_of(report: Report) -> Optional[Dict[str, Any]]:
    """The duet tag of a report, or ``None`` for non-duet reports."""
    ctx = report.parameter.get(PARAMETER)
    if isinstance(ctx, dict) and ctx.get("duet_id"):
        return ctx
    return None


@dataclass(frozen=True)
class DuetPair:
    """One completed round: both roles measured, keyed by the candidate's
    store sequence so pairs order consistently with absolute series."""

    duet_id: str
    round: int
    baseline: float
    candidate: float
    seq: int            # candidate invocation's store sequence
    baseline_seq: int
    timestamp: float    # candidate invocation's timestamp

    def to_dict(self) -> Dict[str, Any]:
        return {"duet_id": self.duet_id, "round": self.round,
                "baseline": self.baseline, "candidate": self.candidate,
                "seq": self.seq, "baseline_seq": self.baseline_seq,
                "timestamp": self.timestamp}


#: slot map shape shared with the columnar extractor:
#: {(duet_id, round): {role: (value, seq, timestamp)}}
Slots = Dict[Tuple[str, int], Dict[str, Tuple[float, int, float]]]


def pairs_from_slots(slots: Slots) -> List[DuetPair]:
    """Completed pairs (both roles present) sorted by (candidate seq, round)."""
    out: List[DuetPair] = []
    for (duet_id, round_idx), roles in slots.items():
        if ROLE_BASELINE not in roles or ROLE_CANDIDATE not in roles:
            continue  # orphaned half-round: never judged
        bval, bseq, _ = roles[ROLE_BASELINE]
        cval, cseq, cts = roles[ROLE_CANDIDATE]
        out.append(DuetPair(duet_id=duet_id, round=round_idx,
                            baseline=bval, candidate=cval,
                            seq=cseq, baseline_seq=bseq, timestamp=cts))
    out.sort(key=lambda p: (p.seq, p.round))
    return out


def pairs_from_reports(pairs: Iterable[Tuple[Any, Report]],
                       metric: str) -> List[DuetPair]:
    """Extract duet pairs from ``(index entry, report)`` pairs — the
    non-columnar twin of :meth:`ColumnTable.duet_pairs`.

    Matches the columnar semantics exactly: successful entries only,
    ``runtime`` falls back to the entry runtime when absent from metrics,
    and the *lowest-seq* value per (duet_id, round, role) wins — input is
    seq-ordered, so duplicate slots (a fencing gap letting a paused worker
    append after the retry) are ignored rather than silently replacing the
    canonical measurement.
    """
    slots: Slots = {}
    for entry, report in pairs:
        ctx = context_of(report)
        if ctx is None:
            continue
        value: Optional[float] = None
        for d in report.data:
            if not d.success:
                continue
            if metric in d.metrics:
                try:
                    value = float(d.metrics[metric])
                except (TypeError, ValueError):
                    continue
            elif metric == "runtime":
                value = float(d.runtime)
        if value is None:
            continue
        slot = slots.setdefault(
            (str(ctx["duet_id"]), int(ctx.get("round", -1))), {})
        slot.setdefault(str(ctx.get("role", "")), (
            value, int(entry.seq), float(report.experiment.timestamp)))
    return pairs_from_slots(slots)
