"""Distributed execution plane: CampaignBroker + process worker pool.

The thread scheduler (``repro.core.scheduler``) keeps every cell in one
interpreter — CPU-bound harness work serializes on the GIL, and one crashed
interpreter loses the whole campaign.  This module is the alternative
dispatch path the paper's JUREAP deployment model needs:

* :class:`CampaignBroker` materializes a campaign's cells into a
  lease-reclaimed :class:`~repro.core.workqueue.WorkQueue` persisted under
  the store root, spawns N worker *processes*, and monitors them —
  reclaiming expired leases and respawning dead workers (bounded).
* :func:`worker_main` is the spawn entrypoint.  A worker is configured by
  plain data only (store root + backend name, harness ``module:factory``
  recipe, lease timings): no closure, harness object, or lock crosses the
  process boundary.  It re-applies the campaign's ambient env-injection
  frame inside its own interpreter (``injected_env`` state is per-process —
  see the spawn caveat in ``repro.core.harness``), then drains the queue:
  claim → execute via a fresh ``ExecutionOrchestrator`` (process-scope
  resource accounting) → persist → write the done marker.
* **Exactly-once effect**: a worker SIGKILLed between its store append and
  its done marker would make the reclaimed retry re-execute the cell.
  Every persisted report is tagged with the cell's ``task_uid``, and a
  retry first checks the store for that tag — it adopts the orphaned
  result instead of appending a duplicate.

Because the queue and the results both live in the store's filesystem, the
same protocol extends to N *hosts* draining one campaign over shared
storage — nothing here assumes the workers share a parent process.  The
multi-host entry point is ``python -m repro.core.workers <queue-root>``: a
remote host sharing the filesystem reads the broker-published
``worker_config.json`` and joins the drain with a ``host:pid:label``
identity that flows into lease files, done markers, and report provenance
(see ``docs/failure_model.md`` for the liveness assumptions).
"""

from __future__ import annotations

import dataclasses
import importlib
import json
import multiprocessing as mp
import os
import socket
import threading
import time
import traceback
import uuid
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import chaos
from repro.core import duet as duet_mod
from repro.core import fingerprint as fingerprint_mod
from repro.core.component import PipelineError
from repro.core.harness import BenchmarkSpec, Harness, HarnessCapabilities, Injections, injected_env
from repro.core.protocol import Report
from repro.core.readiness import Readiness
from repro.core.retry import RetryPolicy, call_with_retry
from repro.core.store import ResultStore
from repro.core.workqueue import (
    DEFAULT_LEASE_TIMEOUT, DEFAULT_MAX_ATTEMPTS, WorkQueue, _atomic_json)

QUEUE_DIRNAME = "_queue"   # under the store root; skipped by prefix scans
WORKER_CONFIG = "worker_config.json"  # broker-published, read by remote hosts

#: Host identity override for workers — lets tests (and containerized
#: deployments whose hostname is meaningless) simulate distinct hosts.
HOST_ENV = "EXACB_HOST"


def host_identity() -> str:
    """This process's host identity: ``$EXACB_HOST`` or the hostname."""
    return os.environ.get(HOST_ENV, "").strip() or socket.gethostname()


def worker_identity(label: str = "") -> str:
    """Compose the full ``host:pid:label`` worker id for this process."""
    return f"{host_identity()}:{os.getpid()}:{label or uuid.uuid4().hex[:8]}"


def host_of(worker_id: str) -> str:
    """The host component of a ``host:pid:label`` worker id ('' if none)."""
    return worker_id.split(":", 1)[0] if ":" in worker_id else ""


# ---------------------------------------------------------------------------
# Spawn-safe configuration
# ---------------------------------------------------------------------------

def spawn_spec_for(harness: Harness) -> Tuple[str, Dict[str, Any]]:
    """The harness's ``("module:factory", kwargs)`` recipe, as a hard error
    (not a mystery pickle failure) when the adapter doesn't provide one."""
    try:
        ref, kwargs = harness.spawn_spec()
    except NotImplementedError as e:
        raise PipelineError(str(e)) from e
    return str(ref), dict(kwargs)


def resolve_harness(ref: str, kwargs: Dict[str, Any]) -> Harness:
    """Rebuild a harness from its spawn recipe inside a worker."""
    module, sep, attr = ref.partition(":")
    if not sep or not attr:
        raise PipelineError(f"bad harness ref {ref!r} (want 'module:factory')")
    factory = getattr(importlib.import_module(module), attr)
    return factory(**kwargs)


@dataclasses.dataclass
class WorkerConfig:
    """Everything a spawned worker needs, as plain data."""

    store_root: str
    store_backend: str = "dir"
    harness_ref: str = ""
    harness_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: Ambient env-injection frame re-applied inside the worker interpreter
    #: (spawn does not inherit the parent's active ``injected_env`` frames).
    env: Dict[str, str] = dataclasses.field(default_factory=dict)
    lease_timeout: float = DEFAULT_LEASE_TIMEOUT
    heartbeat_interval: float = 0.0  # 0 = lease_timeout / 4
    max_attempts: int = DEFAULT_MAX_ATTEMPTS
    poll_s: float = 0.1
    #: Give up after this long with no claimable work and an unfinished
    #: queue (an orphaned worker must not outlive its campaign forever).
    idle_timeout: float = 120.0
    #: The broker's environment fingerprint at campaign start.  Workers
    #: measure against this shared reference so a drifted worker host
    #: (governor flip, different library set) marks its reports untrusted
    #: instead of silently mixing environments into one campaign.
    reference_fingerprint: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(doc: Dict[str, Any]) -> "WorkerConfig":
        return WorkerConfig(**doc)

    def heartbeat_s(self) -> float:
        return self.heartbeat_interval or max(0.05, self.lease_timeout / 4.0)


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------

#: Heartbeat I/O retry: total backoff must stay well under the lease
#: timeout, or the retries themselves would let the lease expire.
_HEARTBEAT_POLICY = RetryPolicy(tries=4, base_s=0.02, factor=2.0, max_s=0.25)


class _Heartbeat(threading.Thread):
    """Refreshes one cell's lease while the harness runs, so a *live* worker
    on a slow cell is never mistaken for a dead one.

    A heartbeat that *errors* used to kill this thread silently: the lease
    then aged out mid-run and a peer reclaimed the cell while this worker
    kept executing — the exact slow-but-alive race fencing exists for, now
    entered through an I/O blip instead of a pause.  Transient failures are
    retried with backoff; persistent failure (or a vanished lease) sets
    ``lost``, which the worker's fence checks before every store append —
    the cell is fenced promptly instead of racing the reclaimer.
    """

    def __init__(self, queue: WorkQueue, idx: int, interval: float):
        super().__init__(daemon=True, name=f"heartbeat-{idx:05d}")
        self.queue = queue
        self.idx = idx
        self.interval = interval
        # NB: not `_stop` — that would shadow threading.Thread's internal
        # `_stop()` method and break `join()`.
        self._halt = threading.Event()
        #: Set when the lease is gone or unheartbeatable — ownership can no
        #: longer be asserted, so the owner must consider itself fenced.
        self.lost = threading.Event()

    def run(self) -> None:
        while not self._halt.wait(self.interval):
            try:
                alive = call_with_retry(
                    lambda: self.queue.heartbeat(self.idx),
                    label="queue.heartbeat", policy=_HEARTBEAT_POLICY)
            except Exception:  # noqa: BLE001 — persistent failure fences
                self.lost.set()
                return
            if not alive:
                self.lost.set()
                return

    def stop(self) -> None:
        self._halt.set()


class _TaggingHarness(Harness):
    """Wraps the real harness to stamp execution-plane provenance
    (``task_uid``, worker id, attempt) into each report *before* the
    orchestrator persists it — the dedup key for crash recovery."""

    def __init__(self, inner: Harness, tags: Dict[str, Any]):
        self.inner = inner
        self.name = inner.name
        self.tags = tags

    def capabilities(self) -> HarnessCapabilities:
        return self.inner.capabilities()

    def run(self, spec, injections=None):
        report = self.inner.run(spec, injections)
        report.parameter.update(self.tags)
        return report


def _injections_from_payload(doc: Optional[Dict[str, Any]]) -> Optional[Injections]:
    if not doc:
        return None
    return Injections(env=dict(doc.get("env", {})),
                      overrides=dict(doc.get("overrides", {})))


class LeaseLostError(RuntimeError):
    """The worker's lease was reclaimed while it was still executing."""


class _FencedStore:
    """Store proxy that re-verifies lease ownership immediately before every
    append — the fencing-token check that closes the slow-but-alive window.
    A worker paused mid-cell (SIGSTOP, NFS stall, GC-like hiccup) and resumed
    *after* the reclaimed retry's adoption check would otherwise append a
    second report for the same ``task_uid``; with the fence it fails here and
    the report is dropped instead."""

    def __init__(self, inner: ResultStore, fence):
        self._inner = inner
        self._fence = fence
        #: Set when an append failed *as I/O* even after the store's own
        #: bounded retries — the signal for the worker to fence itself
        #: (release the lease, skip the done marker) rather than terminally
        #: fail the cell on a sick storage path.
        self.append_failed = False

    def append(self, prefix, report, **kwargs):
        if not self._fence():
            raise LeaseLostError(
                f"lease lost before store append to {prefix!r}; dropping "
                "report — the reclaimed retry owns this cell now")
        try:
            return self._inner.append(prefix, report, **kwargs)
        except OSError:
            self.append_failed = True
            raise

    def __getattr__(self, name):
        return getattr(self._inner, name)


def _find_adopted(store: ResultStore, prefix: str, task_uid: str) -> Optional[Report]:
    """A report persisted by a previous (killed) attempt of this cell.

    ``store.query`` yields in seq order, so the first match is the
    lowest-seq report — the canonical one if a fencing gap ever let a
    duplicate ``task_uid`` entry slip in (defense-in-depth: every reader
    converges on the same record)."""
    for report in store.query(prefix):
        if report.parameter.get("task_uid") == task_uid:
            return report
    return None


def _duet_adopted(
    store: ResultStore, prefix: str, task_uid: str,
) -> Tuple[Optional[str], Dict[Tuple[int, str], Report]]:
    """Per-slot adoption for duet cells.  A worker killed mid-duet may have
    persisted only some ``(round, role)`` invocations; the retry must resume
    the *same* duet (reusing its ``duet_id``) and execute only the missing
    slots — never re-measuring a persisted one, or the pair extraction would
    see duplicate slots and exactly-once would be lost."""
    duet_id: Optional[str] = None
    slots: Dict[Tuple[int, str], Report] = {}
    for report in store.query(prefix):
        if report.parameter.get("task_uid") != task_uid:
            continue
        ctx = duet_mod.context_of(report)
        if ctx is None:
            continue
        if duet_id is None:
            duet_id = str(ctx["duet_id"])
        # Lowest store seq wins per (round, role) slot: query is seq-ordered,
        # so keep the first report seen — duplicates from a fencing gap are
        # ignored, matching duet.pairs_from_reports / columnar.duet_pairs.
        slots.setdefault(
            (int(ctx.get("round", -1)), str(ctx.get("role", ""))), report)
    return duet_id, slots


def _execute_payload(
    payload: Dict[str, Any],
    *,
    store: ResultStore,
    harness: Harness,
    worker_id: str,
    attempt: int,
    reference_fingerprint: Optional[Dict[str, Any]] = None,
    fence=None,
    resource_scope: str = "process",
) -> Dict[str, Any]:
    """Run one queue cell to a terminal result dict (the done-marker body).
    Never raises: execution errors are results, like everywhere else.

    ``fence`` is a zero-arg callable returning whether the caller still owns
    the cell's lease.  When provided, every store append is fenced (see
    :class:`_FencedStore`) and the returned dict carries ``fenced: True``
    whenever ownership was lost — the caller must then *not* write the done
    marker: the reclaimed retry owns the cell, and our (possibly stale or
    FAILED) marker could win the first-writer race against its good result.
    """
    from repro.core.orchestrator import (  # lazy: cycle
        CellResult, ExecutionOrchestrator, reduce_duet)

    fenced_store: Optional[_FencedStore] = None
    if fence is not None:
        fenced_store = _FencedStore(store, fence)
        store = fenced_store
    task_uid = str(payload.get("task_uid", ""))
    base = {
        "task_uid": task_uid,
        "component_ref": payload.get("component_ref", "execution@v4"),
        "call_index": payload.get("call_index", 0),
        "cell_index": payload.get("cell_index", 0),
        "worker": worker_id,
        "host": host_of(worker_id),
        "attempts": attempt,
    }
    def _run() -> Dict[str, Any]:
        spec = BenchmarkSpec(**payload["spec"])
        prefix = payload.get("prefix", "default")
        record = bool(payload.get("record", True))
        raw_inputs = dict(payload.get("inputs", {}))
        duet = bool(raw_inputs.get("duet"))
        if attempt > 1 and record and not duet:
            adopted = _find_adopted(store, prefix, task_uid)
            if adopted is not None:
                # A prior attempt died AFTER persisting: adopt its report
                # instead of re-executing — no duplicate store append.
                return base | {
                    "cell": spec.cell,
                    "readiness": int(adopted.parameter.get("readiness", 0)),
                    "error": None,
                    "report": adopted.to_dict(),
                    "adopted": True,
                }
        # A payload may declare its own harness (`harness:` + `harness.*`
        # inputs travel with it) — the document's choice beats the worker's
        # campaign-level default, same precedence as thread mode.
        from repro import harnesses as harness_families

        declared = harness_families.from_inputs(raw_inputs)
        cell_harness = declared if declared is not None else harness
        tagged = _TaggingHarness(cell_harness, {
            "task_uid": task_uid, "worker": worker_id,
            "host": host_of(worker_id), "attempt": attempt})
        # Payloads may originate from a component with a wider schema
        # (feature-injection sweep points); the worker always executes
        # through the execution orchestrator, so keep only its inputs —
        # plus dotted keys in its open namespaces (harness.* kwargs).
        schema = ExecutionOrchestrator.schema
        allowed = {s.name for s in schema.inputs}
        inputs = {k: v for k, v in raw_inputs.items()
                  if k in allowed
                  or ("." in k and k.split(".", 1)[0] in schema.open_namespaces)}
        ex = ExecutionOrchestrator(
            inputs=inputs,
            harness=tagged,
            store=store,
            resource_scope=resource_scope,
            worker_id=worker_id,
            reference_fingerprint=reference_fingerprint,
        )
        inj = _injections_from_payload(payload.get("injections"))
        if duet:
            # The whole duet is ONE queue task, so every interleaved
            # invocation of the pair runs on this worker — the pinning the
            # paired gate's noise-cancellation argument depends on.
            adopted_id: Optional[str] = None
            slots: Dict[Tuple[int, str], Report] = {}
            if attempt > 1 and record:
                adopted_id, slots = _duet_adopted(store, prefix, task_uid)
            invocations = ex.run_duet(
                spec, inj, duet_id=adopted_id, skip=set(slots))
            results = [
                CellResult(spec, rep,
                           Readiness(int(rep.parameter.get("readiness", 0))))
                for rep in slots.values()
            ] + invocations
            res = reduce_duet(spec, results)
            return base | {
                "cell": spec.cell,
                "readiness": int(res.readiness),
                "error": res.error,
                "report": res.report.to_dict() if res.report is not None else None,
                "duet": {
                    "rounds": int(raw_inputs.get("duet_rounds", 4)),
                    "invocations": len(results),
                    "adopted": len(slots),
                },
            }
        res = ex.run_cell(spec, inj)
        return base | {
            "cell": spec.cell,
            "readiness": int(res.readiness),
            "error": res.error,
            "report": res.report.to_dict() if res.report is not None else None,
        }

    try:
        out = _run()
    except LeaseLostError as e:
        # A fenced append outside the orchestrator's own retry loop: the
        # report was dropped, nothing reached the store from this attempt.
        out = base | {
            "cell": payload.get("spec", {}).get("arch", "?"),
            "readiness": 0,
            "error": str(e),
            "report": None,
        }
    except Exception as e:  # noqa: BLE001 — a worker must never die on one cell
        out = base | {
            "cell": payload.get("spec", {}).get("arch", "?"),
            "readiness": 0,
            "error": f"{type(e).__name__}: {e}\n{traceback.format_exc(limit=3)}",
            "report": None,
        }
    if fence is not None and not fence():
        # Post-execution ownership check.  A LeaseLostError raised inside
        # run_cell is swallowed by its per-cell retry (it surfaces as a
        # FAILED result) — without this check the worker would go on to
        # write that FAILED marker and could beat the retry's good one.
        out = dict(out)
        out["fenced"] = True
    if fenced_store is not None and fenced_store.append_failed:
        # The store path is sick (append failed even after bounded retries):
        # this is the worker's problem, not the cell's — the caller must
        # self-fence (release the lease for a retry elsewhere) instead of
        # recording a terminal FAILED marker.
        out = dict(out)
        out["store_failed"] = True
    return out


def _release_quietly(queue: WorkQueue, idx: int, worker_id: str, attempt: int,
                     max_attempts: int) -> None:
    """Best-effort charged release: when even the release path errors the
    lease simply ages out and the reclaimer charges the attempt instead."""
    try:
        queue.release(idx, worker_id, attempt, charge=True,
                      max_attempts=max_attempts)
    except OSError:
        pass


def worker_main(worker_id: str, queue_root: str, config: Dict[str, Any]) -> None:
    """Spawn entrypoint: drain the queue until the campaign finishes.

    Runs in a fresh interpreter — everything it needs arrives as plain data
    in ``config`` (see :class:`WorkerConfig`).  A bare ``worker_id`` (no
    ``:``) is treated as a *label* and expanded to the full
    ``host:pid:label`` identity, so every lease, done marker, and report
    carries the provenance needed to attribute work across hosts.
    """
    if ":" not in worker_id:
        worker_id = worker_identity(worker_id)
    host = host_of(worker_id)
    cfg = WorkerConfig.from_dict(config)
    queue = WorkQueue(queue_root, lease_timeout=cfg.lease_timeout)
    store = ResultStore(cfg.store_root, backend=cfg.store_backend)
    harness = resolve_harness(cfg.harness_ref, cfg.harness_kwargs)
    queue.register_worker(worker_id, host=host, pid=os.getpid())
    idle_since = time.monotonic()
    last_done = queue.done_count()
    # Ambient injection frames do NOT survive spawn — re-enter them here so
    # every cell this worker runs sees the campaign's environment.
    with injected_env(cfg.env):
        while True:
            queue.touch_worker(worker_id)
            try:
                claim = call_with_retry(
                    lambda: queue.claim_next(worker_id, host=host),
                    label="queue.claim")
            except OSError:
                # Queue root unreadable even after bounded retries: this
                # worker's filesystem view is sick — exit instead of
                # spinning (the broker's respawn budget covers a fresh
                # process; other hosts keep draining).
                return
            if claim is None:
                if queue.finished() or queue.stop_requested():
                    return
                try:
                    queue.reclaim_expired(max_attempts=cfg.max_attempts)
                except OSError:
                    pass  # reclaim is cooperative; another pass will win
                # Campaign progress = liveness: while *other* workers are
                # finishing cells, this one must keep polling even with
                # nothing claimable — the remaining long-running cells may
                # yet be reclaimed onto it.  Only bail when both claims AND
                # progress have stalled for idle_timeout.
                done = queue.done_count()
                if done != last_done:
                    last_done = done
                    idle_since = time.monotonic()
                if time.monotonic() - idle_since > cfg.idle_timeout:
                    return
                time.sleep(cfg.poll_s)
                continue
            idle_since = time.monotonic()
            idx, payload, attempt = claim
            chaos.trip("worker.claimed")
            beat = _Heartbeat(queue, idx, cfg.heartbeat_s())
            beat.start()
            try:
                result = _execute_payload(
                    payload, store=store, harness=harness,
                    worker_id=worker_id, attempt=attempt,
                    reference_fingerprint=cfg.reference_fingerprint or None,
                    # The fence folds in heartbeat health: a lease this
                    # worker can no longer refresh (or that vanished) must
                    # fence appends promptly, not only after a reclaimer
                    # happens to race us.
                    fence=lambda i=idx, a=attempt: (
                        not beat.lost.is_set()
                        and queue.owns(i, worker_id, a)))
            finally:
                beat.stop()
            if result.get("store_failed"):
                # Self-fence: the report could not be persisted even with
                # retries.  Hand the cell back charged (bounded attempts)
                # and exit — this worker's store path cannot be trusted.
                _release_quietly(queue, idx, worker_id, attempt,
                                 cfg.max_attempts)
                return
            if beat.lost.is_set():
                # Heartbeat died while executing: release promptly (charged)
                # instead of leaving the lease to age out under a reclaimer.
                _release_quietly(queue, idx, worker_id, attempt,
                                 cfg.max_attempts)
                continue
            if result.get("fenced") or not queue.owns(idx, worker_id, attempt):
                # Lease reclaimed while executing: the retry owns this cell.
                # Our marker (possibly stale or FAILED) must not contest the
                # first-writer race against the retry's result.
                continue
            chaos.trip("worker.pre_complete")
            try:
                queue.complete(idx, result)
            except OSError:
                # The report (if any) is already persisted under its
                # task_uid; releasing charged lets the retry adopt it.
                _release_quietly(queue, idx, worker_id, attempt,
                                 cfg.max_attempts)


# ---------------------------------------------------------------------------
# Broker side
# ---------------------------------------------------------------------------

class CampaignBroker:
    """Materializes cells into a work queue and supervises the worker pool.

    The broker never executes cells itself: its monitor loop only watches
    completion, reclaims expired leases, and respawns dead workers (bounded
    by ``workers * max_attempts`` — a systematically crashing campaign must
    terminate, not flap forever).
    """

    def __init__(
        self,
        store: ResultStore,
        *,
        workers: int = 4,
        name: str = "campaign",
        lease_timeout: float = DEFAULT_LEASE_TIMEOUT,
        heartbeat_interval: float = 0.0,
        max_attempts: int = DEFAULT_MAX_ATTEMPTS,
        poll_s: float = 0.1,
        queue_root: Optional[Path] = None,
        env: Optional[Dict[str, str]] = None,
        deadline_s: Optional[float] = None,
        keep_queue: bool = False,
    ):
        self.store = store
        self.workers = max(1, int(workers))
        self.name = name
        self.lease_timeout = float(lease_timeout)
        self.heartbeat_interval = float(heartbeat_interval)
        self.max_attempts = max(1, int(max_attempts))
        self.poll_s = float(poll_s)
        self.queue_root = Path(queue_root) if queue_root else (
            Path(store.root) / QUEUE_DIRNAME / f"{name}-{uuid.uuid4().hex[:8]}")
        self.env = dict(env or {})
        self.deadline_s = deadline_s
        self.keep_queue = keep_queue
        self.queue: Optional[WorkQueue] = None
        self.processes: List[Optional[mp.process.BaseProcess]] = []

    def _config(self, harness: Harness) -> WorkerConfig:
        ref, kwargs = spawn_spec_for(harness)
        backend = getattr(self.store.backend, "name", "dir")
        if backend not in ("dir", "jsonl"):
            raise PipelineError(
                f"store backend {backend!r} is not shareable across worker "
                "processes (need a filesystem-backed backend)")
        return WorkerConfig(
            store_root=str(self.store.root),
            store_backend=backend,
            harness_ref=ref,
            harness_kwargs=kwargs,
            env=self.env,
            lease_timeout=self.lease_timeout,
            heartbeat_interval=self.heartbeat_interval,
            max_attempts=self.max_attempts,
            # One reference for the whole pool: every worker compares its
            # own capture against the broker's, not against itself.
            reference_fingerprint=fingerprint_mod.capture(),
        )

    def materialize(self, payloads: Sequence[Dict[str, Any]]) -> WorkQueue:
        queue = WorkQueue(self.queue_root, lease_timeout=self.lease_timeout)
        queue.create(list(payloads), campaign=self.name)
        self.queue = queue
        return queue

    def publish(self, payloads: Sequence[Dict[str, Any]], *,
                harness: Harness) -> WorkQueue:
        """Materialize the queue AND publish ``worker_config.json`` into it,
        so workers launched out-of-band — ``python -m repro.core.workers``
        on any host sharing the filesystem — can join the drain with the
        same store/harness/lease configuration as the local pool."""
        cfg = self._config(harness).to_dict()   # validate before mutating
        queue = self.materialize(payloads)
        _atomic_json(self.queue_root / WORKER_CONFIG, cfg)
        return queue

    def _synthesized(self, payloads: Sequence[Dict[str, Any]],
                     error: str) -> Dict[int, Dict[str, Any]]:
        return {
            idx: {
                "task_uid": payloads[idx].get("task_uid", ""),
                "readiness": 0,
                "error": error,
                "attempts": 0,
                "report": None,
            }
            for idx in range(len(payloads))
        }

    def run(self, payloads: Sequence[Dict[str, Any]], *, harness: Harness) -> Dict[int, Dict[str, Any]]:
        """Drain ``payloads`` through the worker pool; returns the terminal
        result dict for every cell index (synthesized failure records for
        cells that never completed — the caller always gets len(payloads)
        answers).

        Degraded mode: an unusable queue root (unreadable, out of space)
        yields synthesized failure records for every cell instead of an
        exception — a broker embedded in the daemon must report a sick
        filesystem, not crash the service.
        """
        payloads = list(payloads)
        cfg = self._config(harness).to_dict()
        try:
            queue = self.materialize(payloads)
            _atomic_json(self.queue_root / WORKER_CONFIG, cfg)
        except OSError as e:
            return self._synthesized(
                payloads, f"queue root unusable at {self.queue_root}: {e}")
        ctx = mp.get_context("spawn")  # spawn-safe by construction
        spawned = 0

        def _spawn() -> mp.process.BaseProcess:
            nonlocal spawned
            spawned += 1
            p = ctx.Process(
                target=worker_main,
                args=(f"{self.name}-w{spawned}", str(self.queue_root), cfg),
                daemon=True,
                name=f"exacb-worker-{spawned}",
            )
            p.start()
            return p

        self.processes = [_spawn() for _ in range(min(self.workers, len(payloads)))]
        respawn_budget = self.workers * self.max_attempts
        t0 = time.monotonic()
        try:
            while not queue.finished():
                try:
                    queue.reclaim_expired(max_attempts=self.max_attempts)
                except OSError:
                    pass  # cooperative: workers also reclaim; retry next tick
                if queue.finished():
                    break
                for i, proc in enumerate(self.processes):
                    if proc is not None and proc.exitcode is not None:
                        proc.join()
                        if spawned < respawn_budget:
                            self.processes[i] = _spawn()
                        else:
                            self.processes[i] = None
                if all(p is None for p in self.processes):
                    break  # respawn budget exhausted with work outstanding
                if self.deadline_s is not None and time.monotonic() - t0 > self.deadline_s:
                    break
                time.sleep(self.poll_s)
        finally:
            try:
                queue.request_stop()
            except OSError:
                pass  # workers still exit via idle timeout
            for proc in self.processes:
                if proc is None:
                    continue
                proc.join(timeout=2 * self.lease_timeout)
                if proc.is_alive():
                    proc.terminate()
                    proc.join(timeout=5)
        results = queue.results()
        for idx in range(len(payloads)):
            results.setdefault(idx, {
                "task_uid": payloads[idx].get("task_uid", ""),
                "readiness": 0,
                "error": "cell never completed (worker pool exhausted or deadline hit)",
                "attempts": 0,
                "report": None,
            })
        if not self.keep_queue:
            import shutil
            shutil.rmtree(self.queue_root, ignore_errors=True)
        return results


# ---------------------------------------------------------------------------
# Payload construction + collection entrypoint
# ---------------------------------------------------------------------------

def cell_payload(
    spec: BenchmarkSpec,
    inputs: Dict[str, Any],
    *,
    component_ref: str = "execution@v4",
    call_index: int = 0,
    cell_index: int = 0,
    injections: Optional[Injections] = None,
) -> Dict[str, Any]:
    """One queue task: pure data, dispatchable by any interpreter."""
    if injections is not None and injections.launcher is not None:
        raise PipelineError(
            "launcher injection (a callable) cannot cross the process "
            "boundary; run launcher-injected cells in thread mode")
    return {
        "component_ref": component_ref,
        "call_index": int(call_index),
        "cell_index": int(cell_index),
        "prefix": inputs.get("prefix", "default"),
        "record": bool(inputs.get("record", True)),
        "inputs": dict(inputs),
        "spec": dataclasses.asdict(spec),
        "injections": (
            {"env": dict(injections.env), "overrides": dict(injections.overrides)}
            if injections is not None else None),
    }


def run_collection_process(
    *,
    inputs: Dict[str, Any],
    harness: Harness,
    store: ResultStore,
    specs: Sequence[BenchmarkSpec],
    injections: Optional[Injections] = None,
    workers: int = 4,
    **broker_kwargs: Any,
):
    """Process-mode twin of ``ExecutionOrchestrator.run_collection``: same
    specs in, same ordered ``CellResult`` list out, but drained by spawned
    workers through the broker."""
    from repro.core.orchestrator import CellResult  # lazy: cycle

    specs = list(specs)
    payloads = [
        cell_payload(spec, dict(inputs), cell_index=i, injections=injections)
        for i, spec in enumerate(specs)
    ]
    name = f"collection-{inputs.get('prefix', 'default')}"
    broker = CampaignBroker(store, workers=workers, name=name, **broker_kwargs)
    results = broker.run(payloads, harness=harness)
    out: List[CellResult] = []
    for i, spec in enumerate(specs):
        out.append(result_to_cell(spec, results.get(i)))
    return out


def result_to_cell(spec: BenchmarkSpec, result: Optional[Dict[str, Any]]):
    """Done-marker dict → CellResult (shared by collection and pipeline
    process paths)."""
    from repro.core.orchestrator import CellResult  # lazy: cycle

    if result is None:
        return CellResult(spec, None, Readiness.FAILED,
                          error="no result recorded for cell")
    report = None
    if result.get("report"):
        try:
            report = Report.from_dict(result["report"])
        except Exception as e:  # noqa: BLE001 — a torn marker is a failure
            return CellResult(spec, None, Readiness.FAILED,
                              error=f"unreadable result marker: {e}")
    return CellResult(
        spec,
        report,
        Readiness(int(result.get("readiness", 0))),
        error=result.get("error"),
        attempts=int(result.get("attempts", 1)),
    )


def pipeline_payloads(calls: Sequence[Any]) -> Tuple[List[Dict[str, Any]], Dict[int, List[int]]]:
    """Materialize every *producer* call of a pipeline into queue payloads.

    Returns ``(payloads, owners)`` where ``owners[call_index]`` lists the
    payload indices belonging to that call — a feature-injection sweep
    contributes one payload per sweep point, so its points drain across the
    whole worker pool instead of serializing inside one call."""
    from repro.core.orchestrator import (  # lazy: cycle
        _injections_from_inputs, spec_from_inputs)

    payloads: List[Dict[str, Any]] = []
    owners: Dict[int, List[int]] = {}
    for ci, call in enumerate(calls):
        if call.name not in ("execution", "feature-injection"):
            continue
        inputs = call.inputs
        spec = spec_from_inputs(inputs)
        points: List[Optional[Injections]]
        if call.name == "execution":
            points = [None]
        else:
            base = _injections_from_inputs(inputs)
            values = inputs.get("values")
            if values:
                if not (inputs.get("env_knob") or inputs.get("override_knob")):
                    raise PipelineError(
                        f"{inputs.component}: 'values' needs an 'env_knob' "
                        "or 'override_knob' to sweep")
                points = []
                for v in values:
                    inj = Injections(env=dict(base.env), overrides=dict(base.overrides))
                    if inputs.get("env_knob"):
                        inj.env[inputs["env_knob"]] = str(v)
                    if inputs.get("override_knob"):
                        inj.overrides[inputs["override_knob"]] = v
                    points.append(inj)
            else:
                points = [base]
        owners[ci] = []
        for k, inj in enumerate(points):
            owners[ci].append(len(payloads))
            payloads.append(cell_payload(
                spec, dict(inputs), component_ref=inputs.component or call.ref,
                call_index=ci, cell_index=k, injections=inj))
    return payloads, owners


# ---------------------------------------------------------------------------
# Multi-host entry point: `python -m repro.core.workers <queue-root>`
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    """Join a campaign drain from any host sharing the store filesystem.

    The broker publishes ``worker_config.json`` into the queue root when it
    materializes a campaign (see :meth:`CampaignBroker.publish`); this entry
    point reads it, composes a ``host:pid:label`` identity (host from
    ``$EXACB_HOST`` or the hostname), and drains until the campaign
    finishes.  Exit code 0 = drained to completion, 2 = queue/config
    unusable.
    """
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.workers",
        description="join a campaign work queue as a remote worker",
    )
    ap.add_argument("queue_root", help="the campaign's queue directory "
                                       "(<store>/_queue/<name>-<id>)")
    ap.add_argument("--harness", default="",
                    help="module:factory harness override (default: the "
                         "recipe published in worker_config.json)")
    ap.add_argument("--label", default="",
                    help="worker label; the full id is host:pid:label "
                         "(default: a random 8-hex label)")
    ap.add_argument("--host", default="",
                    help="host identity override (default: $EXACB_HOST or "
                         "the hostname)")
    args = ap.parse_args(argv)

    queue_root = Path(args.queue_root)
    try:
        config = json.loads((queue_root / WORKER_CONFIG).read_text())
    except (OSError, ValueError) as e:
        print(f"error: no usable {WORKER_CONFIG} under {queue_root}: {e}\n"
              "(the broker publishes it when the campaign is materialized)",
              flush=True)
        return 2
    if args.harness:
        config["harness_ref"] = args.harness
        config["harness_kwargs"] = {}
    if args.host:
        os.environ[HOST_ENV] = args.host
    worker_id = worker_identity(args.label)
    print(f"worker {worker_id} joining queue {queue_root}", flush=True)
    worker_main(worker_id, str(queue_root), config)
    return 0


if __name__ == "__main__":  # pragma: no cover — exercised via subprocess
    raise SystemExit(main())
