"""Deterministic, seeded fault injection over the execution plane.

The exactly-once guarantees of the broker/worker/queue stack (PR 6/8) were
proven on two hand-picked races.  This module turns that into a *searched*
property: every trust boundary in the plane — store appends, queue claims,
heartbeats, reclaim, worker lifecycle — calls a named **injection site**
(:func:`trip` / :func:`torn` / :func:`skew`), and a seeded
:class:`ChaosEngine` decides, reproducibly, which calls fail and how.

Spec format (the ``EXACB_CHAOS`` environment variable, also accepted by
the ``chaos@v1`` component)::

    seed=42;site=store.append:kind=eio:at=2;site=worker.claimed:kind=kill:p=0.2:times=1

Clauses are ``;``-separated.  ``seed=N`` seeds the engine's RNG; every
other clause is a rule of ``:``-separated ``key=value`` pairs:

``site``    fnmatch glob over injection-site names (``queue.*``)
``kind``    ``eio`` | ``enospc`` | ``stall`` | ``kill`` | ``stop`` |
            ``exit`` | ``torn`` | ``skew``
``p``       fire probability per matching call (seeded RNG; default 1.0)
``at``      fire only on the N-th matching call (1-based)
``times``   total fire budget for the rule (default: unbounded)
``dur``     seconds: stall length / SIGSTOP length (default 0.05 / 0.75)
``skew``    seconds of injected clock skew (``skew`` kind)
``frac``    fraction of bytes written before a torn write fails

Determinism contract: with a fixed spec (seed included), the engine's
fire/skip decision for the N-th call at a given site is a pure function of
the spec — the per-rule call counters and the seeded RNG stream are the
only state.  ``engine.log`` records every fired decision so tests can
assert two replays are identical.  The engine installs lazily from the
environment in *every* process, so spawned broker workers inherit the
scenario automatically.

Injection sites live where the faults would really bite (see
``docs/failure_model.md``): ``store.append``, ``queue.claim``,
``queue.complete``, ``queue.heartbeat``, ``queue.reclaim``,
``worker.claimed``, ``worker.pre_complete``.
"""

from __future__ import annotations

import dataclasses
import errno
import fnmatch
import os
import random
import signal
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.component import ComponentSchema, InputSpec, PipelineError

#: Environment variable carrying the active chaos spec.  Set it before
#: spawning workers (the broker uses multiprocessing "spawn", which
#: inherits the environment) and every process replays the same scenario.
ENV_VAR = "EXACB_CHAOS"

FAULT_KINDS = ("eio", "enospc", "stall", "kill", "stop", "exit",
               "torn", "skew")

#: Kinds handled by :func:`trip` (raise / sleep / signal the process).
_TRIP_KINDS = ("eio", "enospc", "stall", "kill", "stop", "exit")


@dataclasses.dataclass(frozen=True)
class ChaosRule:
    """One parsed fault rule."""

    site: str                       # fnmatch glob over injection sites
    kind: str                       # one of FAULT_KINDS
    p: float = 1.0                  # fire probability per matching call
    at: int = 0                     # fire only on the N-th call (0 = any)
    times: int = 0                  # total fire budget (0 = unbounded)
    dur: float = 0.0                # stall / stop duration override
    skew: float = 0.0               # injected clock offset (skew kind)
    frac: float = 0.5               # torn-write fraction (torn kind)

    def render(self) -> str:
        parts = [f"site={self.site}", f"kind={self.kind}"]
        if self.p != 1.0:
            parts.append(f"p={self.p:g}")
        if self.at:
            parts.append(f"at={self.at}")
        if self.times:
            parts.append(f"times={self.times}")
        if self.dur:
            parts.append(f"dur={self.dur:g}")
        if self.skew:
            parts.append(f"skew={self.skew:g}")
        if self.frac != 0.5:
            parts.append(f"frac={self.frac:g}")
        return ":".join(parts)


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """A full scenario: a seed plus an ordered tuple of rules."""

    seed: int = 0
    rules: Tuple[ChaosRule, ...] = ()

    @classmethod
    def parse(cls, text: str) -> "ChaosSpec":
        seed = 0
        rules: List[ChaosRule] = []
        for clause in filter(None, (c.strip() for c in text.split(";"))):
            if clause.startswith("seed="):
                try:
                    seed = int(clause[5:])
                except ValueError:
                    raise PipelineError(f"chaos: bad seed clause {clause!r}")
                continue
            kv: Dict[str, str] = {}
            for pair in clause.split(":"):
                if "=" not in pair:
                    raise PipelineError(
                        f"chaos: bad rule token {pair!r} in {clause!r} "
                        "(want key=value)")
                k, v = pair.split("=", 1)
                kv[k.strip()] = v.strip()
            site = kv.pop("site", "")
            kind = kv.pop("kind", "")
            if not site or kind not in FAULT_KINDS:
                raise PipelineError(
                    f"chaos: rule {clause!r} needs site=<glob> and "
                    f"kind=<{'|'.join(FAULT_KINDS)}>")
            try:
                rule = ChaosRule(
                    site=site, kind=kind,
                    p=float(kv.pop("p", 1.0)),
                    at=int(kv.pop("at", 0)),
                    times=int(kv.pop("times", 0)),
                    dur=float(kv.pop("dur", 0.0)),
                    skew=float(kv.pop("skew", 0.0)),
                    frac=float(kv.pop("frac", 0.5)),
                )
            except ValueError as e:
                raise PipelineError(f"chaos: bad rule {clause!r}: {e}")
            if kv:
                raise PipelineError(
                    f"chaos: unknown key(s) {sorted(kv)} in rule {clause!r}")
            rules.append(rule)
        return cls(seed=seed, rules=tuple(rules))

    def render(self) -> str:
        """Canonical text round-trip (``parse(render()) == self``)."""
        parts = [f"seed={self.seed}"]
        parts += [r.render() for r in self.rules]
        return ";".join(parts)


class ChaosError(OSError):
    """An injected I/O failure.  An OSError subclass carrying a real errno
    so the retry taxonomy (and every existing ``except OSError``) treats it
    exactly like the storage fault it emulates."""

    def __init__(self, code: int, site: str, call: int):
        super().__init__(code, f"chaos[{site}#{call}]: injected "
                               f"{errno.errorcode.get(code, code)}")
        self.site = site
        self.call = call


class ChaosEngine:
    """Seeded decision engine.  One instance per process; all state (per-
    rule call counters, fire counts, the RNG stream) advances only on
    matching calls, so a replay from the same spec is bit-identical."""

    def __init__(self, spec: ChaosSpec):
        self.spec = spec
        self.rng = random.Random(spec.seed)
        self._lock = threading.RLock()
        self._calls = [0] * len(spec.rules)
        self._fired = [0] * len(spec.rules)
        #: Every fired decision, in order: (site, rule_index, call_no, kind).
        self.log: List[Tuple[str, int, int, str]] = []

    # -- decision core ----------------------------------------------------

    def _decide(self, site: str, kinds: Tuple[str, ...]) -> List[Tuple[ChaosRule, int]]:
        """Advance counters for every rule matching ``site``/``kinds`` and
        return the (rule, call_no) pairs that fire on this call."""
        fired: List[Tuple[ChaosRule, int]] = []
        with self._lock:
            for i, rule in enumerate(self.spec.rules):
                if rule.kind not in kinds:
                    continue
                if not fnmatch.fnmatchcase(site, rule.site):
                    continue
                self._calls[i] += 1
                call = self._calls[i]
                if rule.times and self._fired[i] >= rule.times:
                    continue
                if rule.at and call != rule.at:
                    continue
                if rule.p < 1.0 and self.rng.random() >= rule.p:
                    continue
                self._fired[i] += 1
                self.log.append((site, i, call, rule.kind))
                fired.append((rule, call))
        return fired

    # -- actions ----------------------------------------------------------

    def trip(self, site: str) -> None:
        """Raise/stall/signal according to the first firing trip rule."""
        for rule, call in self._decide(site, _TRIP_KINDS):
            if rule.kind == "eio":
                raise ChaosError(errno.EIO, site, call)
            if rule.kind == "enospc":
                raise ChaosError(errno.ENOSPC, site, call)
            if rule.kind == "stall":
                time.sleep(rule.dur or 0.05)
                continue                      # stall then carry on
            if rule.kind == "exit":
                os._exit(70)                  # EX_SOFTWARE: scripted crash
            if rule.kind == "kill":
                os.kill(os.getpid(), signal.SIGKILL)
                time.sleep(5.0)               # pragma: no cover — dying
            if rule.kind == "stop":
                self._sigstop_self(rule.dur or 0.75)

    def torn(self, site: str, size: int) -> Optional[int]:
        """For a write of ``size`` bytes: None (write everything) or the
        number of bytes to write before failing with EIO."""
        for rule, _call in self._decide(site, ("torn",)):
            return max(0, min(size - 1, int(size * rule.frac)))
        return None

    def skew(self, site: str) -> float:
        """Injected clock offset (seconds) to add at ``site``."""
        total = 0.0
        for rule, _call in self._decide(site, ("skew",)):
            total += rule.skew
        return total

    @staticmethod
    def _sigstop_self(dur: float) -> None:
        """SIGSTOP the current process, with a forked resumer that delivers
        SIGCONT after ``dur`` seconds (the stopped process can't resume
        itself).  The child does nothing but sleep/kill/_exit."""
        pid = os.getpid()
        if os.fork() == 0:  # pragma: no cover — trivial resumer child
            time.sleep(dur)
            try:
                os.kill(pid, signal.SIGCONT)
            finally:
                os._exit(0)
        os.kill(pid, signal.SIGSTOP)

    def counters(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "seed": self.spec.seed,
                "rules": [r.render() for r in self.spec.rules],
                "calls": list(self._calls),
                "fired": list(self._fired),
                "log": [list(entry) for entry in self.log],
            }


# ---------------------------------------------------------------------------
# Process-wide engine: installed explicitly (tests, chaos@v1) or lazily from
# EXACB_CHAOS on first use (spawned workers inherit the scenario that way).
# ---------------------------------------------------------------------------

_UNSET = object()
_engine: Any = _UNSET
_engine_lock = threading.Lock()


def current() -> Optional[ChaosEngine]:
    global _engine
    if _engine is _UNSET:
        with _engine_lock:
            if _engine is _UNSET:
                text = os.environ.get(ENV_VAR, "").strip()
                _engine = ChaosEngine(ChaosSpec.parse(text)) if text else None
    return _engine


def install(engine: Optional[ChaosEngine]) -> Optional[ChaosEngine]:
    """Install ``engine`` process-wide (None disables injection)."""
    global _engine
    with _engine_lock:
        _engine = engine
    return engine


def reset() -> None:
    """Forget the installed engine; next use re-reads ``EXACB_CHAOS``."""
    global _engine
    with _engine_lock:
        _engine = _UNSET


def trip(site: str) -> None:
    """Module-level injection hook — no-op unless an engine is active."""
    engine = current()
    if engine is not None:
        engine.trip(site)


def torn(site: str, size: int) -> Optional[int]:
    engine = current()
    return engine.torn(site, size) if engine is not None else None


def skew(site: str) -> float:
    engine = current()
    return engine.skew(site) if engine is not None else 0.0


# ---------------------------------------------------------------------------
# chaos@v1 — the self-registering component: a pipeline document can pin a
# scenario declaratively; the runner installs the engine (and exports the
# spec so broker-spawned workers replay it too).
# ---------------------------------------------------------------------------

CHAOS_SCHEMA = ComponentSchema(
    "chaos", 1,
    (
        InputSpec("spec", str, required=True,
                  help="fault rules, ';'-separated: "
                       "site=<glob>:kind=<eio|enospc|stall|kill|stop|exit|"
                       "torn|skew>[:p=<f>][:at=<n>][:times=<m>][:dur=<s>]"
                       "[:skew=<s>][:frac=<f>]"),
        InputSpec("seed", int, default=0,
                  help="scenario seed; overrides any seed= clause in spec"),
        InputSpec("export", bool, default=True,
                  help="export the scenario via EXACB_CHAOS so spawned "
                       "worker processes inherit it"),
    ),
    description="deterministic seeded fault injection over the execution "
                "plane (see docs/failure_model.md)",
)


def run_chaos_component(inputs: Any, ctx: Any) -> Dict[str, Any]:
    spec = ChaosSpec.parse(inputs["spec"])
    if inputs.get("seed"):
        spec = dataclasses.replace(spec, seed=int(inputs["seed"]))
    engine = ChaosEngine(spec)
    install(engine)
    if inputs.get("export", True):
        os.environ[ENV_VAR] = spec.render()
    return {
        "component": "chaos",
        "seed": spec.seed,
        "rules": [r.render() for r in spec.rules],
        "exported": bool(inputs.get("export", True)),
    }
