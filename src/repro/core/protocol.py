"""The exaCB protocol (paper §V-B): a hierarchical, self-describing report
format that decouples producers (harnesses, orchestrators) from consumers
(analysis, visualization).

Top-level sections — Version / Reporter / Parameter / Experiment / Data —
mirror the paper exactly.  Documents are JSON; the schema is versioned so
older reports remain readable (``migrate``).  Every ``DataEntry`` carries the
paper's required result columns (Table I) plus an extensible ``metrics``
object for benchmark-specific values (roofline terms, energy, MFU, ...).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import time
from typing import Any, Dict, List, Optional, Tuple

PROTOCOL_VERSION = "2"
SUPPORTED_VERSIONS = ("1", "2")


class ProtocolError(ValueError):
    pass


@dataclasses.dataclass
class Reporter:
    """Provenance of the report (paper §V-B b)."""

    tool: str = "exacb-jax"
    tool_version: str = "0.1.0"
    system: str = ""
    user: str = "ci"
    pipeline_id: str = ""
    job_id: str = ""
    commit: str = ""
    software_version: str = ""
    timestamp: float = 0.0
    environment: Dict[str, str] = dataclasses.field(default_factory=dict)
    # External data injected through hooks cannot be fully trusted (§IV-E).
    chain_of_trust: bool = True

    def complete(self) -> bool:
        return bool(self.system and self.pipeline_id and self.timestamp)


@dataclasses.dataclass
class Experiment:
    """Semantic context of the run (paper §V-B d)."""

    system: str = ""
    software_version: str = ""
    variant: str = ""
    usecase: str = ""
    timestamp: float = 0.0


@dataclasses.dataclass
class DataEntry:
    """One benchmark execution (paper §V-B e / Table I)."""

    success: bool = False
    runtime: float = 0.0            # application-reported runtime, seconds
    nodes: int = 1
    tasks_per_node: int = 1
    threads_per_task: int = 1
    job_id: str = ""
    queue: str = ""
    metrics: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def validate(self) -> None:
        if self.runtime < 0:
            raise ProtocolError("runtime must be >= 0")
        if self.nodes < 1 or self.tasks_per_node < 1 or self.threads_per_task < 1:
            raise ProtocolError("node/task/thread counts must be >= 1")


@dataclasses.dataclass
class Report:
    """One protocol document = one benchmark report."""

    version: str = PROTOCOL_VERSION
    reporter: Reporter = dataclasses.field(default_factory=Reporter)
    parameter: Dict[str, Any] = dataclasses.field(default_factory=dict)
    experiment: Experiment = dataclasses.field(default_factory=Experiment)
    data: List[DataEntry] = dataclasses.field(default_factory=list)

    # ---- (de)serialization ----
    def to_dict(self) -> Dict[str, Any]:
        return {
            "version": self.version,
            "reporter": dataclasses.asdict(self.reporter),
            "parameter": dict(self.parameter),
            "experiment": dataclasses.asdict(self.experiment),
            "data": [dataclasses.asdict(d) for d in self.data],
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @staticmethod
    def from_dict(doc: Dict[str, Any]) -> "Report":
        doc = migrate(doc)
        try:
            rep = Reporter(**doc["reporter"])
            exp = Experiment(**doc["experiment"])
            data = [DataEntry(**d) for d in doc["data"]]
        except TypeError as e:
            raise ProtocolError(f"malformed report: {e}") from e
        r = Report(
            version=doc["version"],
            reporter=rep,
            parameter=doc.get("parameter", {}),
            experiment=exp,
            data=data,
        )
        r.validate()
        return r

    @staticmethod
    def from_json(text: str) -> "Report":
        return Report.from_dict(json.loads(text))

    def validate(self) -> None:
        if self.version not in SUPPORTED_VERSIONS:
            raise ProtocolError(f"unsupported protocol version {self.version!r}")
        for d in self.data:
            d.validate()

    def digest(self) -> str:
        """Stable content hash (integrity check for the result store)."""
        return hashlib.sha256(self.to_json(indent=None).encode()).hexdigest()[:16]


def migrate(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Schema evolution: upgrade old protocol documents in place (§V-B a)."""
    version = str(doc.get("version", "1"))
    if version not in SUPPORTED_VERSIONS:
        raise ProtocolError(f"unknown protocol version {version!r}")
    if version == "1":
        # v1 had no chain_of_trust flag and stored metrics flat on the entry.
        doc = dict(doc)
        rep = dict(doc.get("reporter", {}))
        rep.setdefault("chain_of_trust", True)
        doc["reporter"] = rep
        new_data = []
        for d in doc.get("data", []):
            d = dict(d)
            if "metrics" not in d:
                known = {f.name for f in dataclasses.fields(DataEntry)}
                d["metrics"] = {k: d.pop(k) for k in list(d) if k not in known}
            new_data.append(d)
        doc["data"] = new_data
        doc["version"] = "2"
    return doc


# ---------------------------------------------------------------------------
# Envelopes — protocol-compliant carriers for derived state (baselines, gate
# verdicts, ...).  Wrapping a payload in a full Report means it persists
# through any ResultStore backend with provenance, digest integrity, and the
# same query/index machinery as benchmark results.
# ---------------------------------------------------------------------------

ENVELOPE_PARAMETER = "envelope"


def wrap_envelope(
    kind: str,
    payload: Dict[str, Any],
    *,
    system: str = "exacb",
    source: str = "",
    variant: Optional[str] = None,
    pipeline_id: str = "",
    commit: str = "",
) -> Report:
    """Wrap a derived artifact in a protocol report.

    ``kind`` names the payload schema (e.g. ``baseline``, ``gate-verdict``);
    ``source`` records the store prefix the artifact was derived from;
    ``variant`` is index-filterable, so callers storing many envelope streams
    under one prefix (one baseline per metric) can query without parsing.
    Top-level finite numeric payload values are mirrored into the data
    entry's ``metrics`` so exporters see envelopes like any other report.
    """
    if not isinstance(payload, dict):
        raise ProtocolError("envelope payload must be a dict")
    rep = new_report(
        system=system,
        variant=variant if variant is not None else f"envelope.{kind}",
        usecase=source,
        pipeline_id=pipeline_id,
        commit=commit,
    )
    rep.parameter[ENVELOPE_PARAMETER] = {"kind": str(kind), "payload": payload}
    rep.data.append(DataEntry(success=True, runtime=0.0, metrics={
        k: float(v) for k, v in payload.items()
        if isinstance(v, (int, float)) and not isinstance(v, bool)
        and math.isfinite(float(v))
    }))
    return rep


def is_envelope(report: Report, kind: Optional[str] = None) -> bool:
    env = report.parameter.get(ENVELOPE_PARAMETER)
    ok = isinstance(env, dict) and "kind" in env
    return bool(ok and (kind is None or str(env["kind"]) == kind))


def unwrap_envelope(report: Report) -> Tuple[str, Dict[str, Any]]:
    """(kind, payload) of an envelope report; raises ``ProtocolError`` on a
    plain benchmark report so consumers cannot silently misread one."""
    env = report.parameter.get(ENVELOPE_PARAMETER)
    if not isinstance(env, dict) or "kind" not in env:
        raise ProtocolError("report is not an envelope")
    payload = env.get("payload", {})
    if not isinstance(payload, dict):
        raise ProtocolError("malformed envelope payload")
    return str(env["kind"]), payload


def new_report(
    *,
    system: str,
    variant: str,
    usecase: str = "",
    pipeline_id: str = "",
    software_version: str = "",
    parameter: Optional[Dict[str, Any]] = None,
    commit: str = "",
) -> Report:
    now = time.time()
    return Report(
        reporter=Reporter(
            system=system,
            pipeline_id=pipeline_id or f"pl-{int(now)}",
            timestamp=now,
            software_version=software_version,
            commit=commit,
        ),
        experiment=Experiment(
            system=system,
            software_version=software_version,
            variant=variant,
            usecase=usecase,
            timestamp=now,
        ),
        parameter=parameter or {},
    )
