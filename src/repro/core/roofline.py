"""Roofline analysis (deliverable g).

Derives the three roofline terms per (arch × shape × mesh) cell from the
compiled dry-run artifact:

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

HLO_FLOPs/bytes/collective_bytes come from the loop-aware HLO cost model
(``repro.distributed.hlo``) — XLA's own cost_analysis undercounts scanned
layers (measured; see DESIGN.md).  MODEL_FLOPS = 6·N·D (train) or 2·N·D
(forward-only), N = non-embedding (active for MoE) params, giving the
"useful ratio" that exposes remat/redundancy waste.

Collective-term convention: wire bytes are per-device ring-model bytes; we
conservatively credit ONE of the chip's ICI links (documented; an axis-aware
multi-link model is a refinement iteration).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.distributed.hlo import HloCost
from repro.hardware import ChipSpec, SystemSpec
from repro.models import params as MP
from repro.models.config import ModelConfig


def kernel_terms(flops: float, bytes_moved: float, chip: ChipSpec) -> Dict[str, Any]:
    """Single-kernel roofline terms on a reference chip.

    The autotune sweep classifies each block-config point with the same
    two-term vocabulary the cell-level analysis uses — but from analytic
    kernel counts (one device, no collectives) rather than the HLO cost
    model, since interpret-mode HLO says nothing about the kernel's math.
    """
    t_c = flops / chip.peak_flops_bf16
    t_m = bytes_moved / chip.hbm_bw
    return {
        "t_compute": t_c,
        "t_memory": t_m,
        "bound_s": max(t_c, t_m),
        "dominant": "compute" if t_c >= t_m else "memory",
        "intensity_flops_per_byte": flops / bytes_moved if bytes_moved else 0.0,
        "ridge_flops_per_byte": chip.peak_flops_bf16 / chip.hbm_bw,
    }


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    system: str
    strategy: str
    chips: int
    # Per-device quantities from the HLO cost model.
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    # Terms (seconds).
    t_compute: float
    t_memory: float
    t_collective: float
    # Model-level accounting.
    model_flops: float
    useful_ratio: float
    # Minimum HBM traffic the step fundamentally needs (params + state read
    # once) vs what the compiled program moves — memory-side usefulness.
    model_bytes: float
    memory_useful_ratio: float
    tokens_per_step: int
    # Memory feasibility (per device, bytes).
    hbm_per_device: float
    hbm_required: float
    fits: bool
    collectives: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Lower-bound step time: resources overlap perfectly."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-bound step time."""
        peak = self.flops_per_device / max(self.t_compute, 1e-30)  # chip peak
        if self.step_time <= 0:
            return 0.0
        return self.model_flops / self.chips / self.step_time / peak

    @property
    def roofline_fraction(self) -> float:
        """Headline score: useful fraction of the *binding* resource.

        Compute-bound cells score MFU; memory-bound cells score
        model_bytes/HLO_bytes at the bound time.  1.0 = the step moves or
        computes nothing the model doesn't fundamentally require.
        """
        if self.step_time <= 0:
            return 0.0
        t_useful_compute = (self.model_flops / self.chips) / (
            self.flops_per_device / max(self.t_compute, 1e-30)
        )
        t_useful_memory = self.t_memory * min(self.memory_useful_ratio, 1.0)
        return max(t_useful_compute, t_useful_memory) / self.step_time

    def suggestion(self) -> str:
        d = self.dominant
        if d == "compute":
            if self.useful_ratio < 0.5:
                return (
                    "compute-bound with low useful ratio: cut redundant compute "
                    "(remat policy, causal-block skipping, replicated attention)"
                )
            return "compute-bound: good; push MXU utilization via kernel tiling"
        if d == "memory":
            return (
                "memory-bound: raise arithmetic intensity (fuse, larger "
                "microbatch, bf16 states, weight-stationary layouts)"
            )
        return (
            "collective-bound: reshard to reduce cross-axis traffic, overlap "
            "collectives with compute, or compress gradients"
        )

    def metrics(self) -> Dict[str, Any]:
        return {
            "hlo_flops": self.flops_per_device * self.chips,
            "hlo_bytes": self.bytes_per_device * self.chips,
            "collective_bytes": self.collective_bytes_per_device * self.chips,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "model_bytes": self.model_bytes,
            "memory_useful_ratio": self.memory_useful_ratio,
            "step_time_bound_s": self.step_time,
            "mfu": self.mfu,
            "roofline_fraction": self.roofline_fraction,
            "hbm_required": self.hbm_required,
            "fits": self.fits,
        }


def tokens_per_step(shape_kind: str, seq_len: int, global_batch: int) -> int:
    if shape_kind == "decode":
        return global_batch  # one token per sequence
    return global_batch * seq_len


def model_flops(cfg: ModelConfig, shape_kind: str, n_tokens: int) -> float:
    n = MP.non_embedding_param_count(cfg, active_only=True)
    factor = 6.0 if shape_kind == "train" else 2.0
    return factor * n * n_tokens


def model_bytes_per_device(
    cfg: ModelConfig, shape_kind: str, *, state_bytes: float, chips: int
) -> float:
    """Minimum HBM traffic/device: weights once (+grad/moment traffic for
    train ≈ 3x params: read p, write p, read+write moments amortized), decode
    state read+write once."""
    import jax.numpy as jnp

    n = MP.count_params_cfg(cfg)
    pbytes = n * jnp.dtype(cfg.dtype).itemsize
    mult = 3.0 if shape_kind == "train" else 1.0
    return (pbytes * mult + state_bytes * 2.0) / chips


def compute(
    *,
    cfg: ModelConfig,
    arch: str,
    shape_name: str,
    shape_kind: str,
    seq_len: int,
    global_batch: int,
    system: SystemSpec,
    strategy: str,
    cost: HloCost,
    hbm_required: float,
    state_bytes: float = 0.0,
) -> Roofline:
    chip = system.chip
    chips = system.n_chips
    ntok = tokens_per_step(shape_kind, seq_len, global_batch)
    mf = model_flops(cfg, shape_kind, ntok)
    mb = model_bytes_per_device(cfg, shape_kind, state_bytes=state_bytes, chips=chips)
    t_c = cost.flops / chip.peak_flops_bf16
    t_m = cost.bytes / chip.hbm_bw
    t_x = cost.collective_bytes / chip.ici_bw_per_link
    ratio = mf / max(cost.flops * chips, 1e-30)
    return Roofline(
        arch=arch,
        shape=shape_name,
        system=system.name,
        strategy=strategy,
        chips=chips,
        flops_per_device=cost.flops,
        bytes_per_device=cost.bytes,
        collective_bytes_per_device=cost.collective_bytes,
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        model_flops=mf,
        useful_ratio=ratio,
        model_bytes=mb,
        memory_useful_ratio=mb / max(cost.bytes, 1e-30),
        tokens_per_step=ntok,
        hbm_per_device=chip.hbm_bytes,
        hbm_required=hbm_required,
        fits=hbm_required <= chip.hbm_bytes,
        collectives=dict(cost.collectives),
    )
