"""Statistical regression gating: baselines + noise-aware detectors for the
CI/CD pipeline layer (paper §IV "early detection of regressions").

The paper's argument for continuous benchmarking is that it only pays off
when the workflow can *act* on performance data — a regression must block a
merge, not surface in an offline plot weeks later.  This module supplies the
three pieces that turn stored benchmark history into an enforceable gate:

* **BaselineManager** — rolling per-(prefix, metric) baselines persisted
  through any ``ResultStore`` backend as protocol envelopes, with explicit
  ``promote`` / ``pin`` / ``expire`` semantics.  Baselines only roll forward
  on green runs, so a regression can never launder itself into the
  reference; a known-good commit can be pinned as a frozen reference.
* **Detectors** — pluggable, each returning a structured :class:`Verdict`
  (status, signed effect size, confidence) instead of a bool:

  - ``mad``       sliding-window median/MAD robust z-score of the candidate
                  against the baseline window (cheap, catches step changes);
  - ``bootstrap`` confidence-interval comparison of candidate vs baseline
                  means via deterministic bootstrap resampling (calibrated
                  under noise, no distributional assumptions);
  - ``cusum``     CUSUM change-point locator over the recent *history*
                  series — it both detects a shift and names the store
                  sequence that introduced it, even when the shift landed
                  between gate runs (e.g. data ingested out-of-band);
  - ``paired``    duet-mode paired-delta judge: per-round
                  (candidate − baseline) relative deltas from interleaved
                  A/B invocations, so shared environmental noise cancels
                  instead of masquerading as signal (the gate switches to
                  it automatically when duet data exists — see
                  ``docs/measurement_methodology.md``).

* **RegressionGate** — a ``gate`` pipeline component: declares which
  execution prefix and metrics it guards (with per-metric direction and
  tolerance), runs after its producers via the component DAG, records its
  verdicts back into the store, and drives ``python -m repro.core.cicd
  ... --gate`` exit codes (0 pass, 3 regression).  By default the gate
  judges straight from the incremental columnar plane
  (``repro.core.columnar``) — metric series arrive as contiguous numpy
  columns extended in O(delta) per append, so a warm gate over a
  multi-thousand-report history costs fractions of a millisecond;
  ``columnar: false`` (CLI ``--no-columnar``) re-parses report objects,
  and both paths are asserted verdict-identical in tests.

CLI (baseline lifecycle + standalone gating)::

    PYTHONPATH=src python -m repro.core.regression --store S show ci.smoke
    PYTHONPATH=src python -m repro.core.regression --store S pin ci.smoke \
        step_time_s --last 8 --commit abc123
    PYTHONPATH=src python -m repro.core.regression --store S gate ci.smoke

See ``docs/regression_gating.md`` for the full lifecycle and YAML syntax.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import duet as duet_mod
from repro.core import fingerprint as fp_mod
from repro.core.component import ComponentSchema, InputSpec
from repro.core.protocol import ProtocolError, unwrap_envelope, wrap_envelope
from repro.core.store import ResultStore

PASS, WARN, FAIL = "pass", "warn", "fail"
_ORDER = {PASS: 0, WARN: 1, FAIL: 2}

BASELINE_KIND = "baseline"
VERDICT_KIND = "gate-verdict"

# Confidence bars for the shared verdict policy (see ``classify``).
FAIL_CONFIDENCE = 0.9
WARN_CONFIDENCE = 0.5


class GateError(ValueError):
    pass


def worst(statuses: Iterable[str]) -> str:
    return max(statuses, key=_ORDER.__getitem__, default=PASS)


def json_safe(obj):
    """Recursively replace non-finite floats (a zero-baseline effect is
    ±inf) with their string form, so persisted reports stay strict JSON —
    ``json.dumps`` would otherwise emit the non-standard ``Infinity`` token
    that jq / JSON.parse consumers reject."""
    if isinstance(obj, float) and not math.isfinite(obj):
        return repr(obj)  # 'inf' / '-inf' / 'nan'
    if isinstance(obj, dict):
        return {k: json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_safe(v) for v in obj]
    return obj


# ---------------------------------------------------------------------------
# Metric specification — direction + tolerance, the per-metric gate contract.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """What "worse" means for one metric.

    ``direction="lower"`` guards lower-is-better metrics (step time, energy);
    ``"higher"`` guards higher-is-better ones (throughput, MFU).
    ``tolerance`` is the minimum relative shift considered meaningful — the
    noise floor of the deployment, not a statistical parameter.
    """

    name: str
    direction: str = "lower"
    tolerance: float = 0.05

    def __post_init__(self):
        if self.direction not in ("lower", "higher"):
            raise GateError(f"bad metric direction {self.direction!r} "
                            "(want 'lower' or 'higher')")
        if self.tolerance < 0:
            raise GateError("tolerance must be >= 0")

    @staticmethod
    def parse(spec: Any, *, direction: str = "lower",
              tolerance: float = 0.05) -> "MetricSpec":
        """``"name"`` | ``"name:direction"`` | ``"name:direction:tolerance"``
        — the compact per-metric form usable inside a YAML list."""
        parts = str(spec).split(":")
        name = parts[0]
        if not name:
            raise GateError(f"empty metric name in {spec!r}")
        if len(parts) > 1 and parts[1]:
            direction = parts[1]
        if len(parts) > 2 and parts[2]:
            tolerance = float(parts[2])
        return MetricSpec(name, direction, tolerance)

    def worse(self, candidate_stat: float, baseline_stat: float) -> float:
        """Signed absolute shift in the 'worse' direction (+ = regression)."""
        d = candidate_stat - baseline_stat
        return d if self.direction == "lower" else -d

    def effect(self, candidate_stat: float, baseline_stat: float) -> float:
        """Signed relative shift (+ = regression); ±inf on a zero baseline."""
        w = self.worse(candidate_stat, baseline_stat)
        if baseline_stat == 0:
            return 0.0 if w == 0 else math.copysign(math.inf, w)
        return w / abs(baseline_stat)


@dataclasses.dataclass
class Verdict:
    """Structured detector output — what a bool can never carry: how big the
    shift is, how sure the detector is, and where the shift started."""

    status: str
    detector: str
    metric: str
    prefix: str
    effect: float = 0.0        # signed relative shift, + = worse
    confidence: float = 0.0    # 0..1
    baseline_n: int = 0
    candidate_n: int = 0
    change_seq: Optional[int] = None  # store sequence that introduced the shift
    detail: str = ""

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(doc: Dict[str, Any]) -> "Verdict":
        known = {f.name for f in dataclasses.fields(Verdict)}
        return Verdict(**{k: v for k, v in doc.items() if k in known})


def classify(effect: float, confidence: float, spec: MetricSpec) -> str:
    """Shared verdict policy: fail needs a meaningful effect AND high
    confidence; either one alone is at most a warning.  This is what keeps
    ultra-low-variance series (tiny sigma, huge z, microscopic effect) and
    single noisy outliers (big effect, low confidence) from blocking CI."""
    if effect >= spec.tolerance and confidence >= FAIL_CONFIDENCE:
        return FAIL
    if effect >= spec.tolerance and confidence >= WARN_CONFIDENCE:
        return WARN
    if confidence >= FAIL_CONFIDENCE and effect >= spec.tolerance / 2:
        return WARN
    return PASS


# ---------------------------------------------------------------------------
# Detectors
# ---------------------------------------------------------------------------

class Detector:
    """Pluggable detector interface.  ``scans_history=True`` detectors are
    fed the raw store history instead of the managed baseline window — they
    localize shifts anywhere in the recent series, including ones that
    landed between gate runs."""

    name = "abstract"
    scans_history = False

    def verdict(
        self,
        baseline: Sequence[float],
        candidate: Sequence[float],
        spec: MetricSpec,
        *,
        prefix: str = "",
        baseline_seqs: Optional[Sequence[int]] = None,
        candidate_seqs: Optional[Sequence[int]] = None,
    ) -> Verdict:
        raise NotImplementedError

    def _skip(self, spec: MetricSpec, prefix: str, nb: int, nc: int,
              detail: str) -> Verdict:
        return Verdict(PASS, self.name, spec.name, prefix,
                       baseline_n=nb, candidate_n=nc, detail=detail)


class MadZScoreDetector(Detector):
    """Robust z-score of the candidate median against the baseline window's
    median/MAD — the noise-aware upgrade of the seed's threshold check."""

    name = "mad"

    def __init__(self, z_threshold: float = 4.0):
        self.z_threshold = max(1e-6, float(z_threshold))

    def verdict(self, baseline, candidate, spec, *, prefix="",
                baseline_seqs=None, candidate_seqs=None) -> Verdict:
        base = np.asarray(baseline, dtype=np.float64)
        cand = np.asarray(candidate, dtype=np.float64)
        if base.size == 0 or cand.size == 0:
            return self._skip(spec, prefix, base.size, cand.size, "empty window")
        med = float(np.median(base))
        mad = float(np.median(np.abs(base - med)))
        # Sigma floor: an all-identical baseline must not turn measurement
        # epsilon into an infinite z — the effect bar in classify() still
        # guards, but the confidence should stay proportionate too.
        sigma = max(1.4826 * mad, 1e-9 * max(abs(med), 1.0))
        cmed = float(np.median(cand))
        z = spec.worse(cmed, med) / sigma
        confidence = min(1.0, max(0.0, z) / self.z_threshold)
        effect = spec.effect(cmed, med)
        return Verdict(
            status=classify(effect, confidence, spec),
            detector=self.name, metric=spec.name, prefix=prefix,
            effect=effect, confidence=confidence,
            baseline_n=int(base.size), candidate_n=int(cand.size),
            detail=f"z={z:.2f}, median {med:.6g} -> {cmed:.6g}",
        )


class BootstrapDetector(Detector):
    """Bootstrap confidence-interval comparison of candidate vs baseline
    means.  Confidence is the bootstrap probability that the candidate is
    worse at all; the effect bar supplies the practical-significance gate.
    Deterministically seeded so CI verdicts are reproducible."""

    name = "bootstrap"

    def __init__(self, n_boot: int = 400, seed: int = 0):
        self.n_boot = max(10, int(n_boot))
        self.seed = int(seed)

    def verdict(self, baseline, candidate, spec, *, prefix="",
                baseline_seqs=None, candidate_seqs=None) -> Verdict:
        base = np.asarray(baseline, dtype=np.float64)
        cand = np.asarray(candidate, dtype=np.float64)
        if base.size == 0 or cand.size == 0:
            return self._skip(spec, prefix, base.size, cand.size, "empty window")
        rng = np.random.default_rng(self.seed)
        bm = rng.choice(base, (self.n_boot, base.size), replace=True).mean(axis=1)
        cm = rng.choice(cand, (self.n_boot, cand.size), replace=True).mean(axis=1)
        diff = cm - bm if spec.direction == "lower" else bm - cm
        confidence = float(np.mean(diff > 0))
        effect = spec.effect(float(cand.mean()), float(base.mean()))
        lo, hi = np.percentile(diff, [2.5, 97.5])
        return Verdict(
            status=classify(effect, confidence, spec),
            detector=self.name, metric=spec.name, prefix=prefix,
            effect=effect, confidence=confidence,
            baseline_n=int(base.size), candidate_n=int(cand.size),
            detail=f"95% CI of worse-shift [{lo:.6g}, {hi:.6g}]",
        )


class CusumDetector(Detector):
    """CUSUM change-point locator over the recent history series.

    Unlike the window detectors it scans history+candidate jointly: the
    cumulative-sum excursion finds *where* the mean shifted, a permutation
    test (deterministically seeded) says how unlikely that excursion is
    under exchangeability, and the verdict names the store sequence right
    after the change point — the commit that introduced the regression.
    """

    name = "cusum"
    scans_history = True

    def __init__(self, n_permutations: int = 128, seed: int = 0):
        self.n_permutations = max(20, int(n_permutations))
        self.seed = int(seed)

    def verdict(self, baseline, candidate, spec, *, prefix="",
                baseline_seqs=None, candidate_seqs=None) -> Verdict:
        x = np.concatenate([
            np.asarray(baseline, dtype=np.float64),
            np.asarray(candidate, dtype=np.float64),
        ])
        # `is not None` (not truthiness): numpy arrays are valid seq inputs.
        seqs = (list(baseline_seqs) if baseline_seqs is not None else []) + \
               (list(candidate_seqs) if candidate_seqs is not None else [])
        n = int(x.size)
        if n < 4:
            return self._skip(spec, prefix, len(baseline), len(candidate),
                              "series too short for change-point analysis")
        s = np.cumsum(x - x.mean())
        k = int(np.argmax(np.abs(s)))  # shift lies between k and k+1
        before, after = x[:k + 1], x[k + 1:]
        if after.size == 0:
            return self._skip(spec, prefix, len(baseline), len(candidate),
                              "no post-change samples")
        effect = spec.effect(float(after.mean()), float(before.mean()))
        obs = float(s.max() - s.min())
        rng = np.random.default_rng(self.seed)
        perms = rng.permuted(np.tile(x, (self.n_permutations, 1)), axis=1)
        sp = np.cumsum(perms - x.mean(), axis=1)
        confidence = float(np.mean(sp.max(axis=1) - sp.min(axis=1) < obs))
        change_seq = int(seqs[k + 1]) if len(seqs) == n else None
        return Verdict(
            status=classify(effect, confidence, spec),
            detector=self.name, metric=spec.name, prefix=prefix,
            effect=effect, confidence=confidence,
            baseline_n=len(baseline), candidate_n=len(candidate),
            change_seq=change_seq,
            detail=(f"shift after index {k}: mean "
                    f"{float(before.mean()):.6g} -> {float(after.mean()):.6g}"),
        )


class PairedDeltaDetector(Detector):
    """Judges per-round duet deltas instead of absolute series.

    Both roles of a duet round run back-to-back on one worker, so shared
    multiplicative noise (frequency scaling, noisy neighbors) divides out of
    each relative delta — the inputs here are already effects, not raw
    values.  ``baseline`` is the historical delta series (older duets of the
    same cell), ``candidate`` the current duet's per-round deltas:

    * effect = median current delta, recentered on the historical delta
      median once enough history exists (cancels any systematic asymmetry
      between the two roles, e.g. cache warm-up favoring the second
      invocation);
    * confidence = fraction of rounds whose delta clears half the
      tolerance, damped by ``1 - 0.5**rounds`` so one or two unanimous
      rounds can warn but never fail on their own.

    Deterministic — no resampling, so CI verdicts are reproducible.
    """

    name = "paired"
    scans_history = False

    def __init__(self, min_rounds: int = 2, center_min_history: int = 3):
        self.min_rounds = max(1, int(min_rounds))
        self.center_min_history = max(1, int(center_min_history))

    def verdict(self, baseline, candidate, spec, *, prefix="",
                baseline_seqs=None, candidate_seqs=None) -> Verdict:
        hist = np.asarray(baseline, dtype=np.float64)
        cand = np.asarray(candidate, dtype=np.float64)
        if cand.size < self.min_rounds:
            return self._skip(spec, prefix, int(hist.size), int(cand.size),
                              f"fewer than {self.min_rounds} completed "
                              "duet rounds")
        finite_hist = hist[np.isfinite(hist)]
        center = (float(np.median(finite_hist))
                  if finite_hist.size >= self.center_min_history else 0.0)
        d = cand - center
        effect = float(np.median(d))
        over = d > spec.tolerance / 2
        confidence = float(np.mean(over)) * (1.0 - 0.5 ** int(cand.size))
        change_seq = None
        if (candidate_seqs is not None and len(candidate_seqs) == cand.size
                and bool(over.any())):
            change_seq = int(list(candidate_seqs)[int(np.argmax(over))])
        return Verdict(
            status=classify(effect, confidence, spec),
            detector=self.name, metric=spec.name, prefix=prefix,
            effect=effect, confidence=confidence,
            baseline_n=int(hist.size), candidate_n=int(cand.size),
            change_seq=change_seq,
            detail=(f"paired deltas: median {effect:+.4g} over "
                    f"{int(cand.size)} rounds (center {center:+.4g})"),
        )


DETECTORS = {
    MadZScoreDetector.name: MadZScoreDetector,
    BootstrapDetector.name: BootstrapDetector,
    CusumDetector.name: CusumDetector,
    PairedDeltaDetector.name: PairedDeltaDetector,
}

DEFAULT_DETECTORS = ("mad", "bootstrap", "cusum")


def get_detector(name: str, **params) -> Detector:
    try:
        cls = DETECTORS[name]
    except KeyError:
        raise GateError(
            f"unknown detector {name!r} (have {sorted(DETECTORS)})"
        ) from None
    return cls(**params)




# ---------------------------------------------------------------------------
# Baseline manager — promote / pin / expire, persisted as envelopes.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Baseline:
    """Reference window for one (source prefix, metric)."""

    metric: str
    source_prefix: str
    values: List[float]
    seqs: List[int]          # store sequences the values came from
    pinned: bool = False
    commit: str = ""
    expired: bool = False
    # Environment-class key (fingerprint.key) the window was measured under;
    # "" for legacy/untagged baselines.  A candidate whose key differs is
    # judged against stratified history instead, and never promotes.
    fingerprint: str = ""

    def to_payload(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_payload(doc: Dict[str, Any]) -> "Baseline":
        known = {f.name for f in dataclasses.fields(Baseline)}
        return Baseline(**{k: v for k, v in doc.items() if k in known})


class BaselineManager:
    """Append-only baseline history in the result store, latest-wins.

    Each state change (promote/pin/unpin/expire) appends one envelope report
    under ``<baseline prefix>.<source prefix>`` with the metric name as the
    report variant — so ``current`` is a single index-filtered ``latest``
    and the full lifecycle stays auditable like any benchmark history.

    * ``promote`` rolls green values into the window (no-op while pinned —
      a pinned reference defends itself until explicitly released);
    * ``pin`` freezes a known-good reference (by values, or the newest
      ``last`` store points);
    * ``expire`` drops the baseline; the next gate re-seeds from history.
    """

    def __init__(self, store: ResultStore, *, prefix: str = "baseline",
                 window: int = 32):
        self.store = store
        self.prefix = prefix
        self.window = max(1, int(window))

    def storage_prefix(self, source_prefix: str) -> str:
        return f"{self.prefix}.{source_prefix}"

    def current(self, source_prefix: str, metric: str) -> Optional[Baseline]:
        rep = self.store.latest(self.storage_prefix(source_prefix), variant=metric)
        if rep is None:
            return None
        try:
            kind, payload = unwrap_envelope(rep)
        except ProtocolError:
            return None
        if kind != BASELINE_KIND:
            return None
        b = Baseline.from_payload(payload)
        return None if b.expired else b

    def _record(self, b: Baseline) -> Baseline:
        rep = wrap_envelope(
            BASELINE_KIND, b.to_payload(),
            system="baseline-manager", source=b.source_prefix, variant=b.metric,
        )
        self.store.append(self.storage_prefix(b.source_prefix), rep)
        return b

    def promote(self, source_prefix: str, metric: str,
                values: Sequence[float], seqs: Sequence[int],
                commit: str = "", fingerprint: str = "") -> Baseline:
        cur = self.current(source_prefix, metric)
        if cur is not None and cur.pinned:
            return cur
        old_v = list(cur.values) if cur else []
        old_s = list(cur.seqs) if cur else []
        # A sequence already in the window is a re-judged point, not new
        # evidence (a gate re-run over an unchanged store): skip it, or the
        # window degenerates into copies of the newest candidate and MAD's
        # sigma collapses.  Duplicates *within* one batch are legitimate —
        # one report can carry several data entries at the same sequence.
        seen = set(old_s)
        fresh = [(float(v), int(s)) for v, s in zip(values, seqs)
                 if s not in seen]
        if not fresh and cur is not None:
            return cur
        merged_v = (old_v + [v for v, _ in fresh])[-self.window:]
        merged_s = (old_s + [s for _, s in fresh])[-self.window:]
        return self._record(Baseline(
            metric, source_prefix, merged_v, merged_s, commit=commit,
            fingerprint=fingerprint or (cur.fingerprint if cur else "")))

    def pin(self, source_prefix: str, metric: str, *,
            values: Optional[Sequence[float]] = None,
            seqs: Optional[Sequence[int]] = None,
            last: Optional[int] = None, commit: str = "") -> Baseline:
        if values is None and last is not None:
            pairs = self.store.query_with_entries(source_prefix, last=None)
            series = _series(pairs, metric)[-max(1, int(last)):]
            if not series:
                raise GateError(f"no {metric!r} history under {source_prefix!r}")
            seqs = [s for s, _ in series]
            values = [v for _, v in series]
        if values is None:
            cur = self.current(source_prefix, metric)
            if cur is None:
                raise GateError(
                    f"no baseline for ({source_prefix!r}, {metric!r}) to pin; "
                    "pass values or --last")
            values, seqs = cur.values, cur.seqs
        return self._record(Baseline(
            metric, source_prefix,
            [float(v) for v in values], [int(s) for s in (seqs or [])],
            pinned=True, commit=commit,
        ))

    def unpin(self, source_prefix: str, metric: str) -> Baseline:
        cur = self.current(source_prefix, metric)
        if cur is None:
            raise GateError(f"no baseline for ({source_prefix!r}, {metric!r})")
        return self._record(dataclasses.replace(cur, pinned=False))

    def expire(self, source_prefix: str, metric: str) -> Baseline:
        return self._record(Baseline(metric, source_prefix, [], [], expired=True))

    def metrics(self, source_prefix: str) -> List[str]:
        """Metric names with any baseline history under a source prefix."""
        reports = self.store.query(self.storage_prefix(source_prefix))
        return sorted({r.experiment.variant for r in reports})


# ---------------------------------------------------------------------------
# The gate
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GateSpec:
    """Declarative gate configuration (the pipeline component's inputs)."""

    source_prefix: str
    metrics: List[MetricSpec]
    detectors: Tuple[str, ...] = DEFAULT_DETECTORS
    window: int = 32          # baseline rolling-window size
    candidate: int = 1        # newest points treated as "this run"
    min_points: int = 3       # minimum baseline points before judging
    history: int = 512        # store tail pulled for history-scanning detectors
    update_baseline: bool = True
    warn_only: bool = False   # report, but never block (staged rollout)
    baseline_prefix: str = "baseline"
    record_prefix: str = ""   # "" -> gate.<source_prefix>; "none" disables
    use_columnar: bool = True  # series from the columnar plane (O(delta) warm)
    duet: bool = True         # judge paired deltas when duet data exists
    duet_rounds: int = 2      # min completed pairs in the newest duet to engage
    detector_params: Dict[str, Dict[str, Any]] = dataclasses.field(
        default_factory=dict)

    @staticmethod
    def from_inputs(inputs: Dict[str, Any]) -> "GateSpec":
        inp = dict(inputs)
        source = inp.get("source_prefix")
        if not source:
            raise GateError("gate component needs a source_prefix input")
        direction = str(inp.get("direction", "lower"))
        tolerance = float(inp.get("tolerance", 0.05))
        raw = inp.get("metrics", ["step_time_s"])
        if isinstance(raw, str):
            raw = [raw]
        metrics = [MetricSpec.parse(m, direction=direction, tolerance=tolerance)
                   for m in raw]
        dets = inp.get("detectors", list(DEFAULT_DETECTORS))
        if isinstance(dets, str):
            dets = [d.strip() for d in dets.split(",") if d.strip()]
        for d in dets:
            if d not in DETECTORS:
                raise GateError(f"unknown detector {d!r} (have {sorted(DETECTORS)})")
        # Detector tuning: nested {"mad": {"z_threshold": 6}} (JSON
        # pipelines / library use) or flat dotted keys ``mad.z_threshold: 6``
        # (the YAML subset has no nested mappings).
        params: Dict[str, Dict[str, Any]] = {
            k: dict(v) for k, v in inp.get("detector_params", {}).items()
            if isinstance(v, dict)
        }
        for key, val in inp.items():
            if "." in key:
                det, _, param = key.partition(".")
                if det in DETECTORS:
                    params.setdefault(det, {})[param] = val
        return GateSpec(
            source_prefix=str(source),
            metrics=metrics,
            detectors=tuple(dets),
            window=int(inp.get("window", 32)),
            candidate=int(inp.get("candidate", 1)),
            min_points=int(inp.get("min_points", 3)),
            history=int(inp.get("history", 512)),
            update_baseline=bool(inp.get("update_baseline", True)),
            warn_only=bool(inp.get("warn_only", False)),
            baseline_prefix=str(inp.get("baseline_prefix", "baseline")),
            record_prefix=str(inp.get("prefix", inp.get("record_prefix", ""))),
            use_columnar=bool(inp.get("columnar", True)),
            duet=bool(inp.get("duet", True)),
            duet_rounds=int(inp.get("duet_rounds", 2)),
            detector_params=params,
        )


# Declared input schema for the ``gate`` pipeline component, registered by
# ``repro.core.orchestrator`` alongside the other components.  Defaults are
# DERIVED from the ``GateSpec``/``MetricSpec`` dataclass fields — one source
# of truth, so a default changed there can never silently diverge between
# pipeline-dispatched and library-constructed gates.  Per-detector tuning
# arrives through the open ``<detector>.<param>`` dotted namespaces
# (``mad.z_threshold: 6``), matching ``GateSpec.from_inputs``.
_GS = {f.name: f.default for f in dataclasses.fields(GateSpec)}
_MS = {f.name: f.default for f in dataclasses.fields(MetricSpec)}
GATE_SCHEMA = ComponentSchema(
    "gate", 1,
    inputs=(
        InputSpec("source_prefix", str, required=True,
                  help="execution prefix whose history the gate judges"),
        InputSpec("metrics", (str, list), default=("step_time_s",),
                  wrap_scalar=True,
                  help="metric names, or 'name:direction:tolerance' forms"),
        InputSpec("direction", str, default=_MS["direction"],
                  choices=("lower", "higher")),
        InputSpec("tolerance", float, default=_MS["tolerance"],
                  help="minimum relative shift considered meaningful"),
        InputSpec("detectors", (str, list), default=_GS["detectors"],
                  help=f"detector names (have {sorted(DETECTORS)})"),
        InputSpec("window", int, default=_GS["window"]),
        InputSpec("candidate", int, default=_GS["candidate"]),
        InputSpec("min_points", int, default=_GS["min_points"]),
        InputSpec("history", int, default=_GS["history"]),
        InputSpec("update_baseline", bool, default=_GS["update_baseline"]),
        InputSpec("warn_only", bool, default=_GS["warn_only"]),
        InputSpec("baseline_prefix", str, default=_GS["baseline_prefix"]),
        InputSpec("prefix", str,
                  help="record prefix for verdicts ('none' disables; "
                       "default gate.<source_prefix>)"),
        InputSpec("record_prefix", str),
        InputSpec("columnar", bool, default=_GS["use_columnar"]),
        InputSpec("duet", bool, default=_GS["duet"],
                  help="judge paired per-round deltas whenever the newest "
                       "duet has enough completed rounds"),
        InputSpec("duet_rounds", int, default=_GS["duet_rounds"],
                  help="minimum completed pairs in the newest duet before "
                       "paired mode engages (else absolute fallback)"),
        InputSpec("detector_params", dict,
                  help="nested per-detector tuning (JSON pipelines)"),
    ),
    open_namespaces=tuple(DETECTORS),
    description="statistical regression gate over one prefix's stored history",
)


class RegressionGate:
    """Runs every configured detector over every guarded metric and reduces
    to one enforceable status; ``cicd --gate`` maps it to exit codes."""

    def __init__(self, spec: GateSpec):
        self.spec = spec

    @staticmethod
    def from_inputs(inputs: Dict[str, Any]) -> "RegressionGate":
        return RegressionGate(GateSpec.from_inputs(inputs))

    def run(self, store: ResultStore) -> Dict[str, Any]:
        sp = self.spec
        mgr = BaselineManager(store, prefix=sp.baseline_prefix, window=sp.window)
        if sp.use_columnar:
            # Columnar fast path: O(delta) refresh + one mask per metric —
            # no report object is materialized on the warm path.
            table = store.columnar.table(sp.source_prefix)
            series_for = {
                m.name: table.series(m.name, success_only=True,
                                     last_entries=sp.history)
                for m in sp.metrics
            }
            pairs_for = ({m.name: table.duet_pairs(m.name,
                                                   last_entries=sp.history)
                          for m in sp.metrics} if sp.duet else {})
            fp_map = table.seq_fingerprints()
            trusted = {int(s) for s, t in zip(table.columns["seq"].tolist(),
                                              table.columns["trusted"].tolist())
                       if t}
        else:
            pairs = store.query_with_entries(sp.source_prefix, last=sp.history)
            series_for = {m.name: _series(pairs, m.name) for m in sp.metrics}
            pairs_for = ({m.name: duet_mod.pairs_from_reports(pairs, m.name)
                          for m in sp.metrics} if sp.duet else {})
            fp_map = {int(e.seq): fp_mod.key_of(r) for e, r in pairs}
            trusted = {int(e.seq) for e, r in pairs
                       if r.reporter.chain_of_trust}
        gates = []
        for m in sp.metrics:
            hist_p, cand_p = _split_duet_pairs(pairs_for.get(m.name, []),
                                               sp.candidate)
            if cand_p and len(cand_p) >= max(1, sp.duet_rounds):
                gates.append(self._gate_metric_paired(hist_p, cand_p, m,
                                                      fp_map=fp_map))
            else:
                gates.append(self._gate_metric(mgr, series_for[m.name], m,
                                               fp_map=fp_map, trusted=trusted))
        status = worst(g["status"] for g in gates)
        summary = {
            "component": "gate",
            "source_prefix": sp.source_prefix,
            "status": status,
            "gates": gates,
        }
        summary["markdown"] = gate_markdown([summary])
        if sp.record_prefix != "none":
            record_prefix = sp.record_prefix or f"gate.{sp.source_prefix}"
            store.append(record_prefix, wrap_envelope(
                VERDICT_KIND, json_safe({"status": status, "gates": gates}),
                system="gate", source=sp.source_prefix,
            ))
        return summary

    def _gate_metric(self, mgr: BaselineManager, series: Any,
                     mspec: MetricSpec, *,
                     fp_map: Optional[Dict[int, str]] = None,
                     trusted: Optional[set] = None) -> Dict[str, Any]:
        sp = self.spec
        fp_map = fp_map or {}
        # ``series`` is either a columnar ``MetricSeries`` (arrays, no
        # conversion) or the report-path ``[(seq, value), ...]`` list; both
        # are normalized to aligned numpy columns once, here.
        if hasattr(series, "seqs"):
            seqs = np.asarray(series.seqs, dtype=np.int64)
            vals = np.asarray(series.values, dtype=np.float64)
        else:
            n = len(series)
            seqs = np.fromiter((s for s, _ in series), dtype=np.int64, count=n)
            vals = np.fromiter((v for _, v in series), dtype=np.float64, count=n)
        split = max(0, int(seqs.size) - max(0, sp.candidate))
        hist_vals, hist_seqs = vals[:split], seqs[:split]
        cvals, cseqs = vals[split:], seqs[split:]
        cseq_list = cseqs.tolist()
        # Fingerprint stratification: when the candidate carries an
        # environment-class key, only history measured under the SAME class
        # may serve as a judged-against or re-seeded baseline.  Untagged
        # candidates ("" — legacy reports, synthetic injections) keep the
        # pre-fingerprint behavior exactly.
        cand_fp = fp_map.get(int(cseq_list[-1]), "") if cseq_list else ""
        stratified_out = 0
        if cand_fp and hist_seqs.size:
            keep = np.fromiter(
                (fp_map.get(int(s), "") in ("", cand_fp) for s in hist_seqs),
                dtype=bool, count=int(hist_seqs.size))
            stratified_out = int(hist_seqs.size - keep.sum())
            if stratified_out:
                hist_vals, hist_seqs = hist_vals[keep], hist_seqs[keep]
        base = mgr.current(sp.source_prefix, mspec.name)
        base_fp = base.fingerprint if base is not None else ""
        drift_fields: List[str] = []
        if base is not None and base_fp and cand_fp and base_fp != cand_fp:
            # The recorded baseline was measured under a different
            # environment class: judge from stratified history instead, and
            # block promotion below — a drifted run must never silently
            # become the reference.
            drift_fields = fp_mod.drift(base_fp, cand_fp) or ["fingerprint"]
        if base is not None and not drift_fields:
            bvals = np.asarray(base.values, dtype=np.float64)
            bseqs, pinned = list(base.seqs), base.pinned
        else:
            bvals = hist_vals[-sp.window:]
            bseqs = hist_seqs[-sp.window:].tolist()
            pinned = base.pinned if base is not None else False
        nb, nc = int(bvals.size), int(cvals.size)
        out: Dict[str, Any] = {
            "prefix": sp.source_prefix,
            "metric": mspec.name,
            "direction": mspec.direction,
            "tolerance": mspec.tolerance,
            "mode": "absolute",
            "baseline": {
                "n": nb,
                "pinned": pinned,
                "median": float(np.median(bvals)) if nb else None,
            },
            "candidate_seqs": cseq_list,
            "warn_only": sp.warn_only,
            "fingerprint": {
                "candidate": cand_fp,
                "baseline": base_fp,
                "drift": drift_fields,
                "stratified_out": stratified_out,
            },
        }
        if nb < sp.min_points or not nc:
            verdicts = [Verdict(
                PASS, "none", mspec.name, sp.source_prefix,
                baseline_n=nb, candidate_n=nc,
                detail=f"insufficient history to judge "
                       f"(baseline {nb} < {sp.min_points} "
                       f"or no candidate points)",
            )]
        else:
            verdicts = []
            for name in sp.detectors:
                det = get_detector(name, **sp.detector_params.get(name, {}))
                if det.scans_history:
                    v = det.verdict(hist_vals, cvals, mspec,
                                    prefix=sp.source_prefix,
                                    baseline_seqs=hist_seqs.tolist(),
                                    candidate_seqs=cseq_list)
                else:
                    v = det.verdict(bvals, cvals, mspec,
                                    prefix=sp.source_prefix,
                                    baseline_seqs=bseqs,
                                    candidate_seqs=cseq_list)
                verdicts.append(v)
        raw_status = worst(v.status for v in verdicts)
        out["verdicts"] = [v.to_dict() for v in verdicts]
        out["change_seq"] = next(
            (v.change_seq for v in verdicts if v.change_seq is not None), None)
        # Only green runs roll the baseline forward — a failed candidate must
        # never become part of the reference it just violated.  Drifted or
        # untrusted candidates never promote either: a changed environment
        # must be acknowledged (baseline expire/pin), not laundered in.
        promotion = "skipped"
        if sp.update_baseline and raw_status != FAIL and nc:
            if drift_fields:
                promotion = "blocked-drift"
            elif base is not None and base.pinned:
                promotion = "frozen-pinned"
            else:
                keep_idx = [i for i, s in enumerate(cseq_list)
                            if trusted is None or int(s) in trusted]
                if not keep_idx:
                    promotion = "blocked-untrusted"
                else:
                    pv = cvals[keep_idx]
                    ps = [int(cseq_list[i]) for i in keep_idx]
                    if base is None:
                        mgr.promote(sp.source_prefix, mspec.name,
                                    np.concatenate([bvals, pv]), bseqs + ps,
                                    fingerprint=cand_fp)
                    else:
                        mgr.promote(sp.source_prefix, mspec.name, pv, ps,
                                    fingerprint=cand_fp)
                    promotion = "updated"
        out["promotion"] = promotion
        out["status"] = WARN if (sp.warn_only and raw_status == FAIL) else raw_status
        return out

    def _gate_metric_paired(self, hist_pairs: List["duet_mod.DuetPair"],
                            cand_pairs: List["duet_mod.DuetPair"],
                            mspec: MetricSpec, *,
                            fp_map: Optional[Dict[int, str]] = None
                            ) -> Dict[str, Any]:
        """Paired-delta gate path: the newest duet's per-round relative
        deltas (already noise-cancelled) judged against the historical delta
        series of older duets.  No absolute baseline participates — the
        interleaved baseline role IS the reference, so there is nothing to
        promote and environment drift cannot bias the verdict (it shifts
        both roles of a pair together)."""
        sp = self.spec
        fp_map = fp_map or {}
        hist_d = np.asarray([mspec.effect(p.candidate, p.baseline)
                             for p in hist_pairs], dtype=np.float64)
        cand_d = np.asarray([mspec.effect(p.candidate, p.baseline)
                             for p in cand_pairs], dtype=np.float64)
        det = PairedDeltaDetector(**sp.detector_params.get("paired", {}))
        v = det.verdict(hist_d, cand_d, mspec, prefix=sp.source_prefix,
                        baseline_seqs=[p.seq for p in hist_pairs],
                        candidate_seqs=[p.seq for p in cand_pairs])
        raw_status = v.status
        cand_fp = fp_map.get(int(cand_pairs[-1].seq), "")
        finite_hist = hist_d[np.isfinite(hist_d)]
        out: Dict[str, Any] = {
            "prefix": sp.source_prefix,
            "metric": mspec.name,
            "direction": mspec.direction,
            "tolerance": mspec.tolerance,
            "mode": "paired",
            "duet": {
                "duet_ids": sorted({p.duet_id for p in cand_pairs}),
                "rounds": len(cand_pairs),
                "history_pairs": len(hist_pairs),
            },
            "baseline": {
                "n": len(hist_pairs),
                "pinned": False,
                "median": (float(np.median(finite_hist))
                           if finite_hist.size else None),
            },
            "candidate_seqs": [p.seq for p in cand_pairs],
            "warn_only": sp.warn_only,
            "fingerprint": {
                "candidate": cand_fp,
                "baseline": "",
                "drift": [],
                "stratified_out": 0,
            },
            "verdicts": [v.to_dict()],
            "change_seq": v.change_seq,
            # Absolute baselines do not roll in paired mode: the paired
            # history is read straight from stored duet reports.
            "promotion": "paired",
        }
        out["status"] = WARN if (sp.warn_only and raw_status == FAIL) else raw_status
        return out


def _split_duet_pairs(
    dpairs: Sequence["duet_mod.DuetPair"], n_current: int
) -> Tuple[List["duet_mod.DuetPair"], List["duet_mod.DuetPair"]]:
    """(historical pairs, current-run pairs): the newest ``n_current`` duet
    groups (by candidate store order) are "this run", everything older is
    the paired-delta history."""
    order: List[str] = []
    groups: Dict[str, List[Any]] = {}
    for p in dpairs:  # already sorted by (candidate seq, round)
        if p.duet_id not in groups:
            order.append(p.duet_id)
            groups[p.duet_id] = []
        groups[p.duet_id].append(p)
    cut = max(1, int(n_current))
    cand = [p for i in order[-cut:] for p in groups[i]]
    hist = [p for i in order[:-cut] for p in groups[i]]
    return hist, cand


def _series(pairs: Sequence[Tuple[Any, Any]], metric: str) -> List[Tuple[int, float]]:
    """(store sequence, value) points for one metric, successful entries only
    — failed runs must not poison baselines or trip detectors."""
    out: List[Tuple[int, float]] = []
    for entry, report in pairs:
        for d in report.data:
            if not d.success:
                continue
            if metric in d.metrics:
                try:
                    out.append((entry.seq, float(d.metrics[metric])))
                except (TypeError, ValueError):
                    continue
            elif metric == "runtime":
                out.append((entry.seq, float(d.runtime)))
    return out


_ICON = {PASS: "✅", WARN: "⚠️", FAIL: "❌"}


def gate_markdown(summaries: Sequence[Dict[str, Any]]) -> str:
    """PR-comment-ready summary of one or more gate component results."""
    if not summaries:
        return "## Benchmark regression gate\n\nNo gate components ran.\n"
    lines = [
        "## Benchmark regression gate",
        "",
        "| prefix | metric | status | effect | confidence | detector | change seq |",
        "|---|---|---|---|---|---|---|",
    ]
    for s in summaries:
        for g in s.get("gates", []):
            vs = g.get("verdicts", [])
            w = max(vs, key=lambda v: (_ORDER.get(v.get("status"), 0),
                                       v.get("confidence", 0.0)),
                    default={"effect": 0.0, "confidence": 0.0, "detector": "none"})
            seq = g.get("change_seq")
            lines.append(
                f"| {g['prefix']} | {g['metric']} "
                f"| {_ICON.get(g['status'], '')} {g['status']} "
                f"| {w.get('effect', 0.0):+.1%} | {w.get('confidence', 0.0):.2f} "
                f"| {w.get('detector', '')} | {seq if seq is not None else '—'} |"
            )
    lines += [
        "",
        "_effect: relative shift in the guarded direction (+ = worse); "
        "confidence: detector certainty the shift is real; change seq: store "
        "sequence that introduced it (CUSUM)._",
    ]
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# CLI — baseline lifecycle + standalone gating (CI-scriptable, exit 0/3).
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    import argparse
    import json as _json

    ap = argparse.ArgumentParser(prog="repro.core.regression",
                                 description=__doc__)
    ap.add_argument("--store", default="exacb_data")
    ap.add_argument("--store-backend", default="dir", choices=("dir", "jsonl"))
    sub = ap.add_subparsers(dest="cmd", required=True)

    show = sub.add_parser("show", help="print current baselines for a prefix")
    show.add_argument("source_prefix")
    show.add_argument("--metric", default=None)

    pin = sub.add_parser("pin", help="pin a known-good reference")
    pin.add_argument("source_prefix")
    pin.add_argument("metric")
    pin.add_argument("--last", type=int, default=None,
                     help="pin the newest N store points (default: pin the "
                          "current rolling baseline)")
    pin.add_argument("--commit", default="")

    unpin = sub.add_parser("unpin", help="release a pinned reference")
    unpin.add_argument("source_prefix")
    unpin.add_argument("metric")

    exp = sub.add_parser("expire", help="drop a baseline (next gate re-seeds)")
    exp.add_argument("source_prefix")
    exp.add_argument("metric")

    gate = sub.add_parser("gate", help="run the gate standalone (exit 0/3)")
    gate.add_argument("source_prefix")
    gate.add_argument("--metrics", default="step_time_s",
                      help="comma-separated metric specs "
                           "(name[:direction[:tolerance]])")
    gate.add_argument("--direction", default="lower", choices=("lower", "higher"))
    gate.add_argument("--tolerance", type=float, default=0.05)
    gate.add_argument("--detectors", default=",".join(DEFAULT_DETECTORS))
    gate.add_argument("--candidate", type=int, default=1)
    gate.add_argument("--min-points", type=int, default=3)
    gate.add_argument("--window", type=int, default=32)
    gate.add_argument("--no-update-baseline", action="store_true")
    gate.add_argument("--no-duet", action="store_true",
                      help="ignore duet pairs; judge the absolute series")
    gate.add_argument("--duet-rounds", type=int, default=2,
                      help="min completed pairs in the newest duet before "
                           "the paired path engages")
    gate.add_argument("--no-columnar", action="store_true",
                      help="judge from report objects instead of the "
                           "columnar plane (debug/parity checks)")
    gate.add_argument("--report", default=None,
                      help="write the gate report JSON here")

    args = ap.parse_args(argv)
    store = ResultStore(args.store, backend=args.store_backend)
    mgr = BaselineManager(store)

    if args.cmd == "show":
        metrics = [args.metric] if args.metric else mgr.metrics(args.source_prefix)
        out = {}
        for m in metrics:
            b = mgr.current(args.source_prefix, m)
            out[m] = b.to_payload() if b else None
        print(_json.dumps(out, indent=2))
        return 0
    if args.cmd == "pin":
        b = mgr.pin(args.source_prefix, args.metric, last=args.last,
                    commit=args.commit)
        print(_json.dumps(b.to_payload(), indent=2))
        return 0
    if args.cmd == "unpin":
        b = mgr.unpin(args.source_prefix, args.metric)
        print(_json.dumps(b.to_payload(), indent=2))
        return 0
    if args.cmd == "expire":
        mgr.expire(args.source_prefix, args.metric)
        print(f"expired baseline(s) for ({args.source_prefix}, {args.metric})")
        return 0

    # gate
    summary = RegressionGate(GateSpec.from_inputs({
        "source_prefix": args.source_prefix,
        "metrics": [m.strip() for m in args.metrics.split(",") if m.strip()],
        "direction": args.direction,
        "tolerance": args.tolerance,
        "detectors": args.detectors,
        "candidate": args.candidate,
        "min_points": args.min_points,
        "window": args.window,
        "update_baseline": not args.no_update_baseline,
        "columnar": not args.no_columnar,
        "duet": not args.no_duet,
        "duet_rounds": args.duet_rounds,
    })).run(store)
    if args.report:
        from pathlib import Path

        Path(args.report).write_text(
            _json.dumps(json_safe(summary), indent=2, default=str) + "\n")
    print(summary["markdown"])
    return 3 if summary["status"] == FAIL else 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
