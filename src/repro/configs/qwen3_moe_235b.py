"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) expert
d_ff=1536 vocab=151936; MoE 128 experts, top-8, no shared expert; qk_norm.
[hf:Qwen/Qwen3-30B-A3B; hf]
"""

import dataclasses

from repro.models.config import ATTN, MLP_MOE, LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,  # expert hidden width (pool spec)
    vocab_size=151936,
    block_pattern=(LayerSpec(ATTN, mlp=MLP_MOE),),
    rope_theta=1_000_000.0,
    use_qk_norm=True,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff=1536, capacity_factor=1.25),
    family="moe",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen3-moe-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=32,
        vocab_size=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, capacity_factor=1.5),
    )
