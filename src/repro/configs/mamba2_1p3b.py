"""mamba2-1.3b [ssm] — 48L d_model=2048 (attention-free) vocab=50280,
ssm_state=128.  SSD (state-space duality); expand 2, head_dim 64 (64 heads),
causal conv width 4.  [arXiv:2405.21060; unverified]
"""

import dataclasses

from repro.models.config import MLP_NONE, SSD, LayerSpec, ModelConfig, SSDConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    n_layers=48,
    d_model=2048,
    n_heads=64,  # d_inner / head_dim
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    block_pattern=(LayerSpec(SSD, mlp=MLP_NONE),),
    ssd=SSDConfig(d_state=128, expand=2, head_dim=64, n_groups=1, conv_width=4),
    tie_embeddings=True,
    family="ssm",
    long_context=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="mamba2-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=32,  # d_inner = 128 = 4 * 32
        d_ff=0,
        vocab_size=256,
        ssd=SSDConfig(d_state=16, expand=2, head_dim=32, n_groups=1, conv_width=4, chunk_size=8),
    )
