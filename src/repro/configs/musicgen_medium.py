"""musicgen-medium [audio] — 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048 per codebook.  Decoder-only over EnCodec tokens (4 codebooks,
delay pattern).  The EnCodec frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (assignment requirement).  Deviation noted in
DESIGN.md: positions use RoPE rather than the original sinusoidal embeddings.
[arXiv:2306.05284; hf]
"""

import dataclasses

from repro.models.config import ATTN, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    block_pattern=(LayerSpec(ATTN),),
    input_mode="embeddings",
    n_codebooks=4,
    family="audio",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="musicgen-medium-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=64,
    )
