"""deepseek-v3-671b [moe] — 61L d_model=7168 128H (MLA) vocab=129280;
MoE 1 shared + 256 routed experts, top-8, expert d_ff=2048; sigmoid routing
with routed scaling; MTP depth 1.  [arXiv:2412.19437; hf]

Pool-config note: the published model uses 3 leading dense layers; the pool
entry specifies a uniform "MoE 256e top-8" structure, which we follow exactly
(all 61 layers MoE).  MLA dims follow the paper: q_lora 1536, kv_lora 512,
qk_nope 128, qk_rope 64, v 128.
"""

import dataclasses

from repro.models.config import (
    MLA,
    MLP_MOE,
    LayerSpec,
    MLAConfig,
    ModelConfig,
    MoEConfig,
)

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=192,  # qk_nope + qk_rope
    d_ff=2048,
    vocab_size=129280,
    block_pattern=(LayerSpec(MLA, mlp=MLP_MOE),),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        d_ff=2048,
        n_shared_experts=1,
        shared_d_ff=2048,
        router_fn="sigmoid",
        routed_scale=2.5,
        capacity_factor=1.25,
    ),
    mtp_depth=1,
    family="moe",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="deepseek-v3-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=24,
        d_ff=32,
        vocab_size=256,
        mla=MLAConfig(
            q_lora_rank=32,
            kv_lora_rank=16,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        ),
        moe=MoEConfig(
            n_experts=8,
            top_k=2,
            d_ff=32,
            n_shared_experts=1,
            shared_d_ff=32,
            router_fn="sigmoid",
            routed_scale=2.5,
            capacity_factor=1.5,
        ),
        mtp_depth=1,
    )
