"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.

RoPE, GQA.  [hf:THUDM/glm-4-9b; hf]
"""

import dataclasses

from repro.models.config import ATTN, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    block_pattern=(LayerSpec(ATTN),),
    rope_theta=10000.0,
    family="dense",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="glm4-9b-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
