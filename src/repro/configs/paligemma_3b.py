"""paligemma-3b [vlm] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216.

SigLIP vision tower + gemma decoder; prefix-LM attention over 256 image
patch tokens.  The SigLIP frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (assignment requirement).
[arXiv:2407.07726; hf]
"""

import dataclasses

from repro.models.config import ATTN, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    block_pattern=(LayerSpec(ATTN),),
    prefix_len=256,
    tie_embeddings=True,
    scale_embeddings=True,
    family="vlm",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="paligemma-3b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        prefix_len=8,
    )
