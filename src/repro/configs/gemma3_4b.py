"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global interleaving, 128k context; local window 1024, RoPE theta
10k local / 1M global; qk-norm; tied + scaled embeddings (gemma family).
[hf:google/gemma-3-1b-pt; unverified]
"""

import dataclasses

from repro.models.config import ATTN, LayerSpec, ModelConfig

_LOCAL = LayerSpec(ATTN, window=1024, rope_theta=10_000.0)
_GLOBAL = LayerSpec(ATTN, window=None, rope_theta=1_000_000.0)

CONFIG = ModelConfig(
    name="gemma3-4b",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    block_pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    use_qk_norm=True,
    tie_embeddings=True,
    scale_embeddings=True,
    family="dense",
    long_context=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="gemma3-4b-smoke",
        n_layers=8,  # exercises one full period + remainder
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        block_pattern=(
            dataclasses.replace(_LOCAL, window=8),
            dataclasses.replace(_LOCAL, window=8),
            _GLOBAL,
        ),
    )
