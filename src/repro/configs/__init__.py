"""The benchmark collection: one self-contained module per architecture.

Mirrors the paper's decentralized benchmark repositories — each module owns
its exact published configuration plus a reduced "smoke" variant, and
registers itself with the collection registry (``ARCHS``).  Nothing outside
the module needs editing to onboard a new architecture (paper §IV-A).
"""

from __future__ import annotations

import importlib
from typing import Callable, Dict

from repro.models.config import ModelConfig

_MODULES = {
    "glm4-9b": "repro.configs.glm4_9b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "mamba2-1.3b": "repro.configs.mamba2_1p3b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    cfg = mod.CONFIG
    cfg.validate()
    return cfg


def get_smoke(arch: str) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    cfg = mod.smoke()
    cfg.validate()
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
