"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000.  RG-LRU + local attention, 1 attn : 2 recurrent (Griffin).
[arXiv:2402.19427; hf]
"""

import dataclasses

from repro.models.config import ATTN, RGLRU, LayerSpec, ModelConfig, RGLRUConfig

_REC = LayerSpec(RGLRU)
_ATT = LayerSpec(ATTN, window=2048)

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    n_layers=26,  # 8 full (R,R,A) periods + (R,R) remainder
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=(_REC, _REC, _ATT),
    rglru=RGLRUConfig(lru_width=2560, conv_width=4),
    tie_embeddings=True,
    scale_embeddings=True,
    family="hybrid",
    long_context=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="recurrentgemma-2b-smoke",
        n_layers=5,  # 1 full period + (R,R) remainder
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        block_pattern=(_REC, _REC, dataclasses.replace(_ATT, window=8)),
        rglru=RGLRUConfig(lru_width=64, conv_width=4),
    )
