"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.

GQA, RoPE.  [arXiv:2402.19173; hf]
"""

import dataclasses

from repro.models.config import ATTN, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    block_pattern=(LayerSpec(ATTN),),
    rope_theta=100_000.0,
    family="dense",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="starcoder2-3b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
