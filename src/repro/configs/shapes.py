"""Assigned input shapes and abstract input specs (ShapeDtypeStruct).

Every (architecture × shape) pair defines a *benchmark cell* in the exaCB
collection.  ``decode_*`` / ``long_*`` cells lower ``serve_step`` (one token
against a seq_len KV cache); ``train_*`` lowers ``train_step``; ``prefill_*``
lowers ``prefill_step``.  ``long_500k`` applies only to sub-quadratic
architectures (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig

TRAIN, PREFILL, DECODE = "train", "prefill", "decode"


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", TRAIN, 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", PREFILL, 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", DECODE, 32768, 128),
    "long_500k": ShapeSpec("long_500k", DECODE, 524288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    """long_500k only for sub-quadratic/long-context archs (DESIGN.md)."""
    if shape.name == "long_500k":
        return cfg.long_context
    return True


def cells(cfg_by_arch: Dict[str, ModelConfig]) -> List[Tuple[str, str]]:
    """All applicable (arch, shape) benchmark cells."""
    out = []
    for arch, cfg in cfg_by_arch.items():
        for s in SHAPES.values():
            if applicable(cfg, s):
                out.append((arch, s.name))
    return out


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract specs for the step function's ``batch`` argument."""
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    if shape.kind == TRAIN:
        if cfg.input_mode == "embeddings":
            out = {"embeds": _sds((B, S, d), cfg.dtype)}
            if cfg.n_codebooks > 1:
                out["targets"] = _sds((B, cfg.n_codebooks, S), "int32")
            else:
                out["targets"] = _sds((B, S), "int32")
            return out
        if cfg.prefix_len:
            t = S - cfg.prefix_len
            return {
                "tokens": _sds((B, t), "int32"),
                "prefix_embeds": _sds((B, cfg.prefix_len, d), cfg.dtype),
                "targets": _sds((B, t), "int32"),
            }
        return {"tokens": _sds((B, S), "int32"), "targets": _sds((B, S), "int32")}
    if shape.kind == PREFILL:
        if cfg.input_mode == "embeddings":
            return {"embeds": _sds((B, S, d), cfg.dtype)}
        if cfg.prefix_len:
            return {
                "tokens": _sds((B, S - cfg.prefix_len), "int32"),
                "prefix_embeds": _sds((B, cfg.prefix_len, d), cfg.dtype),
            }
        return {"tokens": _sds((B, S), "int32")}
    if shape.kind == DECODE:
        if cfg.input_mode == "embeddings":
            return {"embeds": _sds((B, 1, d), cfg.dtype)}
        return {"tokens": _sds((B, 1), "int32")}
    raise ValueError(shape.kind)


def decode_state_specs(cfg: ModelConfig, shape: ShapeSpec) -> Any:
    """Abstract decode-state tree for serve_step lowering."""
    assert shape.kind == DECODE
    return jax.eval_shape(
        lambda: T.init_decode_state(cfg, shape.global_batch, shape.seq_len)
    )


def concrete_batch(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0) -> Dict[str, Any]:
    """Materialized random batch (smoke-scale only)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    out = {}
    for k, s in batch_specs(cfg, shape).items():
        if s.dtype == jnp.int32:
            hi = cfg.vocab_size
            out[k] = jnp.asarray(rng.integers(0, hi, size=s.shape, dtype=np.int32))
        else:
            out[k] = jnp.asarray(rng.standard_normal(size=s.shape), dtype=s.dtype)
    return out
