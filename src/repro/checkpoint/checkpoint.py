"""Checkpoint/restart with elastic resharding (fault-tolerance substrate).

Layout: ``<root>/step_<n>/`` containing one ``.npy`` per leaf (path-encoded
filenames) plus a ``manifest.json`` with step, tree structure, per-leaf
digests and the writing mesh.  Writes are atomic (tmp dir + rename) and the
manifest is written LAST, so a crash mid-save can never produce a checkpoint
that ``latest_step`` would pick up.  Restore re-device_puts leaves under the
*current* mesh's shardings — the checkpoint is mesh-elastic by construction
(scale 256 -> 512 chips or down to 1 CPU without conversion).

Async saves run on a background thread (``save(..., block=False)``) — the
train loop keeps stepping while the previous step's host copy is serialized.
"""

from __future__ import annotations

import hashlib
import json
import shutil
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import ml_dtypes
import numpy as np

Pytree = Any

# numpy can't natively (de)serialize bf16/fp8 — store as uint16/uint8 views
# and record the logical dtype in the manifest.
_VIEW_DTYPES = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _flatten(tree: Pytree, prefix: str = "") -> Dict[str, Any]:
    if not isinstance(tree, dict):
        return {prefix or "_root": tree}
    out: Dict[str, Any] = {}
    for k, v in tree.items():
        out.update(_flatten(v, f"{prefix}/{k}" if prefix else k))
    return out


def _unflatten(flat: Dict[str, Any]) -> Pytree:
    if set(flat) == {"_root"}:
        return flat["_root"]
    out: Dict[str, Any] = {}
    for path, v in flat.items():
        node = out
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out


def _fname(path: str) -> str:
    return path.replace("/", "__") + ".npy"


class CheckpointManager:
    def __init__(self, root: str | Path, *, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Pytree, *, block: bool = True,
             extra: Optional[Dict[str, Any]] = None) -> None:
        # Host copy happens synchronously (values must be stable);
        # serialization can proceed in the background.
        flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        self.wait()
        if block:
            self._write(step, flat, extra or {})
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {}), daemon=True
            )
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: Dict[str, np.ndarray], extra: Dict[str, Any]) -> None:
        final = self.root / f"step_{step:08d}"
        tmp = Path(tempfile.mkdtemp(dir=self.root, prefix=".tmp_"))
        try:
            manifest = {"step": step, "time": time.time(), "leaves": {}, "extra": extra}
            for path, arr in flat.items():
                logical = str(arr.dtype)
                store_arr = (
                    arr.view(_VIEW_DTYPES[logical]) if logical in _VIEW_DTYPES else arr
                )
                np.save(tmp / _fname(path), store_arr, allow_pickle=False)
                manifest["leaves"][path] = {
                    "shape": list(arr.shape),
                    "dtype": logical,
                    "digest": hashlib.sha256(arr.tobytes()).hexdigest()[:16],
                }
            # Manifest last: its presence defines checkpoint validity.
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def steps(self) -> List[int]:
        out = []
        for d in self.root.glob("step_*"):
            if (d / "manifest.json").exists():
                try:
                    out.append(int(d.name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(
        self,
        step: Optional[int] = None,
        *,
        shardings: Optional[Pytree] = None,
        verify: bool = True,
    ) -> Pytree:
        """Load a checkpoint; reshard onto the current mesh if ``shardings``
        given (elastic restore — mesh may differ from the writer's)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        d = self.root / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat: Dict[str, Any] = {}
        shard_flat = _flatten(shardings) if shardings is not None else {}
        for path, meta in manifest["leaves"].items():
            arr = np.load(d / _fname(path), allow_pickle=False)
            if meta["dtype"] in _VIEW_DTYPES:
                arr = arr.view(ml_dtypes.bfloat16 if meta["dtype"] == "bfloat16"
                               else getattr(ml_dtypes, meta["dtype"]))
            if verify:
                got = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
                if got != meta["digest"]:
                    raise IOError(f"checkpoint leaf {path} corrupt ({got}!={meta['digest']})")
            if path in shard_flat and shard_flat[path] is not None:
                flat[path] = jax.device_put(arr, shard_flat[path])
            else:
                flat[path] = jax.numpy.asarray(arr)
        return _unflatten(flat)

    def manifest(self, step: int) -> Dict[str, Any]:
        return json.loads(
            (self.root / f"step_{step:08d}" / "manifest.json").read_text()
        )
