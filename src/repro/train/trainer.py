"""Training loop driver with checkpoint/restart fault tolerance.

Designed for the restart model of large fleets: the loop is a pure function
of (checkpoint, data seed, step index), so ANY interruption — preemption,
node failure, manual stop — resumes bit-identically from the last completed
checkpoint (the data pipeline is keyed by step, the optimizer carries its
count, parameter init is path-CRC keyed).

Straggler mitigation at this layer is *detection + telemetry*: per-step wall
times feed the exaCB store, and the time-series orchestrator flags sustained
step-time shifts (the paper's Fig. 4 workflow — on JUPITER that alert is how
slow nodes are drained).  Synchronous SPMD can't locally skip a straggler;
recovery is restart-from-checkpoint onto a healthy (possibly resized) mesh,
which ``CheckpointManager.restore(shardings=...)`` supports elastically.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import sharding as S
from repro.distributed import steps as ST
from repro.models import params as MP
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.train import optimizer as O


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    seed: int = 0
    remat: str = "dots"
    microbatches: int = 1
    data: DataConfig = dataclasses.field(default_factory=DataConfig)
    opt: O.OptConfig = dataclasses.field(default_factory=O.OptConfig)


@dataclasses.dataclass
class TrainResult:
    losses: List[float]
    step_times: List[float]
    final_step: int
    restored_from: Optional[int]

    @property
    def final_loss(self) -> float:
        return self.losses[-1] if self.losses else float("nan")


def train(
    cfg: ModelConfig,
    tc: TrainConfig,
    *,
    ckpt: Optional[CheckpointManager] = None,
    on_step: Optional[Callable[[int, Dict[str, float]], None]] = None,
    mesh=None,
    strategy: Optional[S.Strategy] = None,
) -> TrainResult:
    """Run (or resume) a training job on the local devices."""
    data = SyntheticLM(cfg, tc.data)
    step_fn = ST.make_train_step(
        cfg, tc.opt, remat=tc.remat, microbatches=tc.microbatches
    )
    if mesh is not None and strategy is not None:
        p_shard = S.param_shardings(cfg, mesh, strategy)
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        p_shard = None
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    # ---- restore-or-init (fault-tolerant restart point) ----
    restored_from = None
    start_step = 0
    params = None
    opt_state = None
    if ckpt is not None and ckpt.latest_step() is not None:
        restored_from = ckpt.latest_step()
        blob = ckpt.restore(restored_from, shardings=None)
        params, opt_state = blob["params"], blob["opt_state"]
        start_step = int(ckpt.manifest(restored_from)["extra"]["next_step"])
    if params is None:
        params = MP.init_params(cfg, jax.random.key(tc.seed))
        opt_state = O.init(params, tc.opt)

    losses: List[float] = []
    times: List[float] = []
    step = start_step
    try:
        for step in range(start_step, tc.steps):
            batch = data.batch(step)
            t0 = time.perf_counter()
            params, opt_state, metrics = jitted(
                params, opt_state, batch, jnp.asarray(tc.seed + step, jnp.int32)
            )
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            losses.append(loss)
            times.append(dt)
            if not np.isfinite(loss):
                raise FloatingPointError(f"loss diverged at step {step}: {loss}")
            if on_step:
                on_step(step, {"loss": loss, "step_time_s": dt,
                               "grad_norm": float(metrics["grad_norm"])})
            if ckpt is not None and (step + 1) % tc.ckpt_every == 0:
                ckpt.save(
                    step + 1,
                    {"params": params, "opt_state": opt_state},
                    block=False,
                    extra={"next_step": step + 1, "loss": loss},
                )
    finally:
        # Abnormal exits must not lose the in-flight async save — the restart
        # contract is "resume from the last *completed* checkpoint", and a
        # crash racing the writer thread would otherwise drop it.
        if ckpt is not None:
            ckpt.wait()
    if ckpt is not None:
        ckpt.save(
            tc.steps,
            {"params": params, "opt_state": opt_state},
            block=True,
            extra={"next_step": tc.steps, "loss": losses[-1] if losses else 0.0},
        )
    return TrainResult(losses, times, step, restored_from)


def detect_stragglers(step_times: List[float], *, factor: float = 1.5) -> List[int]:
    """Steps whose wall time exceeds factor x rolling median — the telemetry
    the exaCB time-series component consumes."""
    out = []
    for i in range(4, len(step_times)):
        med = float(np.median(step_times[max(0, i - 16) : i]))
        if step_times[i] > factor * med:
            out.append(i)
    return out
