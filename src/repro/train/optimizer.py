"""AdamW with distributed-training accommodations.

* **Compressed moment states** ("q8": int8 first moment + bf16 second
  moment = 3 bytes/param vs 8 for f32) — required for deepseek-v3-671b to
  fit 512×v5e (16 GB HBM/chip).  The first moment scales like gradients and
  quantizes linearly; the second moment spans ~7 decades, where linear int8
  collapses small entries to zero and m/(sqrt(0)+eps) explodes (measured in
  tests) — bf16's 8-bit exponent covers it, which is why v stays bf16.
* **Stochastic rounding** for bf16 parameter updates — replaces f32 master
  weights (another 4 bytes/param saved) while keeping the update unbiased.
* **ZeRO-1 moment sharding** comes from ``Strategy.opt_rules`` — this module
  only defines the state *structure*; layouts are assigned in
  ``distributed.steps``.

Pure-functional: ``init``/``apply`` over pytrees, no global state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

Q8_BLOCK = 256  # quantization block along the trailing axis


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # "cosine" | "constant" | "linear"
    state_dtype: str = "float32"      # "float32" | "q8"
    stochastic_rounding: bool = False


def learning_rate(cfg: OptConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        decay = jnp.maximum(
            0.0, 1.0 - (s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1)
        )
    else:  # cosine
        frac = jnp.clip(
            (s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
        )
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


# ---------------------------------------------------------------------------
# Block-wise 8-bit quantization
# ---------------------------------------------------------------------------

def _q8_shapes(shape: Tuple[int, ...]) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """(quantized shape, scale shape). Last axis split into Q8_BLOCK blocks."""
    if not shape:
        return shape, shape
    last = shape[-1]
    blocks = max(1, (last + Q8_BLOCK - 1) // Q8_BLOCK)
    return shape, shape[:-1] + (blocks,)


def q8_encode(x: jax.Array) -> Dict[str, jax.Array]:
    xf = x.astype(jnp.float32)
    if x.ndim == 0:
        scale = jnp.maximum(jnp.abs(xf), 1e-12) / 127.0
        return {"q": jnp.round(xf / scale).astype(jnp.int8), "scale": scale}
    last = x.shape[-1]
    blocks = max(1, (last + Q8_BLOCK - 1) // Q8_BLOCK)
    pad = blocks * Q8_BLOCK - last
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = xf.reshape(x.shape[:-1] + (blocks, Q8_BLOCK))
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1), 1e-12) / 127.0
    q = jnp.round(xb / scale[..., None]).astype(jnp.int8)
    q = q.reshape(x.shape[:-1] + (blocks * Q8_BLOCK,))[..., :last]
    return {"q": q, "scale": scale}


def q8_decode(enc: Dict[str, jax.Array], shape: Tuple[int, ...]) -> jax.Array:
    q, scale = enc["q"], enc["scale"]
    if not shape:
        return q.astype(jnp.float32) * scale
    last = shape[-1]
    blocks = scale.shape[-1]
    pad = blocks * Q8_BLOCK - last
    qf = q.astype(jnp.float32)
    if pad:
        qf = jnp.pad(qf, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    qb = qf.reshape(shape[:-1] + (blocks, Q8_BLOCK))
    x = qb * scale[..., None]
    return x.reshape(shape[:-1] + (blocks * Q8_BLOCK,))[..., :last]


def stochastic_round_bf16(x: jax.Array, key: jax.Array) -> jax.Array:
    """Unbiased f32 -> bf16 rounding via random low-bit injection."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    noise = jax.random.randint(key, x.shape, 0, 1 << 16, dtype=jnp.uint32)
    rounded = (bits + noise) & jnp.uint32(0xFFFF0000)
    return jax.lax.bitcast_convert_type(rounded, jnp.float32).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def _moment_like(p: jax.Array, cfg: OptConfig, kind: str) -> Pytree:
    if cfg.state_dtype == "q8":
        if kind == "m":
            _, sshape = _q8_shapes(p.shape)
            return {
                "q": jnp.zeros(p.shape, jnp.int8),
                "scale": jnp.full(sshape, 1e-12, jnp.float32),
            }
        return jnp.zeros(p.shape, jnp.bfloat16)  # v: needs exponent range
    return jnp.zeros(p.shape, jnp.float32)


def init(params: Pytree, cfg: OptConfig) -> Pytree:
    return {
        "m": jax.tree.map(lambda p: _moment_like(p, cfg, "m"), params),
        "v": jax.tree.map(lambda p: _moment_like(p, cfg, "v"), params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_state(abstract_params: Pytree, cfg: OptConfig) -> Pytree:
    """ShapeDtypeStruct state tree for dry-run lowering."""
    return jax.eval_shape(lambda p: init(p, cfg), abstract_params)


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def apply(
    grads: Pytree,
    params: Pytree,
    state: Pytree,
    cfg: OptConfig,
    rng: Optional[jax.Array] = None,
) -> Tuple[Pytree, Pytree, Dict[str, jax.Array]]:
    count = state["count"] + 1
    lr = learning_rate(cfg, count)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) if cfg.grad_clip else 1.0
    bc1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    flat_p = _flatten(params)
    flat_g = _flatten(grads)
    flat_m = _flatten(state["m"], stop_at_moment=cfg.state_dtype == "q8")
    flat_v = _flatten(state["v"], stop_at_moment=cfg.state_dtype == "q8")

    new_p, new_m, new_v = {}, {}, {}
    i = 0
    for k in flat_p:
        p, g = flat_p[k], flat_g[k]
        gf = g.astype(jnp.float32) * clip
        if cfg.state_dtype == "q8":
            m = q8_decode(flat_m[k], p.shape)
            v = flat_v[k].astype(jnp.float32)
        else:
            m, v = flat_m[k], flat_v[k]
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (upd + cfg.weight_decay * pf)
        if p.dtype == jnp.bfloat16 and cfg.stochastic_rounding and rng is not None:
            sub = jax.random.fold_in(rng, i)
            new_p[k] = stochastic_round_bf16(pf, sub)
        else:
            new_p[k] = pf.astype(p.dtype)
        new_m[k] = q8_encode(m) if cfg.state_dtype == "q8" else m
        new_v[k] = v.astype(jnp.bfloat16) if cfg.state_dtype == "q8" else v
        i += 1

    new_state = {
        "m": _unflatten(new_m),
        "v": _unflatten(new_v),
        "count": count,
    }
    metrics = {"lr": lr, "grad_norm": gnorm}
    return _unflatten(new_p), new_state, metrics


def _flatten(tree: Pytree, prefix: str = "", stop_at_moment: bool = False) -> Dict[str, Any]:
    """Flatten nested dicts; optionally treat {'q','scale'} dicts as leaves."""
    out: Dict[str, Any] = {}
    if isinstance(tree, dict) and not (
        stop_at_moment and set(tree.keys()) == {"q", "scale"}
    ):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else k, stop_at_moment))
        return out
    out[prefix] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Pytree:
    out: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out
