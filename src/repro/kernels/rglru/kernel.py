"""Pallas TPU kernel for the RG-LRU gated linear recurrence.

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is elementwise over the width dimension (VPU work, no MXU),
so the TPU-native win is purely memory locality: the running state h stays
in VMEM scratch across sequence chunks (innermost sequential grid axis), and
within a chunk the recurrence unrolls as a log-depth Blelloch-style
associative combine on registers instead of T sequential HBM round-trips.

Grid: (B, n_chunks, W/block_w).  Inputs are pre-gated: callers pass
a (decay, already exp()'d) and the gated input g = i_t * x_t * sqrt(1-a^2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _rglru_kernel(a_ref, g_ref, h0_ref, y_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)  # chunk axis is innermost: it carries the state

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)  # (1, bw) initial state

    a = a_ref[0].astype(jnp.float32)   # (c, bw)
    g = g_ref[0].astype(jnp.float32)   # (c, bw)

    # Blelloch scan over the chunk (log2(c) combine rounds, on registers).
    # Combine: (a1, b1) ∘ (a2, b2) = (a1*a2, b1*a2 + b2).
    av, bv = a, g
    shift = 1
    while shift < chunk:
        a_prev = jnp.pad(av, ((shift, 0), (0, 0)), constant_values=1.0)[:chunk]
        b_prev = jnp.pad(bv, ((shift, 0), (0, 0)), constant_values=0.0)[:chunk]
        av, bv = a_prev * av, b_prev * av + bv
        shift *= 2
    # h_t = prefix_a_t * h_in + prefix_b_t
    h_in = h_scr[...]
    y = av * h_in + bv
    h_scr[...] = y[-1:, :]
    y_ref[0] = y.astype(y_ref.dtype)


def rglru_pallas(
    a: jax.Array,    # (B, T, W) decay in (0,1)
    g: jax.Array,    # (B, T, W) gated input
    h0: jax.Array,   # (B, 1, W) initial state
    *,
    chunk: int = 256,
    block_w: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, T, W = a.shape
    chunk = min(chunk, T)
    block_w = min(block_w, W)
    assert T % chunk == 0 and W % block_w == 0, "ops.py must pad"
    nc = T // chunk
    nw = W // block_w
    kernel = functools.partial(_rglru_kernel, chunk=chunk)
    # Chunk axis must be INNERMOST: the scratch state is per-(b, w-block) and
    # is re-initialized when the chunk index wraps to 0.
    return pl.pallas_call(
        kernel,
        grid=(B, nw, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_w), lambda b, w, c: (b, c, w)),
            pl.BlockSpec((1, chunk, block_w), lambda b, w, c: (b, c, w)),
            pl.BlockSpec((1, 1, block_w), lambda b, w, c: (b, 0, w)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_w), lambda b, w, c: (b, c, w)),
        out_shape=jax.ShapeDtypeStruct((B, T, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, g, h0)
