"""Oracle for the RG-LRU kernel: associative scan over the sequence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(a: jax.Array, g: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t h_{t-1} + g_t with h_0 initial state.  Shapes (B, T, W)."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    af = a.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    pa, pb = jax.lax.associative_scan(combine, (af, gf), axis=1)
    return (pa * h0.astype(jnp.float32) + pb).astype(a.dtype)
