"""Public RG-LRU scan entry point."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.rglru.kernel import rglru_pallas


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _autotuned_blocks(a_shape, dtype) -> dict:
    """Promoted (chunk, block_w) from the autotune cache, when enabled."""
    import os

    if not os.environ.get("EXACB_AUTOTUNE_CACHE"):
        return {}
    from repro.core import autotune

    B, T, W = a_shape
    return autotune.cached_blocks("rglru", f"B{B}.T{T}.W{W}", str(dtype)) or {}


def rglru_scan(
    a: jax.Array,    # (B, T, W)
    g: jax.Array,    # (B, T, W)
    h0: Optional[jax.Array] = None,  # (B, W)
    *,
    chunk: Optional[int] = None,
    block_w: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    # Explicit arguments win, then the autotune cache, then 256/512.
    if chunk is None or block_w is None:
        tuned = _autotuned_blocks(a.shape, a.dtype)
        chunk = int(tuned.get("chunk", 256)) if chunk is None else chunk
        block_w = int(tuned.get("block_w", 512)) if block_w is None else block_w
    return _rglru_scan_jit(a, g, h0, chunk=chunk, block_w=block_w,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "interpret"))
def _rglru_scan_jit(
    a: jax.Array,
    g: jax.Array,
    h0: Optional[jax.Array] = None,
    *,
    chunk: int = 256,
    block_w: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    B, T, W = a.shape
    interpret = (not _on_tpu()) if interpret is None else interpret
    if h0 is None:
        h0 = jnp.zeros((B, W), a.dtype)
    c = min(chunk, T)
    bw = min(block_w, W)
    pad_t = (-T) % c
    pad_w = (-W) % bw
    if pad_t or pad_w:
        # pad decay with 1s (identity) and input with 0s
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_w)), constant_values=1.0)
        g = jnp.pad(g, ((0, 0), (0, pad_t), (0, pad_w)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_w)))
    out = rglru_pallas(a, g, h0[:, None, :], chunk=c, block_w=bw, interpret=interpret)
    return out[:, :T, :W]
