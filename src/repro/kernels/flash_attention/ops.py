"""Public entry point for flash attention: jit wrapper + layout handling.

Call ``flash_attention(q, k, v, ...)`` with model-layout tensors
(B, H, T, D).  On TPU the Pallas kernel runs natively; on CPU the kernel
body executes in interpret mode (tests) — production CPU/dry-run paths use
``repro.models.layers.banded_attention`` instead (see ``install()``).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,   # (B, Hq, T, D)
    k: jax.Array,   # (B, Hkv, T, D)
    v: jax.Array,   # (B, Hkv, T, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    B, Hq, T, D = q.shape
    Hkv = k.shape[1]
    Dv = v.shape[-1]
    interpret = (not _on_tpu()) if interpret is None else interpret
    bq = min(block_q, T)
    bk = min(block_k, T)
    pad = (-T) % max(bq, bk)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    out = flash_attention_pallas(
        q.reshape(B * Hq, Tp, D),
        k.reshape(B * Hkv, Tp, D),
        v.reshape(B * Hkv, Tp, Dv),
        n_q_heads=Hq,
        n_kv_heads=Hkv,
        causal=causal,
        window=window or 0,
        scale=scale,
        block_q=bq,
        block_k=bk,
        interpret=interpret,
    )
    return out.reshape(B, Hq, Tp, Dv)[:, :, :T]


def _impl_adapter(q, k, v, *, causal=True, window=None, prefix_len=0, scale=None, **_):
    if prefix_len:
        # Prefix-LM masks are not in the kernel's contract; jnp path handles.
        from repro.models.layers import banded_attention

        return banded_attention(
            q, k, v, causal=causal, window=window, prefix_len=prefix_len, scale=scale
        )
    return flash_attention(q, k, v, causal=causal, window=window, scale=scale)


def install() -> None:
    """Route model attention through the Pallas kernel (TPU deployments)."""
    from repro.models import layers as L

    L.set_attention_impl(_impl_adapter)


def uninstall() -> None:
    from repro.models import layers as L

    L.set_attention_impl(None)
