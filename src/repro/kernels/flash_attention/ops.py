"""Public entry point for flash attention: jit wrapper + layout handling.

Call ``flash_attention(q, k, v, ...)`` with model-layout tensors
(B, H, T, D).  On TPU the Pallas kernel runs natively; on CPU the kernel
body executes in interpret mode (tests) — production CPU/dry-run paths use
``repro.models.layers.banded_attention`` instead (see ``install()``).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _autotuned_blocks(q_shape, dtype) -> dict:
    """Promoted (block_q, block_k) for this shape on this hardware, if the
    autotune cache is enabled (``EXACB_AUTOTUNE_CACHE``) and holds a
    matching entry.  Import stays local: a bare kernel call must not pull
    the benchmarking core unless the cache is actually switched on."""
    import os

    if not os.environ.get("EXACB_AUTOTUNE_CACHE"):
        return {}
    from repro.core import autotune

    B, H, T, D = q_shape
    key = f"B{B}.H{H}.T{T}.D{D}"
    return autotune.cached_blocks("flash_attention", key, str(dtype)) or {}


def flash_attention(
    q: jax.Array,   # (B, Hq, T, D)
    k: jax.Array,   # (B, Hkv, T, D)
    v: jax.Array,   # (B, Hkv, T, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Block resolution happens *outside* the jit: explicit arguments win,
    then the autotune cache, then the shipped 512/512 defaults — so a
    promoted config changes behavior without any call-site edits."""
    if block_q is None or block_k is None:
        tuned = _autotuned_blocks(q.shape, q.dtype)
        block_q = int(tuned.get("block_q", 512)) if block_q is None else block_q
        block_k = int(tuned.get("block_k", 512)) if block_k is None else block_k
    return _flash_attention_jit(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def _flash_attention_jit(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    B, Hq, T, D = q.shape
    Hkv = k.shape[1]
    Dv = v.shape[-1]
    interpret = (not _on_tpu()) if interpret is None else interpret
    bq = min(block_q, T)
    bk = min(block_k, T)
    pad = (-T) % max(bq, bk)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    out = flash_attention_pallas(
        q.reshape(B * Hq, Tp, D),
        k.reshape(B * Hkv, Tp, D),
        v.reshape(B * Hkv, Tp, Dv),
        n_q_heads=Hq,
        n_kv_heads=Hkv,
        causal=causal,
        window=window or 0,
        scale=scale,
        block_q=bq,
        block_k=bk,
        interpret=interpret,
    )
    return out.reshape(B, Hq, Tp, Dv)[:, :, :T]


def _impl_adapter(q, k, v, *, causal=True, window=None, prefix_len=0, scale=None, **_):
    if prefix_len:
        # Prefix-LM masks are not in the kernel's contract; jnp path handles.
        from repro.models.layers import banded_attention

        return banded_attention(
            q, k, v, causal=causal, window=window, prefix_len=prefix_len, scale=scale
        )
    return flash_attention(q, k, v, causal=causal, window=window, scale=scale)


def install() -> None:
    """Route model attention through the Pallas kernel (TPU deployments)."""
    from repro.models import layers as L

    L.set_attention_impl(_impl_adapter)


def uninstall() -> None:
    from repro.models import layers as L

    L.set_attention_impl(None)
