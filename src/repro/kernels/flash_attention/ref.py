"""Pure-jnp oracle for the flash-attention kernel (materialized softmax)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,   # (B, Hq, T, D)
    k: jax.Array,   # (B, Hkv, T, D)
    v: jax.Array,   # (B, Hkv, T, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    B, Hq, T, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qr = q.reshape(B, Hkv, G, T, D).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qr, k.astype(jnp.float32)) * scale
    i = jnp.arange(T)[:, None]
    j = jnp.arange(T)[None, :]
    mask = jnp.ones((T, T), bool)
    if causal:
        mask = mask & (j <= i)
    if window is not None and window > 0:
        mask = mask & (j > i - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, T, -1).astype(q.dtype)
