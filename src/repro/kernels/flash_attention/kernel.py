"""Pallas TPU flash-attention kernel (causal / sliding-window / GQA).

TPU-native adaptation of the flash algorithm: q blocks are pinned to VMEM
across the innermost (sequential) kv-block grid dimension; the online-softmax
state (m, l, acc) lives in VMEM scratch; causal/window block skipping is a
``pl.when`` predicate on grid indices, so out-of-band blocks issue no MXU
work.  Block shapes default to 512×512 — q/k/v tiles of 512×128 bf16 plus
f32 scratch fit comfortably in the ~16 MB v5e VMEM while keeping the MXU's
128×128 systolic array fully fed.

Layout contract (``ops.py`` prepares it): q: (BH, T, D) with BH = B*Hq;
k/v: (BKV, T, D) with BKV = B*Hkv; the index map folds GQA head groups.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # VMEM blocks
    o_ref,
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    block_q: int,
    block_k: int,
    seq_len: int,
    causal: bool,
    window: int,          # 0 = global
    n_kv_blocks: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level band check (static per grid step via program ids).
    q_lo = qi * block_q
    q_hi = q_lo + block_q - 1
    k_lo = kj * block_k
    k_hi = k_lo + block_k - 1
    live = jnp.asarray(True)
    if causal:
        live = jnp.logical_and(live, k_lo <= q_hi)
    if window > 0:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)           # (bq, D)
        k = k_ref[0].astype(jnp.float32)           # (bk, D)
        v = v_ref[0]                               # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                   # (bq, bk)
        iq = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        jk = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jk < seq_len
        if causal:
            mask = jnp.logical_and(mask, jk <= iq)
        if window > 0:
            mask = jnp.logical_and(mask, jk > iq - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                        # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                     # (bq, bk)
        corr = jnp.exp(m_prev - m_new)             # (bq, 1)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,   # (BH, T, D)
    k: jax.Array,   # (BKV, T, D)
    v: jax.Array,   # (BKV, T, Dv)
    *,
    n_q_heads: int,
    n_kv_heads: int,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    BH, T, D = q.shape
    Dv = v.shape[-1]
    group = n_q_heads // n_kv_heads
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, T)
    block_k = min(block_k, T)
    assert T % block_q == 0 and T % block_k == 0, "ops.py must pad"
    nq = T // block_q
    nk = T // block_k

    def kv_index(bh, i, j):
        b = bh // n_q_heads
        h = bh % n_q_heads
        return b * n_kv_heads + h // group, j, 0

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        seq_len=T,
        causal=causal,
        window=window,
        n_kv_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, D), kv_index),
            pl.BlockSpec((1, block_k, Dv), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dv), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
