"""Oracle for the SSD kernel: the pure-jnp chunked scan from the model."""

from repro.models.layers import ssd_scan_ref  # noqa: F401
