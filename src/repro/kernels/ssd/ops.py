"""Public SSD entry point: model layout in, kernel layout out."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_pallas


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _autotuned_blocks(x_shape, n_state, dtype) -> dict:
    """Promoted chunk size from the autotune cache, when enabled."""
    import os

    if not os.environ.get("EXACB_AUTOTUNE_CACHE"):
        return {}
    from repro.core import autotune

    B, T, H, P = x_shape
    key = f"B{B}.T{T}.H{H}.P{P}.N{n_state}"
    return autotune.cached_blocks("ssd", key, str(dtype)) or {}


def ssd_scan(
    x: jax.Array,    # (B, T, H, P)
    dt: jax.Array,   # (B, T, H)  f32, post-softplus
    A: jax.Array,    # (H,)       f32, negative
    Bm: jax.Array,   # (B, T, G, N)
    Cm: jax.Array,   # (B, T, G, N)
    *,
    chunk: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    # Explicit argument wins, then the autotune cache, then 256.
    if chunk is None:
        tuned = _autotuned_blocks(x.shape, Bm.shape[3], x.dtype)
        chunk = int(tuned.get("chunk", 256))
    return _ssd_scan_jit(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_scan_jit(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    *,
    chunk: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    B, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    interpret = (not _on_tpu()) if interpret is None else interpret
    c = min(chunk, T)
    pad = (-T) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = T + pad
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, Tp, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, Tp).astype(jnp.float32)
    af = jnp.broadcast_to(A[None, :], (B, H)).reshape(B * H).astype(jnp.float32)
    Bh = jnp.repeat(Bm.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, Tp, N)
    Ch = jnp.repeat(Cm.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, Tp, N)
    y = ssd_pallas(xf, dtf, af, Bh, Ch, chunk=c, interpret=interpret)
    return y.reshape(B, H, Tp, P).transpose(0, 2, 1, 3)[:, :T]
