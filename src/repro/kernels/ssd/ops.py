"""Public SSD entry point: model layout in, kernel layout out."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_pallas


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,    # (B, T, H, P)
    dt: jax.Array,   # (B, T, H)  f32, post-softplus
    A: jax.Array,    # (H,)       f32, negative
    Bm: jax.Array,   # (B, T, G, N)
    Cm: jax.Array,   # (B, T, G, N)
    *,
    chunk: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    B, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    interpret = (not _on_tpu()) if interpret is None else interpret
    c = min(chunk, T)
    pad = (-T) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = T + pad
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, Tp, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, Tp).astype(jnp.float32)
    af = jnp.broadcast_to(A[None, :], (B, H)).reshape(B * H).astype(jnp.float32)
    Bh = jnp.repeat(Bm.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, Tp, N)
    Ch = jnp.repeat(Cm.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, Tp, N)
    y = ssd_pallas(xf, dtf, af, Bh, Ch, chunk=c, interpret=interpret)
    return y.reshape(B, H, Tp, P).transpose(0, 2, 1, 3)[:, :T]
