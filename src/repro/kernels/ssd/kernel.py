"""Pallas TPU kernel for the Mamba-2 SSD (state-space duality) scan.

TPU adaptation of the chunked SSD algorithm: the chunk dimension is the
innermost (sequential) grid axis; the running inter-chunk state S (N×P per
head) lives in VMEM scratch and never round-trips to HBM — the key win over
the XLA lowering, which materializes per-chunk states.  Each grid step does
three MXU contractions (CB^T score matrix, intra-chunk y, state update) on
a (chunk × head_dim) tile plus VPU work for the decay masks.

Grid: (B*H, n_chunks).  ``ops.py`` flattens heads and broadcasts groups.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _ssd_kernel(
    x_ref,    # (1, c, P)
    dt_ref,   # (1, c, 1)   f32 (post-softplus)
    a_ref,    # (1, 1, 1)   f32 (negative decay rate for this head)
    b_ref,    # (1, c, N)
    c_ref,    # (1, c, N)
    y_ref,    # (1, c, P)
    s_scr,    # VMEM (N, P) f32 — running inter-chunk state
    *,
    chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0].astype(jnp.float32)           # (c, P)
    dt = dt_ref[0].astype(jnp.float32)         # (c, 1)
    a = a_ref[0, 0, 0]                         # scalar < 0
    Bm = b_ref[0].astype(jnp.float32)          # (c, N)
    Cm = c_ref[0].astype(jnp.float32)          # (c, N)

    dA = dt[:, 0] * a                          # (c,) log-decay per step
    cum = jnp.cumsum(dA)                       # (c,)

    # Intra-chunk: scores[i,j] = C_i.B_j * exp(cum_i - cum_j) * dt_j, j<=i.
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # (c, c)
    li = cum[:, None]
    lj = cum[None, :]
    decay = jnp.exp(jnp.minimum(li - lj, 0.0))
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(jj <= ii, decay, 0.0)
    scores = scores * decay * dt[:, 0][None, :]
    y = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # (c, P)

    # Inter-chunk: y_i += C_i @ S_prev * exp(cum_i).
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, s_scr[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # State update: S = exp(cum_end) * S_prev + sum_j exp(cum_end-cum_j) dt_j B_j x_j^T.
    seg = jnp.exp(cum[-1] - cum) * dt[:, 0]    # (c,)
    s_new = jax.lax.dot_general(
        Bm * seg[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                          # (N, P)
    s_scr[...] = jnp.exp(cum[-1]) * s_scr[...] + s_new

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_pallas(
    x: jax.Array,    # (BH, T, P)
    dt: jax.Array,   # (BH, T)     f32, post-softplus
    a: jax.Array,    # (BH,)       f32, negative
    Bm: jax.Array,   # (BH, T, N)  group-broadcast
    Cm: jax.Array,   # (BH, T, N)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    BH, T, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0, "ops.py must pad"
    nc = T // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt[..., None], a[:, None, None], Bm, Cm)
