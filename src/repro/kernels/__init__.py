# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def tpu_compiler_params(**kwargs):
    """Construct pallas TPU compiler params across jax versions.

    jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
    accept whichever this installation provides.
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
