"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept across shapes and dtypes (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.rglru import ops as lru_ops
from repro.kernels.rglru import ref as lru_ref
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd import ref as ssd_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,T,D,window,bq,bk",
    [
        (2, 4, 2, 256, 32, None, 64, 64),    # GQA causal
        (1, 4, 4, 128, 16, None, 32, 64),    # MHA, uneven blocks
        (2, 4, 1, 256, 32, 50, 64, 64),      # MQA sliding window
        (1, 8, 2, 192, 64, None, 64, 64),    # non-pow2 T (padding path)
        (1, 2, 2, 64, 128, 17, 32, 32),      # tiny window
    ],
)
def test_flash_attention_vs_ref(B, Hq, Hkv, T, D, window, bq, bk, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, Hq, T, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), dtype)
    got = fa_ops.flash_attention(
        q, k, v, window=window, block_q=bq, block_k=bk, interpret=True
    )
    ref = fa_ref.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_flash_attention_mla_value_dim():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 4, 128, 48)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 4, 128, 48)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 4, 128, 32)), jnp.float32)
    got = fa_ops.flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = fa_ref.attention_ref(q, k, v)
    np.testing.assert_allclose(got, ref, rtol=3e-5, atol=3e-5)


def test_flash_attention_matches_model_banded():
    """Kernel and the model's banded jnp attention agree (shared contract)."""
    from repro.models.layers import banded_attention

    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((2, 4, 256, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 256, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 2, 256, 32)), jnp.float32)
    a = fa_ops.flash_attention(q, k, v, window=64, interpret=True, block_q=64, block_k=64)
    b = banded_attention(q, k, v, window=64, chunk_q=64, chunk_k=64)
    np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,T,H,P,G,N,chunk",
    [
        (2, 64, 4, 16, 2, 16, 16),
        (1, 96, 2, 32, 1, 8, 32),    # padding path (96 % 32 == 0, try 48)
        (1, 80, 4, 16, 4, 16, 32),   # T not multiple of chunk
    ],
)
def test_ssd_kernel_vs_ref(B, T, H, P, G, N, chunk, dtype):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((B, T, H, P)), dtype)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, T, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, T, G, N)), dtype)
    Cm = jnp.asarray(rng.standard_normal((B, T, G, N)), dtype)
    got = ssd_ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ref = ssd_ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,T,W,chunk,bw",
    [
        (2, 64, 32, 16, 32),
        (1, 100, 48, 32, 32),  # both dims padded
        (3, 32, 128, 32, 64),
    ],
)
def test_rglru_kernel_vs_ref(B, T, W, chunk, bw, dtype):
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.uniform(0.7, 0.999, (B, T, W)), dtype)
    g = jnp.asarray(rng.standard_normal((B, T, W)) * 0.1, dtype)
    h0 = jnp.asarray(rng.standard_normal((B, W)) * 0.1, dtype)
    got = lru_ops.rglru_scan(a, g, h0, chunk=chunk, block_w=bw, interpret=True)
    ref = lru_ref.rglru_ref(a, g, h0[:, None, :])
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_rglru_zero_init_matches_model_scan():
    """Kernel with h0=0 equals the model's associative scan formulation."""
    rng = np.random.default_rng(5)
    B, T, W = 2, 48, 64
    a = jnp.asarray(rng.uniform(0.8, 0.99, (B, T, W)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((B, T, W)) * 0.1, jnp.float32)
    got = lru_ops.rglru_scan(a, g, None, chunk=16, block_w=64, interpret=True)
    ref = lru_ref.rglru_ref(a, g, jnp.zeros((B, 1, W)))
    np.testing.assert_allclose(got, ref, rtol=3e-5, atol=3e-5)
