"""Tests for the typed component API: input schemas, the component registry
(migration shims), harness capability negotiation, and the Campaign facade."""

import json

import pytest

from repro.core.api import Campaign, main as repro_main
from repro.core.cicd import main as cicd_main, parse_pipeline_text, validate_pipeline
from repro.core.component import (
    REGISTRY,
    ComponentInputs,
    ComponentRegistry,
    ComponentSchema,
    InputSpec,
    PipelineError,
    resolve_parallelism,
)
from repro.core.harness import (
    BenchmarkSpec,
    CapabilityError,
    ExecHarness,
    Harness,
    HarnessCapabilities,
    Injections,
    negotiate,
)
from repro.core.orchestrator import (
    EXECUTION_SCHEMA,
    ExecutionOrchestrator,
    FeatureInjectionOrchestrator,
    register_components,
)
from repro.core.protocol import DataEntry, new_report
from repro.core.readiness import Readiness, parse_level
from repro.core.store import ResultStore


class StubHarness(Harness):
    """Minimal RUNNABLE-only harness with a capability ceiling and a call
    counter, so tests can assert fail-fast (negotiation rejected the cell
    BEFORE run was invoked)."""

    name = "stub"

    def __init__(self, max_readiness=Readiness.RUNNABLE,
                 step_kinds=frozenset()):
        self.calls = 0
        self.seen = []  # (cell, injections.describe()) per run
        self._caps = HarnessCapabilities(
            max_readiness=max_readiness, step_kinds=step_kinds,
            launcher_injection=False)

    def capabilities(self):
        return self._caps

    def run(self, spec, injections=None):
        self.calls += 1
        self.seen.append((spec.cell, injections.describe() if injections else None))
        r = new_report(system=spec.system, variant=spec.effective_variant(),
                       usecase=spec.shape, pipeline_id="p")
        r.data.append(DataEntry(success=True, runtime=0.1,
                                metrics={"step_time_s": 1.0}))
        return r


# ---------------------------------------------------------------------------
# Schema validation
# ---------------------------------------------------------------------------

def test_unknown_input_is_hard_error_naming_component():
    with pytest.raises(PipelineError) as ei:
        EXECUTION_SCHEMA.validate({"arch": "a0", "recrod": True})
    assert "execution@v4" in str(ei.value)
    assert "recrod" in str(ei.value) and "record" in str(ei.value)


def test_type_mismatch_names_component_and_field():
    with pytest.raises(PipelineError) as ei:
        EXECUTION_SCHEMA.validate({"arch": "a0", "parallelism": "two"})
    msg = str(ei.value)
    assert "execution@v4" in msg and "parallelism" in msg and "int" in msg


def test_bool_is_never_silently_an_int():
    # bool subclasses int in Python; the schema must still reject it where
    # an int is declared.
    with pytest.raises(PipelineError):
        EXECUTION_SCHEMA.validate({"arch": "a0", "seed": True})


def test_choices_enforced():
    with pytest.raises(PipelineError) as ei:
        EXECUTION_SCHEMA.validate({"arch": "a0", "require_readiness": "shiny"})
    assert "require_readiness" in str(ei.value)


def test_required_enforced_at_dispatch_but_not_construction():
    with pytest.raises(PipelineError) as ei:
        EXECUTION_SCHEMA.validate({})
    assert "arch" in str(ei.value)
    # Library path: the spec arrives as a method argument instead.
    inputs = EXECUTION_SCHEMA.validate({}, require=False)
    assert "arch" not in inputs and inputs["record"] is True


def test_deprecated_alias_warns_and_maps():
    with pytest.warns(DeprecationWarning, match="machine.*deprecated"):
        inputs = EXECUTION_SCHEMA.validate({"arch": "a0", "machine": "sysA"})
    assert inputs["system"] == "sysA" and "machine" not in inputs


def test_alias_plus_canonical_is_an_error():
    with pytest.raises(PipelineError, match="deprecated alias"):
        EXECUTION_SCHEMA.validate(
            {"arch": "a0", "machine": "sysA", "system": "sysB"})


def test_validated_inputs_are_immutable():
    inputs = EXECUTION_SCHEMA.validate({"arch": "a0"})
    assert isinstance(inputs, ComponentInputs)
    with pytest.raises(TypeError):
        inputs["arch"] = "other"


def test_wrap_scalar_and_element_coercion():
    sch = ComponentSchema("t", 1, (
        InputSpec("labels", list, element=str, wrap_scalar=True),))
    assert sch.validate({"labels": "one"})["labels"] == ["one"]
    with pytest.raises(PipelineError):
        sch.validate({"labels": [1]})


def test_open_namespace_passes_dotted_keys_only():
    sch = ComponentSchema("t", 1, (InputSpec("a", int, default=0),),
                          open_namespaces=("mad",))
    inputs = sch.validate({"a": 1, "mad.z_threshold": 6})
    assert inputs["mad.z_threshold"] == 6
    assert inputs.namespace("mad") == {"z_threshold": 6}
    with pytest.raises(PipelineError):
        sch.validate({"cusum.seed": 1})


def test_shared_parallelism_resolution():
    assert resolve_parallelism({}) == 1
    assert resolve_parallelism({"parallelism": 4}) == 4
    assert resolve_parallelism({"parallelism": 4}, override=2) == 2
    assert resolve_parallelism({"parallelism": -3}) == 1


# ---------------------------------------------------------------------------
# Registry + migration shims
# ---------------------------------------------------------------------------

def test_registry_rejects_unknown_name_and_major():
    with pytest.raises(PipelineError, match="unknown component"):
        REGISTRY.resolve("nonsense", 1)
    with pytest.raises(PipelineError, match="execution@v9 unsupported"):
        REGISTRY.resolve("execution", 9)


def test_every_legacy_component_resolves_with_a_schema():
    for name, major in [("execution", 3), ("feature-injection", 3),
                        ("time-series", 3), ("machine-comparison", 3),
                        ("scalability", 3), ("gate", 1),
                        ("campaign-report", 1)]:
        resolved = REGISTRY.resolve(name, major)
        assert resolved.schema.inputs, f"{name}@v{major} has no declared schema"
        assert resolved.runner is not None


def test_migration_shim_parity_v3_v4(recwarn):
    v3 = ("include:\n"
          "  - component: execution@v3\n"
          "    inputs:\n"
          "      prefix: \"p\"\n"
          "      arch: \"a0\"\n"
          "      usecase: \"train_4k\"\n"
          "      machine: \"sysA\"\n")
    v4 = (v3.replace("execution@v3", "execution@v4")
          .replace("usecase:", "shape:").replace("machine:", "system:"))
    c3, c4 = parse_pipeline_text(v3)[0], parse_pipeline_text(v4)[0]
    assert c3.version == 3 and c4.version == 4
    # Same document, same validated orchestrator config on both majors.
    assert dict(c3.inputs) == dict(c4.inputs)
    # The v3 path migrates silently — no deprecation warning for documents
    # written against the major where those names were canonical.
    assert not [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]


def test_registry_describe_lists_shims():
    entries = {e["component"]: e for e in REGISTRY.describe()}
    assert entries["execution@v3"]["migrates_to"] == "execution@v4"
    names = {s["name"] for s in entries["execution@v4"]["inputs"]}
    assert {"prefix", "arch", "shape", "system", "parallelism"} <= names


def test_duplicate_registration_rejected():
    reg = register_components(ComponentRegistry())
    with pytest.raises(ValueError, match="already registered"):
        reg.register(EXECUTION_SCHEMA)


# ---------------------------------------------------------------------------
# Harness capability negotiation
# ---------------------------------------------------------------------------

def test_parse_level():
    assert parse_level("reproducible") is Readiness.REPRODUCIBLE
    assert parse_level(None) is Readiness.FAILED
    assert parse_level(Readiness.RUNNABLE) is Readiness.RUNNABLE
    assert parse_level(2) is Readiness.INSTRUMENTED
    with pytest.raises(ValueError):
        parse_level("shiny")


def test_negotiation_fails_fast_before_execution(tmp_path):
    h = StubHarness(max_readiness=Readiness.RUNNABLE)
    ex = ExecutionOrchestrator(inputs={"prefix": "t"}, harness=h,
                               store=ResultStore(tmp_path))
    spec = BenchmarkSpec(arch="a0", shape="train_4k", system="s",
                         require_readiness=int(Readiness.REPRODUCIBLE))
    res = ex.run_cell(spec)
    assert res.readiness == Readiness.FAILED
    assert "CapabilityError" in res.error and "REPRODUCIBLE" in res.error
    assert h.calls == 0  # the harness never ran
    # Same cell without the requirement executes fine.
    ok = ex.run_cell(BenchmarkSpec(arch="a0", shape="train_4k", system="s"))
    assert ok.error is None and h.calls == 1


def test_negotiation_checks_step_kind_and_injections():
    h = StubHarness(step_kinds=frozenset({"train"}))
    with pytest.raises(CapabilityError, match="step kind"):
        negotiate(BenchmarkSpec(arch="a", shape="decode_32k", system="s"), h)
    with pytest.raises(CapabilityError, match="launcher"):
        negotiate(BenchmarkSpec(arch="a", shape="train_4k", system="s"), h,
                  Injections(launcher=lambda f: f))
    # Permissive default: the base Harness accepts everything.
    caps = negotiate(
        BenchmarkSpec(arch="a", shape="train_4k", system="s",
                      require_readiness=int(Readiness.REPRODUCIBLE)),
        Harness(), Injections(launcher=lambda f: f))
    assert caps.max_readiness is Readiness.REPRODUCIBLE


def test_exec_harness_declares_full_capabilities():
    caps = ExecHarness().capabilities()
    assert caps.max_readiness is Readiness.REPRODUCIBLE
    assert caps.step_kinds == {"train", "prefill", "decode"}
    assert caps.launcher_injection


def test_pipeline_rejects_reproducible_on_limited_harness(tmp_path):
    from repro.core.cicd import run_pipeline

    yml = ("include:\n"
           "  - component: execution@v4\n"
           "    inputs:\n"
           "      prefix: \"t\"\n"
           "      arch: \"a0\"\n"
           "      require_readiness: \"reproducible\"\n")
    h = StubHarness(max_readiness=Readiness.RUNNABLE)
    results = run_pipeline(parse_pipeline_text(yml),
                           store=ResultStore(tmp_path), harness=h)
    assert results[0]["readiness"] == 0
    assert "CapabilityError" in results[0]["error"]
    assert h.calls == 0


# ---------------------------------------------------------------------------
# CLI: cicd --validate and python -m repro
# ---------------------------------------------------------------------------

GOOD_YML = """\
include:
  - component: execution@v4
    inputs:
      prefix: "t.pipe"
      arch: "a0"
      shape: "train_4k"
      system: "sysA"
  - component: time-series@v4
    inputs:
      prefix: "evaluation.t"
      source_prefix: "t.pipe"
      data_labels: [step_time_s]
"""


def test_cicd_validate_flag(tmp_path, capsys):
    good = tmp_path / "good.yml"
    good.write_text(GOOD_YML)
    assert cicd_main([str(good), "--validate"]) == 0
    out = capsys.readouterr().out
    doc = json.loads(out)
    assert doc[0]["component"] == "execution@v4"
    assert doc[1]["depends_on"] == ["execution@v4"]
    bad = tmp_path / "bad.yml"
    bad.write_text(GOOD_YML.replace("prefix:", "prefxi:"))
    assert cicd_main([str(bad), "--validate"]) == 1
    assert "prefxi" in capsys.readouterr().err


def test_repro_cli_validate_and_components(tmp_path, capsys):
    good = tmp_path / "good.yml"
    good.write_text(GOOD_YML)
    assert repro_main(["validate", str(good)]) == 0
    capsys.readouterr()
    assert repro_main(["components"]) == 0
    listing = json.loads(capsys.readouterr().out)
    refs = {e["component"] for e in listing}
    assert refs >= {
        "execution@v3", "execution@v4",
        "feature-injection@v3", "feature-injection@v4",
        "time-series@v3", "time-series@v4",
        "machine-comparison@v3", "machine-comparison@v4",
        "scalability@v3", "scalability@v4",
        "gate@v1", "campaign-report@v1", "chaos@v1", "autotune@v1",
    }


def test_example_pipelines_validate():
    from pathlib import Path

    pipelines = sorted(Path("examples/pipelines").glob("*.yml"))
    assert pipelines, "no example pipelines found"
    for p in pipelines:
        summary = validate_pipeline(p.read_text())
        assert summary, p


# ---------------------------------------------------------------------------
# Campaign facade
# ---------------------------------------------------------------------------

def test_campaign_facade_run_report_gate(tmp_path):
    c = Campaign(tmp_path / "store", harness=StubHarness())
    results = c.run(GOOD_YML)
    assert [r["component"] for r in results] == ["execution", "time-series"]
    assert not results[0]["error"]
    rep = c.report()
    assert rep["component"] == "campaign-report" and "t.pipe" in rep["table"]
    verdict = c.gate("t.pipe", metrics=["step_time_s"])
    assert verdict["component"] == "gate" and verdict["status"] == "pass"
    with pytest.raises(PipelineError, match="tolerence"):
        c.gate("t.pipe", tolerence=0.1)


def test_campaign_validate_is_read_only(tmp_path):
    store_dir = tmp_path / "never_created"
    c = Campaign(store_dir)
    assert len(c.validate(GOOD_YML)) == 2
    assert len(c.components()) > 0
    assert not store_dir.exists()
    with pytest.raises(PipelineError, match="unknown input"):
        c.validate(GOOD_YML.replace("arch:", "arc:"))


def test_feature_injection_sweep_component(tmp_path):
    yml = ("include:\n"
           "  - component: feature-injection@v4\n"
           "    inputs:\n"
           "      prefix: \"s\"\n"
           "      arch: \"a0\"\n"
           "      in_command: \"export FIXED=1\"\n"
           "      env_knob: \"MY_KNOB\"\n"
           "      values: [\"a,b\", \"c\"]\n")
    calls = parse_pipeline_text(yml)
    # Quote-aware inline lists: the comma inside "a,b" is content.
    assert calls[0].inputs["values"] == ["a,b", "c"]
    h = StubHarness()
    c = Campaign(tmp_path / "store", harness=h)
    res = c.run(yml)
    assert res[0]["points"] == 2 and not res[0]["error"]
    # The declared fixed injection applies under EVERY sweep point, and
    # each point carries its own swept value.
    envs = [inj["env"] for _, inj in h.seen]
    assert envs == [{"FIXED": "1", "MY_KNOB": "a,b"},
                    {"FIXED": "1", "MY_KNOB": "c"}]
    # Sweep without a knob is a declaration error.
    with pytest.raises(PipelineError, match="env_knob"):
        c.component("feature-injection", 4,
                    {"prefix": "s", "arch": "a0", "values": [1]})


def test_direct_orchestrator_construction_still_validates(tmp_path):
    with pytest.raises(PipelineError, match="recrod"):
        ExecutionOrchestrator(inputs={"recrod": True}, harness=StubHarness(),
                              store=ResultStore(tmp_path))
    ex = ExecutionOrchestrator(inputs={"prefix": "t"}, harness=StubHarness(),
                               store=ResultStore(tmp_path))
    with pytest.raises(PipelineError, match="feature-injection@v4"):
        FeatureInjectionOrchestrator(execution=ex, inputs={"valeus": [1]})
