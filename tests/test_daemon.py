"""Tests for the continuous campaign daemon: lag-driven refresh (only the
stale slice re-executes, proven from the store manifest), downstream and
watermark triggers, crash-restart resume (state file and signature
recovery), graceful SIGTERM drain, and the status view."""

import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import pytest

from repro.core.component import PipelineError
from repro.core.daemon import (
    CampaignDaemon,
    SchedulePolicy,
    daemon_status,
    payload_signature,
    render_status,
    report_signature,
    _last_seq,
)
from repro.core.harness import BenchmarkSpec
from repro.core.orchestrator import ExecutionOrchestrator
from repro.core.store import ResultStore
from repro.core.synthetic import SpinHarness

REPO = Path(__file__).resolve().parent.parent


def _write_doc(path, body):
    path.write_text(body)
    return str(path)


def _two_prefix_doc(tmp_path, *, target_lag=30, triggers="[lag]", extra=""):
    """schedule + one execution cell in each of two prefixes — staleness can
    be proven per cell from each prefix's manifest independently."""
    return _write_doc(tmp_path / "doc.yml", f"""\
include:
  - component: schedule@v1
    inputs:
      target_lag: {target_lag}
      triggers: {triggers}
{extra}  - component: execution@v4
    inputs:
      prefix: "contA"
      arch: "archA"
      shape: "train_4k"
      system: "sysA"
  - component: execution@v4
    inputs:
      prefix: "contB"
      arch: "archB"
      shape: "train_4k"
      system: "sysA"
""")


def _daemon(store, doc, **kw):
    kw.setdefault("harness", SpinHarness(iters=50))
    kw.setdefault("workers", 1)
    return CampaignDaemon(store, [doc], **kw)


def _key_for(daemon, prefix):
    doc = daemon.documents[0]
    keys = [k for k, p in doc.cells.items() if p["prefix"] == prefix]
    assert len(keys) == 1
    return keys[0]


# ---------------------------------------------------------------------------
# lag trigger: exactly the stale slice, manifest-proven, across ticks
# ---------------------------------------------------------------------------

def test_lag_refreshes_exactly_the_stale_cells_across_ticks(tmp_path):
    store = ResultStore(tmp_path / "s")
    doc = _two_prefix_doc(tmp_path, target_lag=30)
    d = _daemon(store, doc)
    key_a, key_b = _key_for(d, "contA"), _key_for(d, "contB")

    # Tick 1: nothing has ever run — both cells refresh.
    s1 = d.tick(now=1000.0)["documents"][doc]
    assert s1["stale"] == {key_a: "never-run", key_b: "never-run"}
    assert sorted(s1["refreshed"]) == sorted([key_a, key_b])
    assert _last_seq(store, "contA") == 0 and _last_seq(store, "contB") == 0

    # Tick 2, inside the lag budget: nothing is stale, nothing re-executes —
    # the manifest is the proof (no new sequence in either prefix).
    s2 = d.tick(now=1010.0)["documents"][doc]
    assert s2["stale"] == {} and s2["refreshed"] == []
    assert sorted(s2["fresh"]) == sorted([key_a, key_b])
    assert _last_seq(store, "contA") == 0 and _last_seq(store, "contB") == 0

    # Age only cell A past target_lag (B was refreshed more recently).
    d.state["documents"][doc]["cells"][key_b]["last_refresh"] = 1020.0
    s3 = d.tick(now=1035.0)["documents"][doc]
    assert s3["stale"] == {key_a: "lag"}
    assert s3["refreshed"] == [key_a] and s3["fresh"] == [key_b]
    # Manifest + watermark proof: exactly one new entry, in A's prefix only.
    assert _last_seq(store, "contA") == 1
    assert _last_seq(store, "contB") == 0
    assert store.columnar.watermark("contA") == 1
    assert store.columnar.watermark("contB") == 0


def test_max_cells_per_tick_bounds_one_ticks_work(tmp_path):
    store = ResultStore(tmp_path / "s")
    doc = _write_doc(tmp_path / "doc.yml", """\
include:
  - component: schedule@v1
    inputs:
      target_lag: 30
      triggers: [lag]
      max_cells_per_tick: 1
  - component: execution@v4
    inputs:
      prefix: "cap"
      arch: "a1"
      shape: "train_4k"
      system: "sysA"
  - component: execution@v4
    inputs:
      prefix: "cap"
      arch: "a2"
      shape: "train_4k"
      system: "sysA"
  - component: execution@v4
    inputs:
      prefix: "cap"
      arch: "a3"
      shape: "train_4k"
      system: "sysA"
""")
    d = _daemon(store, doc)
    counts = []
    for i in range(4):
        s = d.tick(now=1000.0 + i)["documents"][doc]
        counts.append((len(s["stale"]), len(s["refreshed"])))
    # The backlog drains one cell per tick; un-refreshed cells stay stale.
    assert counts == [(3, 1), (2, 1), (1, 1), (0, 0)]
    assert _last_seq(store, "cap") == 2  # three cells, one entry each


# ---------------------------------------------------------------------------
# restart resume: state file, then signature recovery from the store
# ---------------------------------------------------------------------------

def test_restart_with_state_never_reruns_fresh_cells(tmp_path):
    store = ResultStore(tmp_path / "s")
    doc = _two_prefix_doc(tmp_path, target_lag=30)
    d1 = _daemon(store, doc)
    d1.tick(now=1000.0)
    assert _last_seq(store, "contA") == 0 and _last_seq(store, "contB") == 0

    # A new daemon instance (restart) resumes from daemon_state.json.
    d2 = _daemon(store, doc)
    assert d2.ticks == 1  # tick counter survived
    s = d2.tick(now=1010.0)["documents"][doc]
    assert s["stale"] == {} and s["refreshed"] == []
    assert _last_seq(store, "contA") == 0 and _last_seq(store, "contB") == 0
    # Once the budget expires, the restarted daemon picks up where it left.
    s = d2.tick(now=1100.0)["documents"][doc]
    assert set(s["stale"].values()) == {"lag"}
    assert _last_seq(store, "contA") == 1 and _last_seq(store, "contB") == 1


def test_restart_without_state_recovers_from_report_signatures(tmp_path):
    store = ResultStore(tmp_path / "s")
    doc = _two_prefix_doc(tmp_path, target_lag=60)
    d1 = _daemon(store, doc)
    d1.tick(now=1000.0)

    # Crash restart with the state file gone: the daemon matches stored
    # reports against each cell's signature instead of re-running them.
    # (SpinHarness pins experiment timestamps to 1000.0, so recovered
    # last-refresh times are deterministic here.)
    os.unlink(d1.state_path)
    d2 = _daemon(store, doc)
    s = d2.tick(now=1010.0)["documents"][doc]
    assert s["stale"] == {} and s["refreshed"] == []
    assert _last_seq(store, "contA") == 0 and _last_seq(store, "contB") == 0
    # The recovery was persisted: per-cell times are back in the state file.
    saved = json.loads(Path(d2.state_path).read_text())
    cells = saved["documents"][doc]["cells"]
    assert {c["last_refresh"] for c in cells.values()} == {1000.0}

    # And the recovered times still age out normally.
    s = d2.tick(now=1100.0)["documents"][doc]
    assert set(s["stale"].values()) == {"lag"}
    assert _last_seq(store, "contA") == 1


def test_payload_and_report_signatures_agree(tmp_path):
    """The recovery path's core invariant: the signature computed from a
    queue payload equals the one recomputed from the report that executing
    the payload persists."""
    from repro.core.workers import cell_payload

    store = ResultStore(tmp_path / "s")
    spec = BenchmarkSpec(arch="archX", shape="train_4k", system="sysA")
    ex = ExecutionOrchestrator(inputs={"prefix": "sig"},
                               harness=SpinHarness(iters=50), store=store)
    ex.run_collection([spec])
    payload = cell_payload(spec, {"prefix": "sig"})
    report = store.query("sig")[0]
    assert payload_signature(payload) == report_signature("sig", report)


# ---------------------------------------------------------------------------
# downstream + watermark triggers
# ---------------------------------------------------------------------------

def test_downstream_consumer_runs_only_when_inputs_advance(tmp_path):
    store = ResultStore(tmp_path / "s")
    doc = _write_doc(tmp_path / "doc.yml", """\
include:
  - component: schedule@v1
    inputs:
      target_lag: 30
      triggers: [lag, downstream]
  - component: execution@v4
    inputs:
      prefix: "cont"
      arch: "archA"
      shape: "train_4k"
      system: "sysA"
  - component: campaign-report@v1
    inputs:
      metric: "spin_result"
      prefixes: ["cont"]
""")
    d = _daemon(store, doc)
    consumer_key = d.documents[0].consumers[0][0]

    s1 = d.tick(now=1000.0)["documents"][doc]
    assert len(s1["refreshed"]) == 1
    assert s1["consumers_run"] == [consumer_key]  # inputs advanced from empty

    # Nothing stale, inputs unchanged: the analysis is NOT recomputed.
    s2 = d.tick(now=1010.0)["documents"][doc]
    assert s2["refreshed"] == [] and s2["consumers_run"] == []

    # Producer refresh advances the consumed prefix -> consumer re-runs.
    s3 = d.tick(now=1040.0)["documents"][doc]
    assert len(s3["refreshed"]) == 1
    assert s3["consumers_run"] == [consumer_key]
    cst = d.state["documents"][doc]["consumers"][consumer_key]
    assert cst["run_count"] == 2 and cst["cursors"] == {"cont": 1}


def test_watermark_trigger_fires_on_external_store_writes(tmp_path):
    store = ResultStore(tmp_path / "s")
    doc = _write_doc(tmp_path / "doc.yml", """\
include:
  - component: schedule@v1
    inputs:
      target_lag: 100000
      triggers: [watermark]
      watch: ["ext"]
  - component: execution@v4
    inputs:
      prefix: "cont"
      arch: "archA"
      shape: "train_4k"
      system: "sysA"
""")
    d = _daemon(store, doc)
    key = _key_for(d, "cont")
    s1 = d.tick(now=1000.0)["documents"][doc]
    assert s1["stale"] == {key: "never-run"}
    s2 = d.tick(now=1001.0)["documents"][doc]
    assert s2["stale"] == {}

    # Another writer (a CI job sharing the store) lands a report upstream.
    ex = ExecutionOrchestrator(inputs={"prefix": "ext"},
                               harness=SpinHarness(iters=50), store=store)
    ex.run_collection([BenchmarkSpec(arch="up", shape="train_4k",
                                     system="sysA")])
    s3 = d.tick(now=1002.0)["documents"][doc]
    assert s3["stale"] == {key: "watermark:ext"}
    assert s3["refreshed"] == [key]
    # Acted-on marks advance: the same upstream write never fires twice.
    s4 = d.tick(now=1003.0)["documents"][doc]
    assert s4["stale"] == {}


# ---------------------------------------------------------------------------
# schedule@v1 schema + one-shot no-op
# ---------------------------------------------------------------------------

def test_unknown_trigger_is_a_parse_time_error(tmp_path):
    store = ResultStore(tmp_path / "s")
    doc = _two_prefix_doc(tmp_path, triggers="[lag, hourly]")
    with pytest.raises(PipelineError, match="hourly"):
        _daemon(store, doc)


def test_daemon_override_beats_document_target_lag(tmp_path):
    store = ResultStore(tmp_path / "s")
    doc = _two_prefix_doc(tmp_path, target_lag=30)
    d = _daemon(store, doc, target_lag=1000.0)
    d.tick(now=1000.0)
    # 30s budget would mark both stale; the 1000s override keeps them fresh.
    s = d.tick(now=1100.0)["documents"][doc]
    assert s["stale"] == {}
    assert SchedulePolicy.from_calls(d.documents[0].calls).target_lag == 30.0


def test_schedule_component_is_a_noop_under_one_shot_run(tmp_path):
    from repro.core.api import Campaign

    doc = _two_prefix_doc(tmp_path, target_lag=30)
    c = Campaign(tmp_path / "s", harness=SpinHarness(iters=50))
    summaries = c.run(doc)
    sched = [s for s in summaries if s.get("component") == "schedule"]
    assert len(sched) == 1
    assert sched[0]["target_lag"] == 30.0 and "daemon" in sched[0]["note"]
    # The producers still executed normally around it.
    assert _last_seq(ResultStore(tmp_path / "s"), "contA") == 0


# ---------------------------------------------------------------------------
# status view
# ---------------------------------------------------------------------------

def test_daemon_status_reports_lag_and_due(tmp_path):
    store = ResultStore(tmp_path / "s")
    doc = _two_prefix_doc(tmp_path, target_lag=30)
    d = _daemon(store, doc)
    d.tick(now=1000.0)
    fresh = daemon_status(store, [doc], now=1010.0)
    cells = fresh["documents"][doc]["cells"]
    assert [c["due"] for c in cells] == [False, False]
    assert all(c["lag_s"] == pytest.approx(10.0) for c in cells)
    assert all(c["refresh_count"] == 1 for c in cells)
    stale = daemon_status(store, [doc], now=1100.0)
    assert all(c["due"] for c in stale["documents"][doc]["cells"])
    text = render_status(stale)
    assert "contA/archA" in text and "DUE" in text


# ---------------------------------------------------------------------------
# service loop: SIGTERM graceful drain (real process), CLI status
# ---------------------------------------------------------------------------

def _cli_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return env


def test_sigterm_drains_and_persists_resumable_state(tmp_path):
    doc = _write_doc(tmp_path / "doc.yml", """\
include:
  - component: schedule@v1
    inputs:
      target_lag: 3600
      triggers: [lag]
  - component: execution@v4
    inputs:
      prefix: "svc"
      arch: "starcoder2-3b"
      shape: "train_4k"
      system: "cpu-smoke"
""")
    store_root = tmp_path / "store"
    state = store_root / "daemon_state.json"
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro", "daemon", doc,
         "--store", str(store_root), "--interval", "0.3"],
        env=_cli_env(), cwd=str(REPO),
        stdout=subprocess.PIPE, stderr=subprocess.PIPE)
    try:
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            try:
                if json.loads(state.read_text()).get("ticks", 0) >= 1:
                    break
            except (OSError, ValueError):
                pass
            time.sleep(0.1)
        else:
            raise AssertionError("daemon never completed a tick")
        proc.send_signal(signal.SIGTERM)
        assert proc.wait(timeout=30) == 0  # graceful drain, not a crash
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)

    saved = json.loads(state.read_text())  # valid JSON, resumable
    assert saved["version"] == 1 and saved["ticks"] >= 1
    cells = saved["documents"][doc]["cells"]
    assert len(cells) == 1
    (cell,) = cells.values()
    assert cell["refresh_count"] >= 1 and cell["last_error"] is None
    # The work actually landed in the store exactly once per refresh.
    store = ResultStore(store_root)
    assert len(store.query("svc")) == cell["refresh_count"]

    # daemon-status reads the persisted state without a running daemon.
    out = subprocess.run(
        [sys.executable, "-m", "repro", "daemon-status", doc,
         "--store", str(store_root), "--json"],
        env=_cli_env(), cwd=str(REPO), capture_output=True, text=True,
        timeout=60)
    assert out.returncode == 0
    status = json.loads(out.stdout)
    assert status["ticks"] == saved["ticks"]
    assert status["queue_depth"] == 0
    (cell_status,) = status["documents"][doc]["cells"]
    assert cell_status["refresh_count"] == cell["refresh_count"]


# ---------------------------------------------------------------------------
# quarantine circuit-breaker
# ---------------------------------------------------------------------------

class _PoisonHarness(SpinHarness):
    """Raises on every cell — models a persistently failing benchmark."""

    def run(self, spec, injections=None):
        raise RuntimeError(f"poisoned cell {spec.cell}")


def _poison_doc(tmp_path, *, quarantine_after=2):
    return _write_doc(tmp_path / "doc.yml", f"""\
include:
  - component: schedule@v1
    inputs:
      target_lag: 30
      triggers: [lag]
      quarantine_after: {quarantine_after}
  - component: execution@v4
    inputs:
      prefix: "poison"
      arch: "archA"
      shape: "train_4k"
      system: "sysA"
""")


def test_consecutive_failures_quarantine_the_cell(tmp_path):
    store = ResultStore(tmp_path / "s")
    doc = _poison_doc(tmp_path, quarantine_after=2)
    d = _daemon(store, doc, harness=_PoisonHarness())
    key = _key_for(d, "poison")

    s1 = d.tick(now=1000.0)["documents"][doc]
    assert s1["refreshed"] == [key]
    cell_st = d.state["documents"][doc]["cells"][key]
    assert cell_st["fail_streak"] == 1 and "quarantined" not in cell_st

    s2 = d.tick(now=1040.0)["documents"][doc]  # aged past target_lag
    assert s2["refreshed"] == [key]
    cell_st = d.state["documents"][doc]["cells"][key]
    assert cell_st["fail_streak"] == 2
    assert "poisoned cell" in cell_st["quarantined"]["reason"]
    assert len(cell_st["history"]) == 2

    # Parked: the cell is never stale again, however far it ages.
    s3 = d.tick(now=9000.0)["documents"][doc]
    assert s3["stale"] == {} and s3["refreshed"] == []
    assert s3["quarantined"] == [key]
    assert key not in s3["fresh"]

    # Operator clears it -> eligible again on the very next tick.
    assert d.clear_quarantine() == [key]
    s4 = d.tick(now=9100.0)["documents"][doc]
    assert s4["refreshed"] == [key]
    # Still failing, streak restarts from the cleared baseline.
    assert d.state["documents"][doc]["cells"][key]["fail_streak"] == 1


def test_quarantine_zero_disables_the_breaker(tmp_path):
    store = ResultStore(tmp_path / "s")
    doc = _poison_doc(tmp_path, quarantine_after=0)
    d = _daemon(store, doc, harness=_PoisonHarness())
    key = _key_for(d, "poison")
    for i in range(5):
        d.tick(now=1000.0 + 40.0 * i)
    cell_st = d.state["documents"][doc]["cells"][key]
    assert cell_st["fail_streak"] == 5 and "quarantined" not in cell_st
    # History stays bounded even without quarantine.
    assert len(cell_st["history"]) <= 5


def test_success_resets_streak_and_lifts_nothing(tmp_path):
    store = ResultStore(tmp_path / "s")
    doc = _poison_doc(tmp_path, quarantine_after=3)
    d = _daemon(store, doc, harness=_PoisonHarness())
    key = _key_for(d, "poison")
    d.tick(now=1000.0)
    assert d.state["documents"][doc]["cells"][key]["fail_streak"] == 1
    # The cell recovers (harness fixed in place): streak resets to 0.
    d.harness = SpinHarness(iters=50)
    d.tick(now=1040.0)
    cell_st = d.state["documents"][doc]["cells"][key]
    assert cell_st["fail_streak"] == 0
    assert "quarantined" not in cell_st and "history" not in cell_st


def test_daemon_status_surfaces_quarantine_workers_and_retries(tmp_path):
    store = ResultStore(tmp_path / "s")
    doc = _poison_doc(tmp_path, quarantine_after=2)
    d = _daemon(store, doc, harness=_PoisonHarness())
    key = _key_for(d, "poison")
    d.tick(now=1000.0)
    d.tick(now=1040.0)

    status = daemon_status(store, [doc], now=2000.0)
    (cell,) = status["documents"][doc]["cells"]
    assert cell["quarantined"] and cell["due"] is False
    assert cell["fail_streak"] == 2 and len(cell["history"]) == 2
    assert status["documents"][doc]["quarantined"] == [key]
    # New top-level robustness sections are always present.
    assert "hosts" in status["workers"]
    assert isinstance(status["retry_counters"], dict)

    text = render_status(status)
    assert "QUARANTINED" in text and "poisoned cell" in text


def test_max_ticks_exits_cleanly_without_signals(tmp_path):
    store = ResultStore(tmp_path / "s")
    doc = _two_prefix_doc(tmp_path, target_lag=3600)
    d = _daemon(store, doc, interval=0.01, max_ticks=3)
    assert d.run() == 0
    assert d.ticks == 3
    saved = json.loads(Path(d.state_path).read_text())
    assert saved["ticks"] == 3
    # Tick 1 refreshed both never-run cells; ticks 2-3 re-ran nothing.
    assert _last_seq(store, "contA") == 0 and _last_seq(store, "contB") == 0
