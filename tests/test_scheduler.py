"""Tests for the campaign scheduler: parallel collections match serial ones,
failures stay isolated under concurrency, and the component DAG orders
post-processing after the executions it consumes."""

import json
import os
import threading
import time

import pytest

from repro.core import accounting
from repro.core.cicd import component_dag, parse_pipeline_text, run_pipeline
from repro.core.harness import BenchmarkSpec, Harness, Injections, injected_env
from repro.core.orchestrator import ExecutionOrchestrator, FeatureInjectionOrchestrator
from repro.core.readiness import Readiness
from repro.core.registry import campaign, collection
from repro.core.scheduler import CampaignScheduler, SchedulerError, Task
from repro.core.store import ResultStore
from repro.core.protocol import DataEntry, new_report

INSTR = {
    "hlo_flops": 1.0, "hlo_bytes": 1.0, "collective_bytes": 0.0,
    "t_compute": 1.0, "t_memory": 1.0, "t_collective": 0.0,
}


class StubHarness(Harness):
    """Deterministic per-cell reports; optional failures and wall-time."""

    name = "stub"

    def __init__(self, fail_cells=(), delay_s=0.0):
        self.fail_cells = set(fail_cells)
        self.delay_s = delay_s
        self.max_live = 0
        self._live = 0
        self._lock = threading.Lock()

    def run(self, spec, injections=None):
        with self._lock:
            self._live += 1
            self.max_live = max(self.max_live, self._live)
        try:
            if self.delay_s:
                time.sleep(self.delay_s)
            if spec.cell in self.fail_cells:
                raise RuntimeError("infrastructure failure")
            r = new_report(system=spec.system, variant=spec.effective_variant(),
                           usecase=spec.shape, pipeline_id="p1")
            # Deterministic timestamps so serial/parallel reports are
            # byte-comparable.
            r.experiment.timestamp = 1000.0
            r.reporter.timestamp = 1000.0
            m = dict(INSTR)
            m["step_time_s"] = float(len(spec.arch))  # cell-determined value
            m["artifact_digest"] = f"d-{spec.cell}"
            m["seed"] = spec.seed
            r.data.append(DataEntry(success=True, runtime=0.1, metrics=m))
            return r
        finally:
            with self._lock:
                self._live -= 1


def _specs(n):
    return [BenchmarkSpec(arch=f"arch{i}", shape="train_4k", system="sysA")
            for i in range(n)]


# ---------------------------------------------------------------------------
# scheduler core
# ---------------------------------------------------------------------------

def test_dag_ordering_and_isolation():
    order = []
    lock = threading.Lock()

    def mark(key, fail=False):
        with lock:
            order.append(key)
        if fail:
            raise RuntimeError("boom")

    tasks = [
        Task("a", lambda: mark("a")),
        Task("b", lambda: mark("b", fail=True)),
        Task("c", lambda: mark("c"), deps=frozenset({"a", "b"})),
        Task("d", lambda: mark("d")),
    ]
    done = CampaignScheduler(parallelism=4).run_tasks(tasks)
    # c ran after BOTH deps — even though b failed (isolation, not deadlock).
    assert order.index("c") > order.index("a")
    assert order.index("c") > order.index("b")
    assert done["b"].error and "boom" in done["b"].error
    assert done["a"].ok and done["c"].ok and done["d"].ok


def test_scheduler_rejects_structural_errors():
    with pytest.raises(SchedulerError):
        CampaignScheduler().run_tasks([Task("a", lambda: 1, deps=frozenset({"zz"}))])
    with pytest.raises(SchedulerError):
        CampaignScheduler().run_tasks([Task("a", lambda: 1), Task("a", lambda: 2)])
    with pytest.raises(SchedulerError):
        CampaignScheduler().run_tasks([
            Task("a", lambda: 1, deps=frozenset({"b"})),
            Task("b", lambda: 2, deps=frozenset({"a"})),
        ])


def test_cycle_detected_before_any_task_runs():
    """The Kahn pre-pass fires before the pool exists: a cyclic DAG must
    not execute even its acyclic members."""
    ran = []
    tasks = [
        Task("free", lambda: ran.append("free")),  # not on the cycle
        Task("a", lambda: ran.append("a"), deps=frozenset({"b"})),
        Task("b", lambda: ran.append("b"), deps=frozenset({"a"})),
    ]
    with pytest.raises(SchedulerError, match="cycle"):
        CampaignScheduler(parallelism=4).run_tasks(tasks)
    assert ran == []  # zero task bodies executed


def test_map_items_threads_meta():
    seen = []
    CampaignScheduler(parallelism=2).map_items(
        lambda x: x * 2, [1, 2, 3], metas=["one", "two", "three"],
        on_result=lambda tr: seen.append((tr.meta, tr.value)))
    assert sorted(seen) == [("one", 2), ("three", 6), ("two", 4)]
    with pytest.raises(SchedulerError, match="metas length"):
        CampaignScheduler().map_items(lambda x: x, [1, 2], metas=["only-one"])


def test_scheduler_streams_results():
    seen = []
    CampaignScheduler(parallelism=2).map_items(lambda x: x * 2, [1, 2, 3],
                                               on_result=lambda tr: seen.append(tr.value))
    assert sorted(seen) == [2, 4, 6]


# ---------------------------------------------------------------------------
# parallel collections
# ---------------------------------------------------------------------------

def test_parallel_collection_matches_serial(tmp_path):
    specs = _specs(8)
    serial_store = ResultStore(tmp_path / "serial")
    parallel_store = ResultStore(tmp_path / "parallel")
    ex_s = ExecutionOrchestrator(inputs={"prefix": "c"}, harness=StubHarness(),
                                 store=serial_store)
    ex_p = ExecutionOrchestrator(inputs={"prefix": "c", "parallelism": 4},
                                 harness=StubHarness(), store=parallel_store)
    rs = ex_s.run_collection(specs)
    rp = ex_p.run_collection(specs)
    # Report-for-report: same cells, same readiness, same digests & metrics
    # (modulo the per-run resource accounting, which legitimately varies).
    assert [r.spec.cell for r in rs] == [r.spec.cell for r in rp]
    assert [r.readiness for r in rs] == [r.readiness for r in rp]
    for a, b in zip(rs, rp):
        assert (accounting.strip_volatile(a.report.to_dict())
                == accounting.strip_volatile(b.report.to_dict()))
    # Persisted stores agree too (order-insensitive: workers race to append).
    def canon(store):
        return sorted(json.dumps(accounting.strip_volatile(r.to_dict()),
                                 sort_keys=True)
                      for r in store.query("c"))
    assert canon(serial_store) == canon(parallel_store)


def test_parallel_collection_actually_overlaps(tmp_path):
    h = StubHarness(delay_s=0.05)
    ex = ExecutionOrchestrator(inputs={"prefix": "c"}, harness=h,
                               store=ResultStore(tmp_path))
    ex.run_collection(_specs(8), parallelism=4)
    assert h.max_live >= 2  # cells genuinely ran concurrently


def test_parallel_crash_does_not_lose_siblings(tmp_path):
    store = ResultStore(tmp_path)
    h = StubHarness(fail_cells={"arch3.train_4k.sysA"})
    ex = ExecutionOrchestrator(inputs={"prefix": "c"}, harness=h, store=store)
    results = ex.run_collection(_specs(8), parallelism=4)
    failed = [r for r in results if r.readiness == Readiness.FAILED]
    assert len(failed) == 1 and "infrastructure failure" in failed[0].error
    assert len(store.query("c")) == 7  # all siblings persisted


def test_parallel_sweep(tmp_path):
    store = ResultStore(tmp_path)
    ex = ExecutionOrchestrator(inputs={"prefix": "s"}, harness=StubHarness(),
                               store=store)
    fi = FeatureInjectionOrchestrator(execution=ex, inputs={"prefix": "s"})
    results = fi.sweep(_specs(1)[0], env_knob="EXACB_KNOB",
                       values=[1, 2, 4, 8], parallelism=4)
    assert all(r.readiness == Readiness.REPRODUCIBLE for r in results)
    knobs = sorted(r.report.parameter["injections"]["env"]["EXACB_KNOB"]
                   for r in results)
    assert knobs == ["1", "2", "4", "8"]
    assert len(store.query("s")) == 4


# ---------------------------------------------------------------------------
# thread-safe env injection
# ---------------------------------------------------------------------------

def test_injected_env_concurrent_distinct_keys():
    errors = []
    barrier = threading.Barrier(4)

    def worker(i):
        key = f"EXACB_TEST_K{i}"
        try:
            with injected_env({key: str(i)}):
                barrier.wait(timeout=5)  # all frames active at once
                if os.environ.get(key) != str(i):
                    errors.append(f"{key} lost its value")
                time.sleep(0.01)
        except Exception as e:  # noqa: BLE001
            errors.append(repr(e))

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    for i in range(4):
        assert f"EXACB_TEST_K{i}" not in os.environ  # all restored


def test_parallel_env_sweep_each_cell_sees_its_own_value(tmp_path):
    """Same-key env sweeps under the pool: per-key serialization means each
    cell executes under the value it was assigned, not the last entrant's."""

    class EnvEchoHarness(Harness):
        name = "env-echo"

        def run(self, spec, injections=None):
            with injected_env(injections.env if injections else {}):
                seen = os.environ.get("EXACB_SWEEP_KNOB", "")
                time.sleep(0.01)  # widen the overlap window
                still = os.environ.get("EXACB_SWEEP_KNOB", "")
            assert seen == still, "env changed underneath a running cell"
            r = new_report(system=spec.system, variant=spec.effective_variant(),
                           usecase=spec.shape, pipeline_id="p1")
            r.data.append(DataEntry(success=True, runtime=0.1,
                                    metrics={"seen": float(seen)}))
            return r

    ex = ExecutionOrchestrator(inputs={"prefix": "env"}, harness=EnvEchoHarness(),
                               store=ResultStore(tmp_path))
    fi = FeatureInjectionOrchestrator(execution=ex, inputs={})
    results = fi.sweep(_specs(1)[0], env_knob="EXACB_SWEEP_KNOB",
                       values=[1, 2, 3, 4], parallelism=4)
    seen = [r.report.data[0].metrics["seen"] for r in results]
    assert seen == [1.0, 2.0, 3.0, 4.0]  # intended == executed, per point


def test_injected_env_same_key_restores_original():
    os.environ["EXACB_TEST_SAME"] = "orig"
    try:
        with injected_env({"EXACB_TEST_SAME": "a"}):
            with injected_env({"EXACB_TEST_SAME": "b"}):
                assert os.environ["EXACB_TEST_SAME"] == "b"
            assert os.environ["EXACB_TEST_SAME"] == "a"
        assert os.environ["EXACB_TEST_SAME"] == "orig"
    finally:
        os.environ.pop("EXACB_TEST_SAME", None)


# ---------------------------------------------------------------------------
# pipeline DAG
# ---------------------------------------------------------------------------

PIPE = """\
include:
  - component: execution@v3
    inputs:
      prefix: "dag.a"
      arch: "arch0"
      usecase: "train_4k"
      machine: "sysA"
      parallelism: 4
  - component: execution@v3
    inputs:
      prefix: "dag.a"
      arch: "arch1"
      usecase: "train_4k"
      machine: "sysA"
  - component: execution@v3
    inputs:
      prefix: "dag.b"
      arch: "arch2"
      usecase: "train_4k"
      machine: "sysA"
  - component: time-series@v3
    inputs:
      prefix: "evaluation.dag"
      source_prefix: "dag.a"
      data_labels: [step_time_s]
"""


def test_component_dag_edges():
    calls = parse_pipeline_text(PIPE)
    deps = component_dag(calls)
    # Executions are independent; time-series waits on the two dag.a
    # producers but NOT the unrelated dag.b one.
    assert deps[0] == [] and deps[1] == [] and deps[2] == []
    assert deps[3] == [0, 1]


def test_pipeline_dag_post_processing_sees_all_upstream(tmp_path):
    store = ResultStore(tmp_path)
    results = run_pipeline(parse_pipeline_text(PIPE), store=store,
                           harness=StubHarness())
    assert [r["component"] for r in results] == [
        "execution", "execution", "execution", "time-series"]
    assert all(not r.get("error") for r in results)
    # DAG ordering: the analysis saw BOTH dag.a execution reports even
    # though all executions were dispatched concurrently (parallelism 4).
    assert results[3]["points"]["step_time_s"] == 2


def test_pipeline_component_failure_is_isolated(tmp_path):
    store = ResultStore(tmp_path)
    h = StubHarness(fail_cells={"arch1.train_4k.sysA"})
    results = run_pipeline(parse_pipeline_text(PIPE), store=store, harness=h)
    assert results[1]["error"] and "infrastructure failure" in results[1]["error"]
    # Downstream analysis still ran over the surviving report.
    assert results[3]["points"]["step_time_s"] == 1


# ---------------------------------------------------------------------------
# multi-system campaign expansion
# ---------------------------------------------------------------------------

def test_campaign_expansion():
    single = collection("jedi", archs=["glm4-9b"])
    multi = campaign(["jedi", "jureca"], archs=["glm4-9b"])
    assert len(multi) == 2 * len(single)
    assert {s.system for s in multi} == {"jedi", "jureca"}
    # collection() accepts the multi-system forms directly.
    assert collection(["jedi", "jureca"], archs=["glm4-9b"]) == multi
    assert collection("jedi,jureca", archs=["glm4-9b"]) == multi
