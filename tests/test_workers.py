"""Tests for the distributed execution plane: the lease-reclaimed work
queue protocol, the CampaignBroker + spawned worker pool, crash recovery
(SIGKILL mid-cell → reclaim → retry, exactly once), resource accounting,
and env-injection survival across the spawn boundary."""

import json
import os
import signal
import threading
import time
from pathlib import Path

import multiprocessing as mp

import pytest

from repro.core import accounting
from repro.core.component import PipelineError
from repro.core.harness import BenchmarkSpec, Harness, Injections
from repro.core.orchestrator import ExecutionOrchestrator
from repro.core.readiness import Readiness
from repro.core.store import ResultStore
from repro.core.synthetic import SPIN_ENV_KNOB, BlockingHarness, SpinHarness
from repro.core.workers import (
    CampaignBroker,
    WorkerConfig,
    cell_payload,
    resolve_harness,
    run_collection_process,
    spawn_spec_for,
    worker_main,
)
from repro.core.workqueue import WorkQueue

SPAWN = mp.get_context("spawn")


def _specs(n):
    return [BenchmarkSpec(arch=f"arch{i}", shape="train_4k", system="sysA")
            for i in range(n)]


def _payloads(n, prefix="q"):
    return [cell_payload(s, {"prefix": prefix}, cell_index=i)
            for i, s in enumerate(_specs(n))]


def _canon(store, prefix):
    return sorted(json.dumps(accounting.strip_volatile(r.to_dict()),
                             sort_keys=True)
                  for r in store.query(prefix))


# ---------------------------------------------------------------------------
# work queue protocol
# ---------------------------------------------------------------------------

def test_queue_claim_complete_cycle(tmp_path):
    q = WorkQueue(tmp_path / "q").create(_payloads(2), campaign="c")
    assert q.n_tasks == 2
    idx, payload, attempt = q.claim_next("w1")
    assert (idx, attempt) == (0, 1)
    assert payload["task_uid"] == "c:0"
    # Lowest unleased cell next — the claimed one is skipped.
    idx2, _, _ = q.claim_next("w2")
    assert idx2 == 1
    assert q.claim_next("w3") is None  # everything leased
    assert q.heartbeat(0)
    assert q.complete(0, {"readiness": 3})
    assert not q.finished()
    assert q.complete(1, {"readiness": 3})
    assert q.finished()
    assert q.results()[0] == {"readiness": 3}


def test_queue_done_marker_first_writer_wins(tmp_path):
    q = WorkQueue(tmp_path / "q").create(_payloads(1))
    q.claim_next("w1")
    assert q.complete(0, {"winner": "w1"})
    # A slow-but-alive worker whose cell was reclaimed loses the race and
    # its result is discarded.
    assert not q.complete(0, {"winner": "w2"})
    assert q.results()[0] == {"winner": "w1"}


def test_queue_claim_race_single_winner(tmp_path):
    q = WorkQueue(tmp_path / "q").create(_payloads(1))
    wins, barrier = [], threading.Barrier(8)

    def racer(i):
        barrier.wait(timeout=5)
        got = q.claim_next(f"w{i}")
        if got is not None:
            wins.append(i)

    threads = [threading.Thread(target=racer, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(wins) == 1  # O_EXCL lease create has exactly one winner


def test_queue_reclaim_expired_lease(tmp_path):
    q = WorkQueue(tmp_path / "q", lease_timeout=0.05).create(_payloads(1))
    q.claim_next("dead-worker")
    time.sleep(0.15)
    assert q.reclaim_expired() == [0]
    journal = q.reclaim_journal()
    assert len(journal) == 1 and journal[0]["worker"] == "dead-worker"
    # The reclaimed cell is claimable again, with the attempt counter bumped.
    idx, _, attempt = q.claim_next("w2")
    assert (idx, attempt) == (0, 2)


def test_queue_heartbeat_keeps_lease_alive(tmp_path):
    q = WorkQueue(tmp_path / "q", lease_timeout=0.2).create(_payloads(1))
    q.claim_next("slow-but-alive")
    deadline = time.monotonic() + 0.5
    while time.monotonic() < deadline:
        q.heartbeat(0)
        time.sleep(0.03)
    assert q.reclaim_expired() == []  # never mistaken for dead


def test_queue_bounded_attempts_terminal_failure(tmp_path):
    q = WorkQueue(tmp_path / "q", lease_timeout=0.05).create(_payloads(1))
    for attempt in (1, 2):
        idx, _, got = q.claim_next("crashy")
        assert (idx, got) == (0, attempt)
        time.sleep(0.15)
        q.reclaim_expired(max_attempts=2)
    # Second reclaim exhausted the budget: terminal failure marker, and the
    # queue is finished — a poisoned cell cannot wedge the campaign.
    assert q.finished()
    result = q.results()[0]
    assert result["readiness"] == 0 and result["reclaimed"]
    assert "2 failed attempts" in result["error"]
    assert q.claim_next("w9") is None


def test_queue_stop_flag(tmp_path):
    q = WorkQueue(tmp_path / "q").create(_payloads(1))
    assert not q.stop_requested()
    q.request_stop()
    assert q.stop_requested()


# ---------------------------------------------------------------------------
# spawn-safety plumbing
# ---------------------------------------------------------------------------

def test_spawn_spec_round_trip():
    ref, kwargs = spawn_spec_for(SpinHarness(iters=77))
    rebuilt = resolve_harness(ref, kwargs)
    assert isinstance(rebuilt, SpinHarness) and rebuilt.iters == 77


def test_unspawnable_harness_is_hard_error():
    class ClosureHarness(Harness):
        name = "closure"

        def run(self, spec, injections=None):  # pragma: no cover
            raise AssertionError

    with pytest.raises(PipelineError, match="spawn_spec"):
        spawn_spec_for(ClosureHarness())
    with pytest.raises(PipelineError, match="harness ref"):
        resolve_harness("not-a-module-path", {})


def test_launcher_injection_rejected_in_payload():
    with pytest.raises(PipelineError, match="launcher"):
        cell_payload(_specs(1)[0], {"prefix": "p"},
                     injections=Injections(launcher=lambda cmd: cmd))


def test_worker_config_round_trip():
    cfg = WorkerConfig(store_root="/s", harness_ref="m:f",
                       harness_kwargs={"iters": 3}, env={"K": "1"},
                       lease_timeout=2.0)
    back = WorkerConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert back == cfg
    assert back.heartbeat_s() == pytest.approx(0.5)  # lease / 4


# ---------------------------------------------------------------------------
# process collection end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["dir", "jsonl"])
def test_process_collection_matches_thread_collection(tmp_path, backend):
    specs = _specs(4)
    t_store = ResultStore(tmp_path / "thread", backend=backend)
    p_store = ResultStore(tmp_path / "proc", backend=backend)
    ex_t = ExecutionOrchestrator(inputs={"prefix": "c", "parallelism": 2},
                                 harness=SpinHarness(iters=2000), store=t_store)
    rt = ex_t.run_collection(specs)
    ex_p = ExecutionOrchestrator(
        inputs={"prefix": "c", "workers": 2, "worker_mode": "process"},
        harness=SpinHarness(iters=2000), store=p_store)
    rp = ex_p.run_collection(specs)
    assert [r.readiness for r in rt] == [Readiness.REPRODUCIBLE] * 4
    assert [r.readiness for r in rp] == [Readiness.REPRODUCIBLE] * 4
    assert [r.spec.cell for r in rt] == [r.spec.cell for r in rp]
    # Byte-identical stores modulo timestamps / execution-plane provenance.
    assert _canon(t_store, "c") == _canon(p_store, "c")
    # Resource accounting: envelope + columnar metrics, process scope.
    for report in p_store.query("c"):
        res = report.parameter["resources"]
        assert res["worker_mode"] == "process" and res["scope"] == "process"
        assert report.parameter["task_uid"].startswith("collection-c:")
        metrics = report.data[0].metrics
        for key in accounting.RESOURCE_METRICS:
            assert key in metrics
        assert metrics["res_wall_s"] > 0
    # The queue working directory never leaks into prefix scans.
    assert all(not p.startswith("_") for p in p_store.prefixes())


def test_process_collection_requires_store():
    ex = ExecutionOrchestrator(inputs={"prefix": "c", "worker_mode": "process"},
                               harness=SpinHarness(iters=10))
    with pytest.raises(PipelineError, match="store"):
        ex.run_collection(_specs(2), workers=2)


def test_worker_reapplies_injected_env_after_spawn(tmp_path):
    """Regression: ``injected_env`` frames are per-interpreter state — a
    spawned worker inherits neither the locks nor the parent's active
    frames, so the worker bootstrap must re-enter the campaign env itself."""
    store = ResultStore(tmp_path / "s")
    results = run_collection_process(
        inputs={"prefix": "env"}, harness=SpinHarness(iters=500), store=store,
        specs=_specs(2), workers=2, env={SPIN_ENV_KNOB: "7"})
    assert [r.readiness for r in results] == [Readiness.REPRODUCIBLE] * 2
    for r in results:
        assert r.report.data[0].metrics["spin_env_echo"] == 7.0
    # The frame was scoped to the worker's drain loop, not leaked here.
    assert SPIN_ENV_KNOB not in os.environ


def test_broker_synthesizes_failures_for_lost_cells(tmp_path):
    """A pool that dies without completing its cells still yields one
    terminal answer per payload (synthesized failure records)."""
    store = ResultStore(tmp_path / "s")
    broker = CampaignBroker(store, workers=1, name="doomed",
                            lease_timeout=0.5, max_attempts=1,
                            deadline_s=0.2, poll_s=0.05)

    class Unspawnable(SpinHarness):
        def spawn_spec(self):
            return "repro.core.synthetic:does_not_exist", {}

    results = broker.run(_payloads(2), harness=Unspawnable())
    assert set(results) == {0, 1}
    assert all(r["readiness"] == 0 and r["error"] for r in results.values())


# ---------------------------------------------------------------------------
# crash recovery: SIGKILL mid-cell → lease expiry → reclaim → retry
# ---------------------------------------------------------------------------

def _wait_for(predicate, timeout_s, what):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        got = predicate()
        if got:
            return got
        time.sleep(0.05)
    raise AssertionError(f"timed out waiting for {what}")


@pytest.mark.parametrize("backend", ["dir", "jsonl"])
def test_sigkill_mid_cell_reclaimed_exactly_once(tmp_path, backend):
    store = ResultStore(tmp_path / "store", backend=backend)
    sentinels = tmp_path / "sentinels"
    queue_root = tmp_path / "queue"
    spec = _specs(1)[0]
    cfg = WorkerConfig(
        store_root=str(store.root), store_backend=backend,
        harness_ref="repro.core.synthetic:BlockingHarness",
        harness_kwargs={"sentinel_dir": str(sentinels), "timeout_s": 60.0},
        lease_timeout=0.6, poll_s=0.05, idle_timeout=60.0,
    ).to_dict()
    queue = WorkQueue(queue_root, lease_timeout=0.6)
    queue.create([cell_payload(spec, {"prefix": "crash"})], campaign="crash")

    w1 = SPAWN.Process(target=worker_main, args=("w1", str(queue_root), cfg),
                       daemon=True)
    w1.start()
    try:
        # The harness blocks inside run(); the sentinel name carries the
        # executing pid — kill exactly that process, mid-cell.
        sentinel = _wait_for(
            lambda: next(iter(sentinels.glob(f"started.{spec.cell}.*")), None),
            30.0, "worker to start the cell")
        victim = int(sentinel.name.rsplit(".", 1)[1])
        os.kill(victim, signal.SIGKILL)
        w1.join(timeout=10)
        assert not w1.is_alive()

        # Heartbeats stopped with the process: the lease goes stale and the
        # cell is reclaimed exactly once.
        _wait_for(lambda: queue.reclaim_expired() == [0], 10.0, "reclaim")
        assert len(queue.reclaim_journal()) == 1
        assert queue.done_count() == 0  # reclaimed for retry, not failed

        # A fresh worker claims the retry (attempt 2) and completes once
        # the release file appears.
        (sentinels / "release").write_text("go")
        w2 = SPAWN.Process(target=worker_main, args=("w2", str(queue_root), cfg),
                           daemon=True)
        w2.start()
        w2.join(timeout=30)
        assert queue.finished()
    finally:
        for p in (w1,):
            if p.is_alive():
                p.terminate()

    result = queue.results()[0]
    assert result["readiness"] == int(Readiness.REPRODUCIBLE)
    # worker_main expands a bare label to the full host:pid:label identity.
    assert result["worker"].endswith(":w2") and result["attempts"] == 2
    assert len(queue.reclaim_journal()) == 1  # reclaimed exactly once
    # Exactly one persisted report for the cell — the killed attempt never
    # reached its store append, and the retry appended exactly once.
    reports = store.query("crash")
    assert len(reports) == 1
    assert reports[0].parameter["task_uid"] == "crash:0"
    assert reports[0].parameter["attempt"] == 2


@pytest.mark.parametrize("backend", ["dir", "jsonl"])
def test_sigstop_paused_worker_is_fenced_exactly_one_store_entry(tmp_path, backend):
    """A worker paused mid-cell (SIGSTOP — alive, not dead) loses its lease
    to the reclaimed retry.  When it resumes it is already PAST its adoption
    check with a report in hand; pre-fix that report appended unconditionally
    and the store held two entries for one (task_uid, slot).  The lease
    fence (ownership re-check before append and before complete) is what
    makes the effect exactly-once, on both store backends."""
    from repro.core.workers import LeaseLostError, _FencedStore, _find_adopted
    from repro.core.protocol import Report

    store = ResultStore(tmp_path / "store", backend=backend)
    sentinels = tmp_path / "sentinels"
    queue_root = tmp_path / "queue"
    spec = _specs(1)[0]
    cfg = WorkerConfig(
        store_root=str(store.root), store_backend=backend,
        harness_ref="repro.core.synthetic:BlockingHarness",
        harness_kwargs={"sentinel_dir": str(sentinels), "timeout_s": 60.0},
        lease_timeout=0.6, poll_s=0.05, idle_timeout=60.0,
    ).to_dict()
    queue = WorkQueue(queue_root, lease_timeout=0.6)
    queue.create([cell_payload(spec, {"prefix": "pause"})], campaign="pause")

    w1 = SPAWN.Process(target=worker_main, args=("w1", str(queue_root), cfg),
                       daemon=True)
    w1.start()
    victim = None
    try:
        sentinel = _wait_for(
            lambda: next(iter(sentinels.glob(f"started.{spec.cell}.*")), None),
            30.0, "w1 to start the cell")
        victim = int(sentinel.name.rsplit(".", 1)[1])
        os.kill(victim, signal.SIGSTOP)  # paused mid-run: heartbeats freeze

        _wait_for(lambda: queue.reclaim_expired() == [0], 10.0, "reclaim")
        # The retry completes the cell while w1 is still frozen.
        (sentinels / "release").write_text("go")
        w2 = SPAWN.Process(target=worker_main, args=("w2", str(queue_root), cfg),
                           daemon=True)
        w2.start()
        w2.join(timeout=30)
        assert queue.finished()
        assert len(store.query("pause")) == 1

        # Resume the paused worker: it finishes its blocked harness call and
        # reaches its store append — the fence must drop it.
        os.kill(victim, signal.SIGCONT)
        w1.join(timeout=30)
        assert not w1.is_alive()
    finally:
        if victim is not None and w1.is_alive():
            try:
                os.kill(victim, signal.SIGCONT)
            except ProcessLookupError:
                pass
        for p in (w1,):
            if p.is_alive():
                p.terminate()

    # Exactly one done marker (the retry's) and exactly one store entry.
    result = queue.results()[0]
    assert result["worker"].endswith(":w2") and result["attempts"] == 2
    reports = store.query("pause")
    assert len(reports) == 1
    assert reports[0].parameter["worker"].endswith(":w2")
    assert reports[0].parameter["task_uid"] == "pause:0"

    # Pre-fix repro: the resumed worker's append was an unconditional
    # store.append — replay that exact write and the duplicate lands.
    ghost = Report.from_dict(reports[0].to_dict())
    ghost.parameter["worker"] = "w1-ghost"
    with pytest.raises(LeaseLostError):
        # The fix: the fenced proxy re-checks lease ownership first.
        _FencedStore(store, lambda: queue.owns(0, "w1", 1)).append("pause", ghost)
    assert len(store.query("pause")) == 1  # fenced write never landed
    store.append("pause", ghost)  # the pre-fix behavior
    assert len(store.query("pause")) == 2  # ...duplicated the cell

    # Defense-in-depth for historical stores that already carry such a
    # duplicate: every reader keeps the lowest-seq record.
    adopted = _find_adopted(store, "pause", "pause:0")
    assert adopted is not None and adopted.parameter["worker"].endswith(":w2")


def test_corrupt_task_payload_fails_terminally_without_leaking_lease(tmp_path):
    """``claim_next`` winning the lease race and then failing to parse the
    task payload must not leave the lease behind (the cell would wedge until
    lease_timeout and the journal would charge a phantom attempt): the cell
    is terminally failed with a structured marker and the claim moves on."""
    q = WorkQueue(tmp_path / "q").create(_payloads(2))
    (tmp_path / "q" / "tasks" / "00000.json").write_text("{corrupt")

    claim = q.claim_next("w1")
    assert claim is not None
    idx, payload, attempt = claim
    assert idx == 1 and attempt == 1  # the healthy cell, claimed normally
    assert payload["task_uid"] == "campaign:1"

    # The corrupt cell got a terminal marker, not a stuck lease.
    r0 = q.results()[0]
    assert r0["corrupt"] and r0["readiness"] == 0
    assert "corrupt task payload" in r0["error"]
    assert q.lease_info(0) is None  # complete() released the held lease
    assert q.reclaim_journal() == []  # no phantom attempt charged
    assert q.claim_next("w2") is None  # nothing else claimable

    q.complete(1, {"readiness": 3})
    assert q.finished()  # the campaign terminates normally


def test_idle_worker_outlives_slow_peer_while_campaign_progresses(tmp_path):
    """Campaign progress = liveness: a worker with nothing claimable must
    not abandon an unfinished campaign while ANOTHER worker is still
    completing cells — pre-fix it idle-timed-out and the last cell, later
    reclaimed, had nobody left to run it."""
    store = ResultStore(tmp_path / "s")
    queue_root = tmp_path / "q"
    q = WorkQueue(queue_root, lease_timeout=60.0)
    q.create(_payloads(3, prefix="idle"), campaign="idle")
    # A slow peer owns every cell before our worker starts.
    for want in range(3):
        idx, _, _ = q.claim_next("peer")
        assert idx == want

    cfg = WorkerConfig(
        store_root=str(store.root),
        harness_ref="repro.core.synthetic:SpinHarness",
        harness_kwargs={"iters": 100},
        lease_timeout=60.0, poll_s=0.05, idle_timeout=1.0,
    ).to_dict()
    t = threading.Thread(target=worker_main,
                         args=("w-idle", str(queue_root), cfg), daemon=True)
    t.start()

    # The peer finishes a cell every 0.6s — each completion advances
    # done_count and must reset the worker's idle clock.  Total idle time
    # far exceeds idle_timeout (1.0s), but no single gap does.
    time.sleep(0.6)
    q.complete(0, {"readiness": 3, "worker": "peer"})
    time.sleep(0.6)
    q.complete(1, {"readiness": 3, "worker": "peer"})
    time.sleep(0.6)
    assert t.is_alive()  # ~1.8s idle total: alive only if progress resets

    # The peer dies on its last cell; once the lease frees up, the
    # still-alive worker claims and finishes the campaign.
    (queue_root / "leases" / "00002.lease").unlink()
    _wait_for(q.finished, 15.0, "idle worker to pick up the freed cell")
    t.join(timeout=10)
    assert not t.is_alive()
    assert q.results()[2]["worker"].endswith(":w-idle")
    assert len(store.query("idle")) == 1  # only the cell w-idle executed


def test_retry_adopts_orphaned_store_result(tmp_path):
    """A worker killed AFTER persisting but BEFORE its done marker must not
    make the retry re-append: the retry finds the ``task_uid``-tagged report
    in the store and adopts it."""
    from repro.core.workers import _execute_payload

    store = ResultStore(tmp_path / "s")
    payload = cell_payload(_specs(1)[0], {"prefix": "adopt"})
    payload["task_uid"] = "adopt:0"
    harness = SpinHarness(iters=500)
    # Attempt 1 persists its report; pretend the worker died before
    # queue.complete() by simply discarding the result dict.
    first = _execute_payload(payload, store=store, harness=harness,
                             worker_id="w1", attempt=1)
    assert first["readiness"] == int(Readiness.REPRODUCIBLE)
    assert len(store.query("adopt")) == 1
    # The reclaimed retry adopts instead of re-executing.
    second = _execute_payload(payload, store=store, harness=harness,
                              worker_id="w2", attempt=2)
    assert second["adopted"] and second["readiness"] == int(Readiness.REPRODUCIBLE)
    reports = store.query("adopt")
    assert len(reports) == 1  # no duplicate append
    assert reports[0].parameter["worker"] == "w1"  # the original, adopted


# ---------------------------------------------------------------------------
# resource accounting primitives
# ---------------------------------------------------------------------------

def test_resource_probe_fills_accounting_on_success_and_failure():
    acct = {}
    with accounting.resource_probe(acct, "thread"):
        sum(range(10_000))
    assert acct["res_wall_s"] > 0 and acct["scope"] == "thread"
    failed = {}
    with pytest.raises(RuntimeError):
        with accounting.resource_probe(failed, "process"):
            raise RuntimeError("cell exploded")
    assert "res_wall_s" in failed  # a failed cell still cost time
    with pytest.raises(ValueError):
        with accounting.resource_probe({}, "cluster"):
            pass


def test_strip_volatile_removes_exactly_the_plane_fields(tmp_path):
    store = ResultStore(tmp_path / "s")
    ex = ExecutionOrchestrator(inputs={"prefix": "v"},
                               harness=SpinHarness(iters=200), store=store)
    ex.run_collection(_specs(1))
    doc = store.query("v")[0].to_dict()
    canon = accounting.strip_volatile(doc)
    assert "resources" not in canon["parameter"]
    assert canon["reporter"]["timestamp"] == 0.0
    for key in accounting.RESOURCE_METRICS:
        assert key not in canon["data"][0]["metrics"]
    # Payload metrics survive canonicalization.
    assert "spin_result" in canon["data"][0]["metrics"]
    # The original document is untouched (deep copy).
    assert "resources" in doc["parameter"]
