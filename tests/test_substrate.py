"""Substrate tests: data pipeline determinism, checkpoint/restart fault
tolerance, trainer convergence + resume, serving engine, ExecHarness
readiness integration."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.checkpoint import CheckpointManager
from repro.core.harness import BenchmarkSpec, ExecHarness, Injections
from repro.core.readiness import Readiness, classify, verify_reproduction
from repro.core.energy import energy_launcher
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.hardware import TPU_V5E
from repro.models import params as P
from repro.serve.engine import Engine, Request
from repro.train import optimizer as O
from repro.train.trainer import TrainConfig, detect_stragglers, train


def small_cfg():
    return dataclasses.replace(
        configs.get_smoke("glm4-9b"), d_model=64, n_layers=2, d_ff=128,
        vocab_size=128, dtype="float32",
    )


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_step_keyed():
    cfg = small_cfg()
    d = SyntheticLM(cfg, DataConfig(seq_len=64, global_batch=4, seed=7))
    b1 = d.batch(3)
    b2 = d.batch(3)
    assert jnp.array_equal(b1["tokens"], b2["tokens"])  # restart-stable
    b3 = d.batch(4)
    assert not jnp.array_equal(b1["tokens"], b3["tokens"])


def test_data_host_sharding_disjoint():
    cfg = small_cfg()
    a = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=8, n_hosts=2, host_id=0))
    b = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=8, n_hosts=2, host_id=1))
    assert a.batch(0)["tokens"].shape[0] == 4
    assert not jnp.array_equal(a.batch(0)["tokens"], b.batch(0)["tokens"])


def test_data_targets_shifted():
    cfg = small_cfg()
    d = SyntheticLM(cfg, DataConfig(seq_len=64, global_batch=2))
    b = d.batch(0)
    toks, tgts = np.asarray(b["tokens"]), np.asarray(b["targets"])
    mask = tgts[:, :-1] >= 0
    np.testing.assert_array_equal(
        tgts[:, :-1][mask], toks[:, 1:][mask]
    )


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for s in (10, 20, 30):
        mgr.save(s, tree)
    assert mgr.steps() == [20, 30]  # keep=2 GC'd step 10
    out = mgr.restore(30)
    np.testing.assert_array_equal(out["a"], tree["a"])
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.ones((8, 8))})
    # Corrupt the array file.
    f = next((tmp_path / "step_00000001").glob("w.npy"))
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(IOError):
        mgr.restore(1)


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, {"w": jnp.zeros((16,))}, block=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_partial_write_invisible(tmp_path):
    """A save without manifest (crash mid-write) must not be picked up."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.ones((4,))})
    d = tmp_path / "step_00000002"
    d.mkdir()
    np.save(d / "w.npy", np.ones((4,)))  # no manifest.json
    assert mgr.latest_step() == 1


# ---------------------------------------------------------------------------
# trainer: convergence, restart equivalence, straggler telemetry
# ---------------------------------------------------------------------------

def _tc(steps, tmp=None, ckpt_every=50):
    return TrainConfig(
        steps=steps,
        ckpt_every=ckpt_every,
        data=DataConfig(seq_len=64, global_batch=4, seed=1),
        opt=O.OptConfig(lr=5e-3, warmup_steps=5, total_steps=steps, weight_decay=0.0),
        remat="none",
    )


def test_trainer_loss_decreases():
    cfg = small_cfg()
    res = train(cfg, _tc(30))
    early = float(np.mean(res.losses[:5]))
    late = float(np.mean(res.losses[-5:]))
    assert late < early - 0.2, (early, late)


def test_trainer_restart_bit_identical(tmp_path):
    """Fault-tolerance: crash mid-run, resume, final params identical to an
    uninterrupted run (the loop is a pure function of checkpoint + step)."""
    cfg = small_cfg()
    a = tmp_path / "a"
    b = tmp_path / "b"
    train(cfg, _tc(20, ckpt_every=10), ckpt=CheckpointManager(a))

    # Interrupted run: same 20-step config, simulated node failure at step 12.
    class Crash(RuntimeError):
        pass

    def crash(step, metrics):
        if step == 12:
            raise Crash()

    mgr_b = CheckpointManager(b)
    with pytest.raises(Crash):
        train(cfg, _tc(20, ckpt_every=10), ckpt=mgr_b, on_step=crash)
    res2 = train(cfg, _tc(20, ckpt_every=10), ckpt=CheckpointManager(b))
    assert res2.restored_from == 10
    pa = CheckpointManager(a).restore(20)["params"]
    pb = CheckpointManager(b).restore(20)["params"]
    for k, v in P.flatten(pa).items():
        np.testing.assert_array_equal(np.asarray(v), np.asarray(P.flatten(pb)[k]), err_msg=k)


def test_straggler_detection():
    times = [0.1] * 20
    times[7] = 0.5
    times[15] = 0.3
    assert detect_stragglers(times) == [7, 15]


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_engine_greedy_deterministic():
    cfg = small_cfg()
    params = P.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, batch=4, max_len=64)
    reqs = [
        Request(uid=i, prompt=np.arange(1, 6 + i, dtype=np.int32), max_new_tokens=8)
        for i in range(3)
    ]
    outs1 = eng.generate(reqs)
    outs2 = Engine(cfg, params, batch=4, max_len=64).generate(reqs)
    assert [c.tokens for c in outs1] == [c.tokens for c in outs2]
    assert all(len(c.tokens) == 8 for c in outs1)


def test_engine_matches_stepwise_decode():
    """Engine greedy output == hand-rolled prefill+argmax loop."""
    from repro.models import transformer as T

    cfg = small_cfg()
    params = P.init_params(cfg, jax.random.key(1))
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    eng = Engine(cfg, params, batch=1, max_len=32)
    got = eng.generate([Request(uid=0, prompt=prompt, max_new_tokens=5)])[0].tokens

    logits, state = T.prefill(params, cfg, {"tokens": jnp.asarray(prompt[None])}, max_len=32, remat="none")
    toks = []
    cur = int(jnp.argmax(logits[0, 0]))
    toks.append(cur)
    for t in range(4):
        idx = jnp.asarray(len(prompt) + t, jnp.int32)
        logits, state = T.decode_step(
            params, cfg, state, {"tokens": jnp.full((1, 1), cur, jnp.int32)}, idx
        )
        cur = int(jnp.argmax(logits[0, 0]))
        toks.append(cur)
    assert got == toks


# ---------------------------------------------------------------------------
# ExecHarness end-to-end: readiness ladder on a real (smoke) workload
# ---------------------------------------------------------------------------

def test_exec_harness_reaches_reproducible():
    h = ExecHarness(steps=1, batch=2, seq=8)
    spec = BenchmarkSpec(arch="glm4-9b", shape="train_4k", system="cpu-smoke")
    rep = h.run(spec)
    level, gaps = classify(rep)
    assert level == Readiness.REPRODUCIBLE, gaps
    # Re-run: artifact digests match -> verified reproduction.
    rep2 = h.run(spec)
    assert verify_reproduction(rep, rep2)


def test_exec_harness_energy_injection():
    """Launcher injection adds protocol-compliant energy metrics without
    touching the benchmark (paper §VI-B)."""
    h = ExecHarness(steps=1, batch=2, seq=8)
    spec = BenchmarkSpec(arch="mamba2-1.3b", shape="decode_32k", system="cpu-smoke")
    inj = Injections(launcher=energy_launcher(TPU_V5E, n_chips=1))
    rep = h.run(spec, inj)
    m = rep.data[0].metrics
    assert m["energy_to_solution_j"] > 0
    assert rep.data[0].success
