"""Tests for the incremental columnar metrics plane: watermark semantics
(incremental append extends, fingerprint mutation rebuilds), sidecar
persistence, and byte-identical parity between the vectorized column-backed
analysis paths and the report-object reference paths — on both store
backends."""

import json

import numpy as np
import pytest

from repro.core import analysis, export
from repro.core.cicd import component_dag, parse_pipeline_text, run_pipeline
from repro.core.columnar import CampaignFrame, ColumnTable, MetricSeries
from repro.core.harness import BenchmarkSpec, Harness
from repro.core.orchestrator import PostProcessingOrchestrator
from repro.core.protocol import DataEntry, new_report
from repro.core.regression import (
    GateSpec,
    MetricSpec,
    RegressionGate,
    json_safe,
)
from repro.core.store import ResultStore


def _mk(system="s", variant="v", metrics=None, ts=1.0, nodes=1, success=True,
        pid="p1", job="j", injections=None, entries=1):
    r = new_report(system=system, variant=variant, usecase="u", pipeline_id=pid)
    r.experiment.timestamp = ts
    if injections is not None:
        r.parameter["injections"] = injections
    for k in range(entries):
        r.data.append(DataEntry(success=success, runtime=1.0 + ts / 10 + k,
                                nodes=nodes, metrics=dict(metrics or {}),
                                job_id=f"{job}{k}" if entries > 1 else job))
    return r


def _seed_mixed(store, prefix="p", n=20):
    for i in range(n):
        store.append(prefix, _mk(
            system=f"sys{i % 2}", variant=f"v{i % 3}", ts=float(i),
            nodes=1 + i % 4, success=(i % 7 != 0), pid=f"pl{i % 3}",
            metrics={"m": float(i), "runtime": 100.0 + i} if i % 5 == 0
            else {"m": float(i)},
        ))


@pytest.fixture(params=["dir", "jsonl"])
def any_store(request, tmp_path):
    return ResultStore(tmp_path, backend=request.param)


# ---------------------------------------------------------------------------
# watermark semantics: hit / extend / rebuild
# ---------------------------------------------------------------------------

def test_incremental_append_extends_without_rebuild(any_store):
    _seed_mixed(any_store, n=10)
    t = any_store.columnar.table("p")
    assert t.n_rows == 10 and t.n_entries == 10 and t.watermark == 9
    assert any_store.columnar.stats["rebuilds"] == 1
    # Unchanged fingerprint: pure cache hit.
    t2 = any_store.columnar.table("p")
    assert t2 is t and any_store.columnar.stats["hits"] == 1
    # Append: columns extend in O(delta), no rebuild.
    for i in range(10, 15):
        any_store.append("p", _mk(ts=float(i), metrics={"m": float(i)}))
    t3 = any_store.columnar.table("p")
    assert t3.n_rows == 15 and t3.watermark == 14
    assert any_store.columnar.stats["incremental"] == 1
    assert any_store.columnar.stats["rebuilds"] == 1
    # A metric first seen mid-history back-fills absent for earlier rows.
    any_store.append("p", _mk(ts=99.0, metrics={"late_metric": 7.0}))
    t4 = any_store.columnar.table("p")
    s = t4.series("late_metric")
    assert s.n == 1 and s.values[0] == 7.0
    assert any_store.columnar.stats["rebuilds"] == 1


def test_sidecar_persists_across_store_instances(any_store):
    _seed_mixed(any_store, n=8)
    any_store.columnar.table("p")
    fresh = ResultStore(any_store.root, backend=any_store.backend)
    t = fresh.columnar.table("p")
    assert fresh.columnar.stats["sidecar_loads"] == 1
    assert fresh.columnar.stats["rebuilds"] == 0
    assert fresh.columnar.stats["incremental"] == 0
    assert t.n_rows == 8
    # And the loaded table still answers queries identically.
    assert t.series("m").time_points() == \
        analysis.to_series(fresh.query("p"), "m")


def test_deferred_sidecar_persistence_and_flush(any_store):
    _seed_mixed(any_store, n=10)
    any_store.columnar.table("p")  # rebuild persists immediately
    assert any_store.columnar.stats["sidecar_saves"] == 1
    any_store.append("p", _mk(ts=50.0, metrics={"m": 50.0}))
    any_store.columnar.table("p")  # 1 entry behind < SAVE_EVERY: deferred
    assert any_store.columnar.stats["sidecar_saves"] == 1
    # A fresh process loads the lagging sidecar and extends — no rebuild.
    fresh = ResultStore(any_store.root, backend=any_store.backend)
    assert fresh.columnar.table("p").n_rows == 11
    assert fresh.columnar.stats["rebuilds"] == 0
    assert fresh.columnar.stats["incremental"] == 1
    # flush() forces persistence; the next instance starts fully warm.
    fresh.columnar.flush()
    warm = ResultStore(any_store.root, backend=any_store.backend)
    assert warm.columnar.table("p").n_rows == 11
    assert warm.columnar.stats["incremental"] == 0
    assert warm.columnar.stats["rebuilds"] == 0


def test_empty_prefix_builds_no_backend_state(any_store):
    t = any_store.columnar.table("never_written")
    assert t.n_rows == 0 and t.n_entries == 0 and t.watermark == -1
    assert t.series("m").n == 0
    # The read must not have materialized the prefix in the store.
    assert any_store.prefixes() == []


def test_dir_mutation_invalidates_and_rebuilds(tmp_path):
    store = ResultStore(tmp_path)  # dir backend
    _seed_mixed(store, n=6)
    p1 = store.append("p", _mk(ts=50.0, metrics={"m": 50.0}))
    assert store.columnar.table("p").n_rows == 7
    # In-place tamper: fingerprint changes non-append-only -> one rebuild,
    # and the corrupt record is dropped exactly like the report path drops it.
    doc = json.loads(p1.read_text())
    doc["data"][0]["runtime"] = 123456.0
    p1.write_text(json.dumps(doc))
    t = store.columnar.table("p")
    assert store.columnar.stats["rebuilds"] == 2
    assert t.n_rows == len(store.query("p")) == 6


def test_jsonl_prune_invalidates_and_rebuilds(tmp_path):
    store = ResultStore(tmp_path, backend="jsonl")
    _seed_mixed(store, n=6)
    assert store.columnar.table("p").n_rows == 6
    # Prune the newest record (file shrinks): must rebuild, not extend.
    data = tmp_path / "p.jsonl"
    lines = data.read_text().splitlines()
    data.write_text("\n".join(lines[:-1]) + "\n")
    (tmp_path / "p.jsonl.idx").unlink()
    t = store.columnar.table("p")
    assert store.columnar.stats["rebuilds"] == 2
    assert t.n_rows == len(store.query("p")) == 5


def test_corrupt_sidecar_only_costs_a_rebuild(any_store):
    _seed_mixed(any_store, n=5)
    any_store.columnar.table("p")
    sidecar = any_store.backend.sidecar_path("p", "columns.npz")
    assert sidecar.exists()
    sidecar.write_bytes(b"not an npz")
    fresh = ResultStore(any_store.root, backend=any_store.backend)
    assert fresh.columnar.table("p").n_rows == 5
    assert fresh.columnar.stats["rebuilds"] == 1


# ---------------------------------------------------------------------------
# vectorized vs report-object parity
# ---------------------------------------------------------------------------

def test_series_parity_with_to_series(any_store):
    _seed_mixed(any_store)
    t = any_store.columnar.table("p")
    for metric in ("m", "runtime", "missing_metric"):
        assert t.series(metric).time_points() == \
            analysis.to_series(any_store.query("p"), metric)
    # Dimension filters mirror the index-entry filters.
    for kw in ({"system": "sys1"}, {"variant": "v2"},
               {"since": 3.0, "until": 11.0}, {"trusted_only": True}):
        want = analysis.to_series(any_store.query("p", **{
            k: v for k, v in kw.items() if k != "trusted_only"
        } | ({"trusted_only": True} if kw.get("trusted_only") else {})), "m")
        assert t.series("m", **kw).time_points() == want


def test_series_last_entries_matches_query_last(any_store):
    _seed_mixed(any_store, n=15)
    t = any_store.columnar.table("p")
    from repro.core.regression import _series

    for last in (0, 3, 15, 99):
        pairs = any_store.query_with_entries("p", last=last)
        want = _series(pairs, "m")
        got = t.series("m", success_only=True, last_entries=last).seq_points()
        assert got == want, last


def test_gate_parity_pass_and_fail(any_store):
    rng = np.random.default_rng(0)
    for i in range(40):
        v = float(1.0 + rng.normal(0, 0.02))
        any_store.append("g", _mk(ts=float(i), metrics={"step_time_s": v}))
    kw = dict(source_prefix="g", metrics=[MetricSpec("step_time_s")],
              window=16, candidate=4, min_points=3, history=100,
              update_baseline=False, record_prefix="none")
    col = RegressionGate(GateSpec(**kw, use_columnar=True)).run(any_store)
    obj = RegressionGate(GateSpec(**kw, use_columnar=False)).run(any_store)
    assert json.dumps(json_safe(col), sort_keys=True) == \
        json.dumps(json_safe(obj), sort_keys=True)
    assert col["status"] == "pass"
    # Inject a slowdown: identical FAIL verdicts and change-point sequence.
    for i in range(6):
        any_store.append("g", _mk(ts=40.0 + i, metrics={"step_time_s": 5.0}))
    col = RegressionGate(GateSpec(**kw, use_columnar=True)).run(any_store)
    obj = RegressionGate(GateSpec(**kw, use_columnar=False)).run(any_store)
    assert json.dumps(json_safe(col), sort_keys=True) == \
        json.dumps(json_safe(obj), sort_keys=True)
    assert col["status"] == "fail"
    assert col["gates"][0]["change_seq"] == 40


def test_post_processing_parity(any_store):
    for i in range(24):
        any_store.append("pp", _mk(
            system=f"sys{i % 3}", ts=float(i), nodes=1 << (i % 4),
            pid=f"pl{i % 2}",
            metrics={"step_time_s": 1.0 + 0.1 * (i % 5)},
            injections={"env": {"KNOB": str(i % 3)}} if i % 2 else None,
        ))
    col = PostProcessingOrchestrator(store=any_store, inputs={"record": False})
    obj = PostProcessingOrchestrator(
        store=any_store, inputs={"record": False, "columnar": False})
    assert col.time_series(source_prefix="pp", data_labels=["step_time_s"]) \
        == obj.time_series(source_prefix="pp", data_labels=["step_time_s"])
    assert col.time_series(source_prefix="pp", data_labels=["step_time_s"],
                           pipeline=["pl1"], time_span=(2.0, 20.0)) \
        == obj.time_series(source_prefix="pp", data_labels=["step_time_s"],
                           pipeline=["pl1"], time_span=(2.0, 20.0))
    assert col.machine_comparison(
        selectors=[{"prefix": "pp", "system": "sys1"}, {"prefix": "pp"}],
        metric="step_time_s") == obj.machine_comparison(
        selectors=[{"prefix": "pp", "system": "sys1"}, {"prefix": "pp"}],
        metric="step_time_s")
    for mode in ("strong", "weak"):
        assert col.scalability(source_prefix="pp", metric="step_time_s",
                               mode=mode) == \
            obj.scalability(source_prefix="pp", metric="step_time_s",
                            mode=mode)


def test_time_series_memo_sees_new_appends(any_store):
    for i in range(10):
        any_store.append("pp", _mk(ts=float(i), metrics={"m": float(i)}))
    pp = PostProcessingOrchestrator(store=any_store, inputs={"record": False})
    first = pp.time_series(source_prefix="pp", data_labels=["m"])
    again = pp.time_series(source_prefix="pp", data_labels=["m"])
    assert first == again  # memo hit, same content
    any_store.append("pp", _mk(ts=10.0, metrics={"m": 10.0}))
    after = pp.time_series(source_prefix="pp", data_labels=["m"])
    assert len(after["series"]["m"]) == 11  # table swap invalidated the memo


def test_injection_comparison_parity(any_store):
    for i, thresh in enumerate(["1024", "65536", "1048576"]):
        any_store.append("inj", _mk(
            ts=float(i), metrics={"bw": 10.0 * (i + 1)},
            injections={"env": {"UCX_RNDV_THRESH": thresh}, "overrides": {}},
        ))
    any_store.append("inj", _mk(ts=9.0, metrics={"bw": 1.0}))  # no injection
    want = analysis.injection_comparison(
        any_store.query("inj"), "bw", "UCX_RNDV_THRESH")
    got = any_store.columnar.table("inj").injection_comparison(
        "bw", "UCX_RNDV_THRESH")
    assert got == want
    assert set(got) == {"1024", "65536", "1048576", "default"}


def test_non_numeric_metrics_survive_in_extras(any_store):
    any_store.append("x", _mk(ts=1.0, metrics={
        "num": 3.5, "count": 5, "label": "fast-path", "flag": True}))
    t = any_store.columnar.table("x")
    assert t.series("num").n == 1
    assert t.series("count").n == 1  # analyzable as a numeric column...
    assert t.series("label").n == 0  # not a numeric column
    rec = t.job_records()[0]
    assert rec["metrics"]["label"] == "fast-path"
    assert rec["metrics"]["num"] == 3.5
    # ...while exports round-trip the original types exactly.
    assert rec["metrics"]["count"] == 5 and type(rec["metrics"]["count"]) is int
    assert rec["metrics"]["flag"] is True


def test_multi_entry_reports_row_per_entry(any_store):
    any_store.append("me", _mk(ts=1.0, metrics={"m": 1.0}, entries=3))
    t = any_store.columnar.table("me")
    assert t.n_rows == 3 and t.n_entries == 1
    from repro.core.regression import _series

    assert t.series("m", success_only=True).seq_points() == \
        _series(any_store.query_with_entries("me"), "m")


# ---------------------------------------------------------------------------
# exports through the columnar plane
# ---------------------------------------------------------------------------

def test_exports_match_report_reference(any_store, tmp_path):
    _seed_mixed(any_store, n=9)
    reports = any_store.query("p")
    # grafana: rows must equal the to_series-derived reference.
    g = export.grafana_table(any_store, "p", "m")
    assert g["rows"] == [[int(ts * 1000), v]
                         for ts, v in analysis.to_series(reports, "m")]
    # llview: same records the report path produced (order + content).
    want = []
    for r in reports:
        for d in r.data:
            want.append({
                "jobid": d.job_id, "system": r.experiment.system,
                "queue": d.queue, "nodes": d.nodes, "runtime": d.runtime,
                "state": "COMPLETED" if d.success else "FAILED",
                "ts": r.experiment.timestamp, "metrics": dict(d.metrics),
            })
    assert export.llview_jobs(any_store, "p") == want
    out = export.write_exports(any_store, "p", "m", tmp_path / "out")
    assert set(out) == {"grafana", "llview", "ascii"}
    assert json.loads((tmp_path / "out" / "grafana.p.m.json").read_text()) == g
    assert "p:m" in (tmp_path / "out" / "ascii.p.m.txt").read_text()
    assert "p:m" in export.ascii_timeseries_report(any_store, "p", "m")


# ---------------------------------------------------------------------------
# campaign frame + cicd component
# ---------------------------------------------------------------------------

def test_campaign_frame_cross_prefix(any_store):
    for p in range(3):
        for i in range(6):
            any_store.append(f"app{p}", _mk(
                system=f"sys{p}", ts=float(i),
                metrics={"m": float(10 * p + i)}))
    frame = any_store.columnar.frame()
    assert set(frame.prefixes()) == {"app0", "app1", "app2"}
    summary = frame.summary("m")
    for p in range(3):
        vals = [10.0 * p + i for i in range(6)]
        assert summary[f"app{p}"] == analysis.summary_stats(vals)
    assert frame.watermarks() == {f"app{p}": 5 for p in range(3)}
    # compare_systems across selectors == the report-object reduction.
    sels = [{"prefix": "app0"}, {"prefix": "app2"}]
    reports = [r for s in sels for r in any_store.query(s["prefix"])]
    assert frame.compare_systems(sels, "m") == \
        analysis.compare_systems(reports, "m")
    # Restricting prefixes restricts the scan.
    assert set(CampaignFrame(any_store, ["app1"]).summary("m")) == {"app1"}


def test_campaign_summary_skips_envelope_bookkeeping(any_store):
    """A default frame sweeps the whole store — baseline/gate envelope
    prefixes included — but their bookkeeping rows (runtime 0.0, mirrored
    payload numerics) must not pollute campaign summaries."""
    rng = np.random.default_rng(1)
    for i in range(12):
        any_store.append("app", _mk(
            ts=float(i), metrics={"step_time_s": float(1 + rng.normal(0, 0.01))}))
    RegressionGate(GateSpec(
        source_prefix="app", metrics=[MetricSpec("step_time_s")],
        min_points=3, window=8,
    )).run(any_store)  # writes baseline.app + gate.app envelope prefixes
    frame = any_store.columnar.frame()
    assert {"baseline.app", "gate.app"} <= set(frame.prefixes())
    summary = frame.summary("runtime")
    assert set(summary) == {"app"}, summary  # no envelope placeholder rows
    # The single-prefix parity path is unchanged: envelopes stay visible.
    t = any_store.columnar.table("gate.app")
    assert t.series("runtime").n == 1


class _StubHarness(Harness):
    name = "stub"

    def run(self, spec: BenchmarkSpec, injections=None):
        r = new_report(system=spec.system, variant=spec.effective_variant(),
                       usecase=spec.shape, pipeline_id="p")
        r.data.append(DataEntry(success=True, runtime=0.1,
                                metrics={"step_time_s": 1.0}))
        return r


CAMPAIGN_YML = """\
include:
  - component: execution@v3
    inputs:
      prefix: "c.one"
      arch: "a0"
  - component: execution@v3
    inputs:
      prefix: "c.two"
      arch: "a0"
  - component: campaign-report@v1
    inputs:
      metric: "step_time_s"
"""


def test_campaign_report_component(tmp_path):
    calls = parse_pipeline_text(CAMPAIGN_YML)
    # No explicit prefixes: the report waits for every producer.
    assert component_dag(calls) == [[], [], [0, 1]]
    store = ResultStore(tmp_path)
    results = run_pipeline(calls, store=store, harness=_StubHarness())
    rep = results[2]
    assert rep["component"] == "campaign-report"
    assert set(rep["table"]) == {"c.one", "c.two"}
    assert rep["watermarks"] == {"c.one": 0, "c.two": 0}
    assert "campaign summary" in rep["markdown"]
    # Explicit prefixes create targeted DAG edges instead.
    calls2 = parse_pipeline_text(CAMPAIGN_YML.replace(
        'metric: "step_time_s"', 'metric: "step_time_s"\n      prefixes: [c.two]'))
    assert component_dag(calls2) == [[], [], [1]]


# ---------------------------------------------------------------------------
# vectorized detector vs the seed loop
# ---------------------------------------------------------------------------

def _loop_detect(series, window=8, z_threshold=4.0, min_rel=0.05):
    out = []
    window = max(1, int(window))
    vals = np.array([v for _, v in series], dtype=np.float64)
    if vals.size <= window:
        return out
    for i in range(window, len(vals)):
        base = vals[i - window:i]
        med = float(np.median(base))
        mad = float(np.median(np.abs(base - med)))
        sigma = max(1.4826 * mad, 1e-12)
        dev = abs(vals[i] - med)
        if dev / sigma > z_threshold and (med == 0 or dev / abs(med) > min_rel):
            out.append((i, series[i][0], float(vals[i]), med, dev / sigma))
    return out


def test_detect_regressions_matches_seed_loop():
    rng = np.random.default_rng(7)
    cases = [
        [(float(i), float(1 + rng.normal(0, 0.02))) for i in range(200)],
        [(float(i), float(1 + rng.normal(0, 0.02))) for i in range(100)]
        + [(float(100 + i), float(2 + rng.normal(0, 0.02))) for i in range(50)],
        [(float(i), float(rng.normal(0, 1.0))) for i in range(150)],
        [(float(i), 0.0) for i in range(30)],
        [(float(i), float(-5 + rng.normal(0, 0.3))) for i in range(80)],
        [(float(i), v) for i, v in enumerate([1.0] * 20 + [1.051] + [1.0] * 20)],
    ]
    for w in (1, 2, 8, 13):
        for z in (1.0, 4.0):
            for mr in (0.0, 0.05, 0.3):
                for c in cases:
                    want = _loop_detect(c, w, z, mr)
                    got = [(r.index, r.timestamp, r.value, r.baseline, r.sigma)
                           for r in analysis.detect_regressions(
                               c, window=w, z_threshold=z, min_rel=mr)]
                    assert got == want, (w, z, mr)


def test_detect_regressions_accepts_metric_series():
    ts = np.arange(40, dtype=np.float64)
    vals = np.concatenate([np.ones(30), np.full(10, 3.0)])
    ms = MetricSeries("m", np.arange(40, dtype=np.int64), ts, vals)
    as_list = list(zip(ts.tolist(), vals.tolist()))
    a = analysis.detect_regressions(ms)
    b = analysis.detect_regressions(as_list)
    assert [(r.index, r.timestamp, r.value, r.baseline, r.sigma) for r in a] \
        == [(r.index, r.timestamp, r.value, r.baseline, r.sigma) for r in b]
    assert a and a[0].index == 30


# ---------------------------------------------------------------------------
# warm-append fetch economy (jsonl retained)
# ---------------------------------------------------------------------------

def test_jsonl_warm_append_fetches_only_the_tail(tmp_path):
    store = ResultStore(tmp_path, backend="jsonl")
    _seed_mixed(store, n=10)
    assert len(store.query("p")) == 10  # warm the parsed-report cache
    fetched = []
    orig = store.backend.fetch

    def counting_fetch(prefix, entries):
        fetched.append(len(entries))
        return orig(prefix, entries)

    store.backend.fetch = counting_fetch
    store.append("p", _mk(ts=50.0, metrics={"m": 50.0}))
    assert len(store.query("p")) == 11
    assert fetched == [1], fetched  # only the new record was parsed
