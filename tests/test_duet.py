"""Tests for the duet measurement plane: environment fingerprints (capture,
graceful degradation, drift), duet execution (pairing, exactly-once across
SIGKILL, worker pinning), and the paired-delta gate — including the
discrimination property the methodology exists for: under shared
multiplicative environment noise the absolute-series gate misclassifies
identical binaries while the paired gate passes them AND still flags an
injected slowdown."""

import json
import os
import signal
import time
from pathlib import Path

import multiprocessing as mp

import numpy as np
import pytest

from repro.core import duet, fingerprint
from repro.core.harness import BenchmarkSpec, Injections
from repro.core.orchestrator import ExecutionOrchestrator, reduce_duet
from repro.core.protocol import DataEntry, new_report
from repro.core.readiness import Readiness
from repro.core.regression import (
    FAIL,
    PASS,
    GateSpec,
    MetricSpec,
    PairedDeltaDetector,
    RegressionGate,
)
from repro.core.store import ResultStore
from repro.core.synthetic import (
    DUET_SLOWDOWN_KNOB,
    DuetNoiseHarness,
    SpinHarness,
)
from repro.core.workers import WorkerConfig, cell_payload, worker_main
from repro.core.workqueue import WorkQueue

SPAWN = mp.get_context("spawn")

SPEC = BenchmarkSpec(arch="archA", shape="train_4k", system="sysA")

FP_A = {"hostname": "host-1", "machine": "x86_64", "cpu_count": 8,
        "governor": "performance", "python": "3.12.0", "numpy": "2.0.0"}
FP_B = dict(FP_A, governor="powersave")


def _wait_for(predicate, timeout_s, what):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        got = predicate()
        if got:
            return got
        time.sleep(0.05)
    raise AssertionError(f"timed out waiting for {what}")


def _append(store, prefix, value, *, fp=None, trusted=True,
            metric="step_time_s"):
    r = new_report(system="t", variant="v", usecase="u", pipeline_id="p")
    r.data.append(DataEntry(success=True, runtime=max(value, 0.0),
                            metrics={metric: value}))
    if fp is not None:
        fingerprint.stamp(r, fp)
    r.reporter.chain_of_trust = trusted
    store.append(prefix, r)


def _append_duet(store, prefix, duet_id, jitters, *, base=1.0, factor=1.0,
                 fp=None, metric="step_time_s"):
    """One complete duet: len(jitters) rounds, both roles sharing each
    round's jitter, candidate scaled by ``factor`` — the synthetic
    noisy-environment model from the acceptance criteria."""
    rounds = len(jitters)
    for i, jitter in enumerate(jitters):
        for role, scale in ((duet.ROLE_BASELINE, 1.0),
                            (duet.ROLE_CANDIDATE, factor)):
            val = base * jitter * scale
            r = new_report(system="t", variant="v", usecase="u",
                           pipeline_id=f"{duet_id}-{i}-{role}")
            r.parameter[duet.PARAMETER] = duet.tag(duet_id, role, i, rounds)
            if fp is not None:
                fingerprint.stamp(r, fp)
            r.data.append(DataEntry(success=True, runtime=val,
                                    metrics={metric: val}))
            store.append(prefix, r)


def _gate(store, prefix, **overrides):
    inputs = {"source_prefix": prefix, "metrics": ["step_time_s"],
              "tolerance": 0.05, "min_points": 4, "update_baseline": False,
              "record_prefix": "none"}
    inputs.update(overrides)
    return RegressionGate(GateSpec.from_inputs(inputs)).run(store)


# ---------------------------------------------------------------------------
# fingerprint capture + key/drift semantics
# ---------------------------------------------------------------------------

def test_capture_degrades_gracefully_on_missing_roots(tmp_path):
    fp = fingerprint.capture(sysfs_root=str(tmp_path / "nosys"),
                             proc_root=str(tmp_path / "noproc"))
    # Unreadable probes yield None, never an exception.
    assert fp["governor"] is None
    assert fp["cpu_freq_khz"] is None
    assert fp["cgroup_cpu_max"] is None
    assert fp["thermal_c"] is None
    # Host-level fields still captured.
    assert fp["hostname"] and fp["python"]
    assert fp["cpu_count"] == os.cpu_count()


def test_capture_reads_fabricated_sysfs_tree(tmp_path):
    sysfs = tmp_path / "sys"
    cpufreq = sysfs / "devices" / "system" / "cpu" / "cpu0" / "cpufreq"
    cpufreq.mkdir(parents=True)
    (cpufreq / "scaling_governor").write_text("performance\n")
    (cpufreq / "scaling_cur_freq").write_text("2400000\n")
    (cpufreq / "scaling_max_freq").write_text("3500000\n")
    (sysfs / "fs" / "cgroup").mkdir(parents=True)
    (sysfs / "fs" / "cgroup" / "cpu.max").write_text("200000 100000\n")
    thermal = sysfs / "class" / "thermal" / "thermal_zone0"
    thermal.mkdir(parents=True)
    (thermal / "temp").write_text("45000\n")
    fp = fingerprint.capture(sysfs_root=str(sysfs))
    assert fp["governor"] == "performance"
    assert fp["cpu_freq_khz"] == 2400000
    assert fp["cpu_freq_max_khz"] == 3500000
    assert fp["cgroup_cpu_max"] == "200000 100000"
    assert fp["thermal_c"] == 45.0


def test_capture_tolerates_unreadable_sysfs_entries(tmp_path):
    # A probe path that exists but is not a readable file (here: a
    # directory, the case root-run CI can still exercise) must degrade to
    # None like a missing one.
    sysfs = tmp_path / "sys"
    (sysfs / "devices" / "system" / "cpu" / "cpu0" / "cpufreq"
     / "scaling_governor").mkdir(parents=True)
    fp = fingerprint.capture(sysfs_root=str(sysfs))
    assert fp["governor"] is None


def test_key_ignores_volatile_observations():
    a = dict(FP_A, cpu_freq_khz=2_400_000, loadavg_1m=0.5, thermal_c=40.0)
    b = dict(FP_A, cpu_freq_khz=1_200_000, loadavg_1m=7.9, thermal_c=88.0)
    assert fingerprint.key(a) == fingerprint.key(b)
    assert fingerprint.drift(a, b) == []


def test_drift_names_differing_key_fields():
    assert fingerprint.drift(FP_A, FP_B) == ["governor"]
    # Key strings compare exactly like the dicts they came from.
    assert fingerprint.drift(fingerprint.key(FP_A),
                             fingerprint.key(FP_B)) == ["governor"]
    # Empty/absent fingerprints never drift.
    assert fingerprint.drift(None, FP_A) == []
    assert fingerprint.drift("", FP_A) == []
    assert fingerprint.key({}) == ""
    assert fingerprint.key({"cpu_freq_khz": 1}) == ""


# ---------------------------------------------------------------------------
# orchestrator: stamping, drift downgrade, duet pairing
# ---------------------------------------------------------------------------

def test_run_cell_stamps_fingerprint_and_keeps_trust(tmp_path):
    store = ResultStore(tmp_path / "s")
    ex = ExecutionOrchestrator(inputs={"prefix": "p", "arch": "archA"},
                               harness=SpinHarness(iters=10), store=store)
    res = ex.run_cell(SPEC)
    rep = res.report
    assert rep.parameter[fingerprint.PARAMETER]["hostname"]
    assert rep.reporter.environment.get("hostname")
    assert rep.reporter.chain_of_trust is True
    assert fingerprint.DRIFT_PARAMETER not in rep.parameter


def test_run_cell_drift_downgrades_chain_of_trust(tmp_path):
    store = ResultStore(tmp_path / "s")
    reference = dict(fingerprint.capture(), governor="__elsewhere__")
    ex = ExecutionOrchestrator(inputs={"prefix": "p", "arch": "archA"},
                               harness=SpinHarness(iters=10), store=store,
                               reference_fingerprint=reference)
    rep = ex.run_cell(SPEC).report
    assert rep.reporter.chain_of_trust is False
    assert "governor" in rep.parameter[fingerprint.DRIFT_PARAMETER]


@pytest.mark.parametrize("backend", ["dir", "jsonl"])
def test_run_duet_pairs_and_columnar_parity(tmp_path, backend):
    store = ResultStore(tmp_path / "s", backend=backend)
    ex = ExecutionOrchestrator(
        inputs={"prefix": "p", "arch": "archA", "duet": True,
                "duet_rounds": 3},
        harness=SpinHarness(iters=10), store=store)
    results = ex.run_duet(SPEC)
    assert len(results) == 6
    ctxs = [duet.context_of(r.report) for r in results]
    assert len({c["duet_id"] for c in ctxs}) == 1
    assert [(c["round"], c["role"]) for c in ctxs] == [
        (r, role) for r in range(3) for role in duet.ROLES]
    # Columnar extraction and the raw-report fallback see identical pairs.
    col = store.columnar.table("p").duet_pairs("step_time_s")
    raw = duet.pairs_from_reports(
        store.query_with_entries("p"), "step_time_s")
    assert [p.to_dict() for p in col] == [p.to_dict() for p in raw]
    assert len(col) == 3
    # Interleaved A/B: each round's candidate directly follows its baseline.
    assert all(p.seq == p.baseline_seq + 1 for p in col)
    # The collapsed summary keeps the one-result-per-spec shape.
    red = reduce_duet(SPEC, results)
    assert duet.context_of(red.report)["role"] == duet.ROLE_CANDIDATE
    assert red.attempts == 6


@pytest.mark.parametrize("backend", ["dir", "jsonl"])
def test_duplicate_slot_lowest_seq_wins_in_both_extractions(tmp_path, backend):
    """A fencing gap (paused worker appending after the retry) can leave a
    historical store with two entries for one (duet_id, round, role) slot.
    Both extraction paths must keep the lowest-seq record — and agree —
    rather than letting the late duplicate silently replace the canonical
    measurement."""
    store = ResultStore(tmp_path / "s", backend=backend)
    _append_duet(store, "p", "d1", [1.0, 1.1])  # seqs 0..3, rounds 0-1
    # The late duplicate: round 0's candidate again, different value.
    r = new_report(system="t", variant="v", usecase="u", pipeline_id="dup")
    r.parameter[duet.PARAMETER] = duet.tag("d1", duet.ROLE_CANDIDATE, 0, 2)
    r.data.append(DataEntry(success=True, runtime=9.9,
                            metrics={"step_time_s": 9.9}))
    store.append("p", r)

    col = store.columnar.table("p").duet_pairs("step_time_s")
    raw = duet.pairs_from_reports(store.query_with_entries("p"),
                                  "step_time_s")
    assert [p.to_dict() for p in col] == [p.to_dict() for p in raw]
    assert [p.round for p in col] == [0, 1]
    round0 = col[0]
    assert round0.candidate == pytest.approx(1.0)  # seq 1, not the seq-4 dup
    assert round0.seq == 1


def test_orphaned_half_round_never_judged(tmp_path):
    store = ResultStore(tmp_path / "s")
    _append_duet(store, "p", "d1", [1.0, 1.1])
    # A half-completed round (baseline only) is dropped by extraction.
    r = new_report(system="t", variant="v", usecase="u", pipeline_id="x")
    r.parameter[duet.PARAMETER] = duet.tag("d1", duet.ROLE_BASELINE, 2, 3)
    r.data.append(DataEntry(success=True, runtime=1.0,
                            metrics={"step_time_s": 1.0}))
    store.append("p", r)
    pairs = store.columnar.table("p").duet_pairs("step_time_s")
    assert [p.round for p in pairs] == [0, 1]


# ---------------------------------------------------------------------------
# the acceptance criterion: paired gate discriminates under noise
# ---------------------------------------------------------------------------

HIST_JITTERS = [[1.0, 1.02, 0.98, 1.01], [0.99, 1.01, 1.0, 1.02],
                [1.01, 0.98, 1.0, 0.99], [1.02, 1.0, 0.97, 1.01]]
#: Sustained environmental slowdown (e.g. a governor drop) hitting the
#: final duet: both roles of every round scale by 1.8.
NOISY_JITTERS = [1.8, 1.82, 1.79, 1.81]


def _noisy_store(tmp_path, *, factor):
    store = ResultStore(tmp_path / f"noisy-{factor}")
    fp = FP_A
    for i, jit in enumerate(HIST_JITTERS):
        _append_duet(store, "n", f"hist{i}", jit, fp=fp)
    _append_duet(store, "n", "final", NOISY_JITTERS, factor=factor, fp=fp)
    return store


def test_absolute_gate_misclassifies_shared_noise(tmp_path):
    # Identical binaries (factor 1.0) under a 1.8x environment swing: the
    # absolute-series gate blames the binary for the machine.
    store = _noisy_store(tmp_path, factor=1.0)
    out = _gate(store, "n", duet=False, candidate=2)
    assert out["status"] == FAIL
    assert out["gates"][0]["mode"] == "absolute"


def test_paired_gate_passes_identical_binaries_under_noise(tmp_path):
    store = _noisy_store(tmp_path, factor=1.0)
    out = _gate(store, "n", duet=True, candidate=1)
    g = out["gates"][0]
    assert out["status"] == PASS
    assert g["mode"] == "paired"
    assert g["duet"]["duet_ids"] == ["final"]
    # The shared jitter divides out: per-round deltas are ~0.
    assert abs(g["verdicts"][0]["effect"]) < 1e-9
    assert g["fingerprint"]["candidate"] == fingerprint.key(FP_A)


@pytest.mark.parametrize("columnar", [True, False])
def test_paired_gate_flags_injected_slowdown_under_noise(tmp_path, columnar):
    store = _noisy_store(tmp_path, factor=20.0)
    out = _gate(store, "n", duet=True, candidate=1, columnar=columnar)
    g = out["gates"][0]
    assert out["status"] == FAIL
    assert g["mode"] == "paired"
    v = g["verdicts"][0]
    assert v["detector"] == "paired" and v["status"] == FAIL
    assert v["effect"] == pytest.approx(19.0)
    assert g["change_seq"] is not None
    assert g["promotion"] == "paired"


def test_paired_gate_columnar_report_parity(tmp_path):
    store = _noisy_store(tmp_path, factor=20.0)
    a = _gate(store, "n", duet=True, candidate=1, columnar=True)["gates"][0]
    b = _gate(store, "n", duet=True, candidate=1, columnar=False)["gates"][0]
    assert a == b


def test_gate_falls_back_to_absolute_below_duet_rounds(tmp_path):
    store = ResultStore(tmp_path / "s")
    for v in [1.0, 1.01, 0.99, 1.0, 1.02]:
        _append(store, "p", v)
    _append_duet(store, "p", "d1", [1.0])  # one completed pair < duet_rounds
    out = _gate(store, "p", duet=True, duet_rounds=2, candidate=1)
    assert out["gates"][0]["mode"] == "absolute"
    out = _gate(store, "p", duet=True, duet_rounds=1, candidate=1)
    assert out["gates"][0]["mode"] == "paired"


def test_paired_detector_confidence_scales_with_rounds():
    m = MetricSpec.parse("step_time_s", tolerance=0.05)
    det = PairedDeltaDetector()
    hist = np.zeros(0)
    v2 = det.verdict(hist, np.asarray([19.0, 19.0]), m)
    v4 = det.verdict(hist, np.asarray([19.0] * 4), m)
    assert v2.confidence < 0.9 <= v4.confidence  # 2 rounds warn, 4 fail
    assert v4.status == FAIL


# ---------------------------------------------------------------------------
# fingerprint stratification + promotion blocking (absolute path)
# ---------------------------------------------------------------------------

STABLE = [1.0, 1.02, 0.99, 1.01, 1.0, 0.98, 1.03, 1.0]


@pytest.mark.parametrize("columnar", [True, False])
def test_history_stratified_by_fingerprint(tmp_path, columnar):
    store = ResultStore(tmp_path / "s")
    for v in STABLE:
        _append(store, "p", 50.0 * v, fp=FP_B)  # other environment class
    for v in STABLE:
        _append(store, "p", v, fp=FP_A)
    _append(store, "p", 1.0, fp=FP_A)
    out = _gate(store, "p", columnar=columnar)
    g = out["gates"][0]
    # The FP_B rows never reach the baseline: the candidate is judged only
    # against same-class history and passes.
    assert out["status"] == PASS
    assert g["fingerprint"]["stratified_out"] == len(STABLE)
    assert g["baseline"]["median"] == pytest.approx(1.0, abs=0.05)


def test_fingerprint_drift_blocks_baseline_promotion(tmp_path):
    store = ResultStore(tmp_path / "s")
    for v in STABLE:
        _append(store, "p", v, fp=FP_A)
    out = _gate(store, "p", update_baseline=True)
    assert out["gates"][0]["promotion"] == "updated"
    from repro.core.regression import BaselineManager
    mgr = BaselineManager(store)
    before = mgr.current("p", "step_time_s")
    assert before.fingerprint == fingerprint.key(FP_A)

    # Same values, different environment class: must not become baseline.
    _append(store, "p", 1.0, fp=FP_B)
    out = _gate(store, "p", update_baseline=True)
    g = out["gates"][0]
    assert g["promotion"] == "blocked-drift"
    assert "governor" in g["fingerprint"]["drift"]
    after = mgr.current("p", "step_time_s")
    assert after.fingerprint == fingerprint.key(FP_A)
    assert list(after.seqs) == list(before.seqs)  # provably unchanged


def test_untrusted_candidate_blocks_baseline_promotion(tmp_path):
    store = ResultStore(tmp_path / "s")
    for v in STABLE:
        _append(store, "p", v)
    _append(store, "p", 1.0, trusted=False)  # drifted run, downgraded trust
    out = _gate(store, "p", update_baseline=True)
    assert out["gates"][0]["promotion"] == "blocked-untrusted"
    from repro.core.regression import BaselineManager
    assert BaselineManager(store).current("p", "step_time_s") is None


# ---------------------------------------------------------------------------
# DuetNoiseHarness end to end (the CI discrimination harness)
# ---------------------------------------------------------------------------

def test_duet_noise_harness_shares_jitter_within_round(tmp_path):
    store = ResultStore(tmp_path / "s")
    ex = ExecutionOrchestrator(
        inputs={"prefix": "p", "arch": "archA", "duet": True,
                "duet_rounds": 4},
        harness=DuetNoiseHarness(noise=0.5, seed=7), store=store)
    results = ex.run_duet(SPEC)
    jitters = [r.report.data[0].metrics["duet_jitter"] for r in results]
    # Both roles of a round draw the same jitter; rounds differ.
    assert jitters[0::2] == jitters[1::2]
    assert len(set(jitters[0::2])) > 1
    out = _gate(store, "p", duet=True, candidate=1)
    assert out["status"] == PASS


def test_duet_noise_harness_candidate_injection_flags_regression(tmp_path):
    store = ResultStore(tmp_path / "s")
    ex = ExecutionOrchestrator(
        inputs={"prefix": "p", "arch": "archA", "duet": True,
                "duet_rounds": 4},
        harness=DuetNoiseHarness(noise=0.5, seed=7), store=store)
    ex.run_duet(SPEC, candidate_injections=Injections(
        env={DUET_SLOWDOWN_KNOB: "20"}))
    out = _gate(store, "p", duet=True, candidate=1)
    g = out["gates"][0]
    assert out["status"] == FAIL
    assert g["mode"] == "paired" and g["verdicts"][0]["detector"] == "paired"


# ---------------------------------------------------------------------------
# worker plane: duet pinning + exactly-once across SIGKILL mid-pair
# ---------------------------------------------------------------------------

def test_worker_executes_whole_duet_with_task_uid(tmp_path):
    from repro.core.workers import _execute_payload

    store = ResultStore(tmp_path / "s")
    payload = cell_payload(SPEC, {"prefix": "d", "duet": True,
                                  "duet_rounds": 2})
    payload["task_uid"] = "d:0"
    result = _execute_payload(payload, store=store,
                              harness=SpinHarness(iters=10),
                              worker_id="w1", attempt=1)
    assert result["duet"] == {"rounds": 2, "invocations": 4, "adopted": 0}
    reports = store.query("d")
    assert len(reports) == 4
    assert all(r.parameter["task_uid"] == "d:0" for r in reports)
    # All invocations pinned to one worker.
    assert {r.parameter["worker"] for r in reports} == {"w1"}


def test_duet_retry_adopts_persisted_slots(tmp_path):
    """A retry after a crash mid-duet resumes the SAME duet_id and executes
    only the missing (round, role) slots — per-slot exactly-once."""
    from repro.core.workers import _duet_adopted, _execute_payload

    store = ResultStore(tmp_path / "s")
    payload = cell_payload(SPEC, {"prefix": "d", "duet": True,
                                  "duet_rounds": 2})
    payload["task_uid"] = "d:0"
    harness = SpinHarness(iters=10)
    _execute_payload(payload, store=store, harness=harness,
                     worker_id="w1", attempt=1)
    duet_id, slots = _duet_adopted(store, "d", "d:0")
    assert len(slots) == 4
    # Simulate a partial first attempt: drop round 1 from the store view by
    # re-running against a fresh store seeded with only round 0.
    partial = ResultStore(tmp_path / "partial")
    for rep in store.query("d"):
        if duet.context_of(rep)["round"] == 0:
            partial.append("d", rep)
    result = _execute_payload(payload, store=partial, harness=harness,
                              worker_id="w2", attempt=2)
    assert result["duet"]["adopted"] == 2
    reports = partial.query("d")
    assert len(reports) == 4  # round 0 adopted, round 1 executed once
    ctxs = [duet.context_of(r) for r in reports]
    assert len({c["duet_id"] for c in ctxs}) == 1  # duet_id resumed
    assert sorted((c["round"], c["role"]) for c in ctxs) == sorted(
        (r, role) for r in range(2) for role in duet.ROLES)


@pytest.mark.parametrize("backend", ["dir", "jsonl"])
def test_sigkill_mid_pair_reclaimed_exactly_once(tmp_path, backend):
    """SIGKILL between duet rounds: the lease is reclaimed, a fresh worker
    adopts the persisted round-0 pair and completes only the remaining
    slots — every (round, role) measured exactly once, one duet_id."""
    store = ResultStore(tmp_path / "store", backend=backend)
    sentinels = tmp_path / "sentinels"
    queue_root = tmp_path / "queue"
    cfg = WorkerConfig(
        store_root=str(store.root), store_backend=backend,
        harness_ref="repro.core.synthetic:BlockingHarness",
        harness_kwargs={"sentinel_dir": str(sentinels), "timeout_s": 60.0,
                        # Round 0's pair (calls 0, 1) completes and
                        # persists; call 2 (round 1 baseline) traps.
                        "block_calls": 2},
        lease_timeout=0.6, poll_s=0.05, idle_timeout=60.0,
    ).to_dict()
    queue = WorkQueue(queue_root, lease_timeout=0.6)
    queue.create([cell_payload(SPEC, {"prefix": "crash", "duet": True,
                                      "duet_rounds": 2})], campaign="crash")

    w1 = SPAWN.Process(target=worker_main, args=("w1", str(queue_root), cfg),
                       daemon=True)
    w1.start()
    try:
        sentinel = _wait_for(
            lambda: next(iter(sentinels.glob(f"started.{SPEC.cell}.*")), None),
            30.0, "worker to reach round 1")
        victim = int(sentinel.name.rsplit(".", 1)[1])
        os.kill(victim, signal.SIGKILL)
        w1.join(timeout=10)
        assert not w1.is_alive()
        # Round 0's pair reached the store before the kill.
        assert len(store.query("crash")) == 2

        _wait_for(lambda: queue.reclaim_expired() == [0], 10.0, "reclaim")
        (sentinels / "release").write_text("go")
        w2 = SPAWN.Process(target=worker_main, args=("w2", str(queue_root), cfg),
                           daemon=True)
        w2.start()
        w2.join(timeout=30)
        assert queue.finished()
    finally:
        for p in (w1,):
            if p.is_alive():
                p.terminate()

    result = queue.results()[0]
    # worker_main expands a bare label to the full host:pid:label identity.
    assert result["worker"].endswith(":w2") and result["attempts"] == 2
    assert result["duet"]["adopted"] == 2  # round 0's pair, not re-measured
    reports = store.query("crash")
    assert len(reports) == 4  # exactly one report per (round, role)
    ctxs = [duet.context_of(r) for r in reports]
    assert len({c["duet_id"] for c in ctxs}) == 1
    assert sorted((c["round"], c["role"]) for c in ctxs) == sorted(
        (r, role) for r in range(2) for role in duet.ROLES)
    # Round 0 ran on w1 (adopted), round 1 on w2 — but every slot exactly
    # once, and the gate sees two complete pairs.
    pairs = store.columnar.table("crash").duet_pairs("step_time_s")
    assert [p.round for p in pairs] == [0, 1]
