"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures: instantiate the REDUCED config,
run one forward/train step on CPU, assert output shapes and absence of NaNs.
Additionally run decode-vs-fullseq parity for every temporal-mixer family —
the strongest single correctness check the serving path has.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import shapes as SH
from repro.models import params as P
from repro.models import transformer as T

B, TLEN = 2, 12


def tiny_batch(cfg, key=0, with_targets=True):
    rng = np.random.default_rng(key)
    out = {}
    if cfg.input_mode == "embeddings":
        out["embeds"] = jnp.asarray(
            rng.standard_normal((B, TLEN, cfg.d_model)), dtype=cfg.dtype
        )
        tl = TLEN
    elif cfg.prefix_len:
        out["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.prefix_len, cfg.d_model)), dtype=cfg.dtype
        )
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, TLEN)), dtype=jnp.int32
        )
        tl = TLEN
    else:
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, TLEN)), dtype=jnp.int32
        )
        tl = TLEN
    if with_targets:
        if cfg.n_codebooks > 1:
            out["targets"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, cfg.n_codebooks, tl)), dtype=jnp.int32
            )
        else:
            out["targets"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, tl)), dtype=jnp.int32
            )
    return out


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = configs.get_smoke(arch)
    params = P.init_params(cfg, jax.random.key(0))
    batch = tiny_batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: T.train_loss(p, cfg, b, remat="none")
    )(params, batch)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert float(loss) > 0
    assert jnp.isfinite(metrics["ce"])


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_grad_step_smoke(arch):
    cfg = configs.get_smoke(arch)
    params = P.init_params(cfg, jax.random.key(1))
    batch = tiny_batch(cfg)

    def loss_fn(p):
        loss, _ = T.train_loss(p, cfg, batch, remat="full")
        return loss

    g = jax.jit(jax.grad(loss_fn))(params)
    flat = P.flatten(g)
    finite = [bool(jnp.all(jnp.isfinite(v))) for v in flat.values()]
    assert all(finite), f"{arch}: non-finite grads"
    # At least some gradient must be nonzero.
    assert any(float(jnp.max(jnp.abs(v))) > 0 for v in flat.values())


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_param_counts_positive(arch):
    full = configs.get_config(arch)
    n = P.count_params_cfg(full)
    na = P.count_params_cfg(full, active_only=True)
    assert n > 0 and na > 0 and na <= n
    if full.moe:
        assert na < n, "MoE active params must be < total"


def _f32(cfg):
    # Parity runs in f32 with generous MoE capacity so no tokens drop.
    kw = {"dtype": "float32"}
    if cfg.moe:
        kw["moe"] = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    return dataclasses.replace(cfg, **kw)


@pytest.mark.parametrize(
    "arch",
    [
        "glm4-9b",            # dense GQA
        "gemma3-4b",          # ring-buffer local + global mix
        "recurrentgemma-2b",  # RG-LRU hybrid
        "deepseek-v3-671b",   # MLA absorbed decode + MoE
        "mamba2-1.3b",        # SSD
        "musicgen-medium",    # multi-codebook embeddings input
        "paligemma-3b",       # prefix-LM
        "qwen3-moe-235b-a22b",  # MoE + qk-norm
    ],
)
def test_prefill_decode_parity(arch):
    cfg = _f32(configs.get_smoke(arch))
    params = P.init_params(cfg, jax.random.key(2))
    batch = tiny_batch(cfg, with_targets=False)

    # Full-sequence logits at every position.
    h, _ = T.forward_fullseq(params, cfg, batch, remat="none")
    if cfg.prefix_len:
        h = h[:, cfg.prefix_len:]
    logits_full = T.apply_head(params, cfg, h)

    t0 = 8
    total = TLEN
    # Prefill on the first t0 tokens.
    if cfg.input_mode == "embeddings":
        pre = {"embeds": batch["embeds"][:, :t0]}
    elif cfg.prefix_len:
        pre = {
            "prefix_embeds": batch["prefix_embeds"],
            "tokens": batch["tokens"][:, :t0],
        }
    else:
        pre = {"tokens": batch["tokens"][:, :t0]}
    max_len = cfg.prefix_len + total
    logits_p, state = T.prefill(params, cfg, pre, max_len=max_len, remat="none")

    if cfg.n_codebooks > 1:
        ref = logits_full[:, :, t0 - 1]
        got = logits_p[:, :, 0]
    else:
        ref = logits_full[:, t0 - 1]
        got = logits_p[:, 0]
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)

    # Decode the remaining tokens, checking logits at each position.
    for t in range(t0, total):
        if cfg.input_mode == "embeddings":
            step = {"embeds": batch["embeds"][:, t : t + 1]}
        else:
            step = {"tokens": batch["tokens"][:, t : t + 1]}
        idx = jnp.asarray(cfg.prefix_len + t, jnp.int32)
        logits_d, state = T.decode_step(params, cfg, state, step, idx)
        if cfg.n_codebooks > 1:
            ref = logits_full[:, :, t]
            got = logits_d[:, :, 0]
        else:
            ref = logits_full[:, t]
            got = logits_d[:, 0]
        np.testing.assert_allclose(got, ref, rtol=5e-3, atol=5e-3, err_msg=f"pos {t}")


def test_long_context_applicability():
    """long_500k runs only for sub-quadratic archs (DESIGN §Arch-applicability)."""
    expected_long = {"gemma3-4b", "recurrentgemma-2b", "mamba2-1.3b"}
    got = {
        a
        for a, c in configs.all_configs().items()
        if SH.applicable(c, SH.SHAPES["long_500k"])
    }
    assert got == expected_long


def test_cell_count():
    cfg = configs.all_configs()
    cells = SH.cells(cfg)
    # 10 archs x 3 universal shapes + 3 long_500k-capable archs.
    assert len(cells) == 33
