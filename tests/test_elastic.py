"""Elastic-mesh checkpoint restore: save under one mesh, restore under
another (the fleet-resize recovery path).  Runs in a subprocess so the test
process's single-device jax state is untouched."""

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, r"{src}")
    import dataclasses
    import jax
    import numpy as np

    from repro import configs
    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.distributed import sharding as S
    from repro.models import params as P

    cfg = dataclasses.replace(
        configs.get_smoke("glm4-9b"), d_model=64, n_layers=2, d_ff=128,
        vocab_size=512, n_heads=8, n_kv_heads=4, head_dim=16,
    )
    strat = S.STRATEGIES["tp_dp"]

    # 1. Train-mesh (2 data x 4 model): init sharded params, save.
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    shard_a = S.param_shardings(cfg, mesh_a, strat)
    params = P.init_params(cfg, jax.random.key(0))
    params = jax.tree.map(jax.device_put, params, shard_a)
    mgr = CheckpointManager(r"{ckpt}")
    mgr.save(7, {{"params": params}})

    # 2. "Failure + resize": restore onto a DIFFERENT mesh (4 data x 2 model).
    mesh_b = jax.make_mesh((4, 2), ("data", "model"))
    shard_b = S.param_shardings(cfg, mesh_b, strat)
    restored = mgr.restore(7, shardings={{"params": shard_b}})["params"]

    flat_a = P.flatten(params)
    flat_b = P.flatten(restored)
    for k in flat_a:
        np.testing.assert_array_equal(np.asarray(flat_a[k]), np.asarray(flat_b[k]))
        got = flat_b[k].sharding
        want = P.flatten({{"params": shard_b}})["params/" + k]
        assert got == want, (k, got, want)

    # 3. Downscale to a single device (debug/repair path).
    solo = mgr.restore(7)["params"]
    np.testing.assert_array_equal(
        np.asarray(P.flatten(solo)["embed/table"]),
        np.asarray(flat_a["embed/table"]),
    )
    print("ELASTIC_OK")
""")


@pytest.mark.slow
def test_elastic_restore_across_meshes(tmp_path):
    script = SCRIPT.format(src=ROOT / "src", ckpt=tmp_path / "ck")
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=560,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "ELASTIC_OK" in proc.stdout
