"""Harness family (kernel/serve/train) + autotune plane tests.

Covers the PR-4 negotiation contract per harness (fail-fast before
dispatch), the PR-6 process-worker contract (spawn_spec round-trip and a
payload-declared harness through the real worker code path), the autotune
cache key semantics (hit / miss / fingerprint-drift invalidation), the
ops.py cache consultation, and Poisson load-gen determinism.
"""

import json

import numpy as np
import pytest

from repro import harnesses
from repro.core import fingerprint
from repro.core.autotune import (
    CACHE_ENV,
    AutotuneCache,
    cached_blocks,
    reset_runtime_caches,
)
from repro.core.component import REGISTRY, ComponentContext, PipelineError
from repro.core.harness import (
    BenchmarkSpec,
    CapabilityError,
    Injections,
    negotiate,
)
from repro.core.orchestrator import ExecutionOrchestrator
from repro.core.store import ResultStore
from repro.core.workers import (
    WorkerConfig,
    cell_payload,
    resolve_harness,
    worker_main,
)
from repro.core.workqueue import WorkQueue
from repro.harnesses.kernel import KernelHarness
from repro.harnesses.serve import ServeHarness, poisson_arrivals
from repro.harnesses.train import TrainHarness


def _kernel_harness(**kw):
    base = dict(kernel="flash_attention", batch=1, heads=2, seq=32,
                head_dim=8, calls=1, warmup=1, interpret=True,
                use_cache=False)
    base.update(kw)
    return KernelHarness(**base)


KSPEC = BenchmarkSpec(arch="kernel", shape="fa_smoke", system="local")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_resolves_named_harnesses():
    assert isinstance(harnesses.resolve("kernel"), KernelHarness)
    assert isinstance(harnesses.resolve("serve"), ServeHarness)
    assert isinstance(harnesses.resolve("train"), TrainHarness)
    with pytest.raises(PipelineError, match="unknown harness"):
        harnesses.resolve("warp-drive")
    with pytest.raises(PipelineError, match="kernel"):
        harnesses.resolve("kernel", warp_factor=9)  # bad kwarg names harness


def test_from_inputs_extracts_namespace():
    h = harnesses.from_inputs({
        "harness": "kernel", "harness.kernel": "rglru",
        "harness.seq": 64, "prefix": "x"})
    assert isinstance(h, KernelHarness)
    assert h.kernel == "rglru" and h.seq == 64
    assert harnesses.from_inputs({"prefix": "x"}) is None


# ---------------------------------------------------------------------------
# capability negotiation: fail fast, before any execution
# ---------------------------------------------------------------------------

def test_kernel_harness_rejects_model_shapes_fail_fast():
    ex = ExecutionOrchestrator(
        inputs={"prefix": "t", "record": False}, harness=_kernel_harness())
    res = ex.run_cell(BenchmarkSpec(arch="x", shape="train_4k", system="local"))
    assert res.error and "CapabilityError" in res.error
    assert "step kind" in res.error
    assert res.attempts == 0  # fail-fast: no execution slot burned


def test_kernel_harness_rejects_launcher_injection():
    ex = ExecutionOrchestrator(
        inputs={"prefix": "t", "record": False}, harness=_kernel_harness())
    res = ex.run_cell(KSPEC, injections=Injections(launcher=lambda f: f))
    assert res.error and "CapabilityError" in res.error
    assert res.attempts == 0


def test_serve_and_train_step_kind_negotiation():
    with pytest.raises(CapabilityError):
        negotiate(BenchmarkSpec(arch="a", shape="train_4k", system="s"),
                  ServeHarness())
    with pytest.raises(CapabilityError):
        negotiate(BenchmarkSpec(arch="a", shape="decode_32k", system="s"),
                  TrainHarness())
    # The matching kinds pass.
    negotiate(BenchmarkSpec(arch="a", shape="decode_32k", system="s"),
              ServeHarness())
    negotiate(BenchmarkSpec(arch="a", shape="train_4k", system="s"),
              TrainHarness())


# ---------------------------------------------------------------------------
# kernel harness execution
# ---------------------------------------------------------------------------

def test_kernel_harness_reports_latency_and_roofline_inputs():
    h = _kernel_harness()
    rep = h.run(KSPEC, Injections(overrides={"block_q": 16, "block_k": 16}))
    m = rep.data[-1].metrics
    assert m["kernel_latency_s"] > 0
    assert m["step_time_s"] == m["kernel_latency_s"]
    assert m["hlo_flops"] > 0 and m["hlo_bytes"] > 0
    assert m["achieved_flops"] == pytest.approx(
        m["hlo_flops"] / m["kernel_latency_s"])
    assert rep.parameter["blocks"] == {"block_q": 16, "block_k": 16}
    assert rep.parameter["blocks_source"] == "injections"
    assert rep.parameter["kernel_shape"] == "B1.H2.T32.D8"


def test_kernel_harness_default_blocks_without_cache():
    rep = _kernel_harness().run(KSPEC)
    assert rep.parameter["blocks_source"] == "default"
    assert rep.parameter["blocks"] == {"block_q": 512, "block_k": 512}


# ---------------------------------------------------------------------------
# spawn_spec round-trip + process-worker dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda: _kernel_harness(kernel="ssd", seq=16),
    lambda: ServeHarness(batch=3, requests=5, rate_rps=7.5),
    lambda: TrainHarness(steps=2, seq_len=16),
])
def test_spawn_spec_round_trip(make):
    h = make()
    ref, kwargs = h.spawn_spec()
    json.dumps(kwargs)  # plain data only: must cross the spawn boundary
    h2 = resolve_harness(ref, kwargs)
    assert type(h2) is type(h)
    assert h2.spawn_spec() == (ref, kwargs)


def test_worker_runs_payload_declared_harness(tmp_path):
    """The document's harness choice travels in the payload and beats the
    worker's campaign-level default — through the real worker_main path."""
    store = ResultStore(tmp_path / "store")
    payload = cell_payload(
        KSPEC,
        {"prefix": "wk", "record": True, "harness": "kernel",
         "harness.kernel": "flash_attention", "harness.seq": 32,
         "harness.head_dim": 8, "harness.calls": 1, "harness.warmup": 1,
         "harness.interpret": True, "harness.use_cache": False},
        injections=Injections(overrides={"block_q": 16, "block_k": 16}),
    )
    WorkQueue(tmp_path / "q").create([payload], campaign="t")
    cfg = WorkerConfig(
        store_root=str(store.root),
        harness_ref="repro.core.harness:ExecHarness",  # the default to beat
        harness_kwargs={"steps": 1, "batch": 1, "seq": 8},
        idle_timeout=60.0,
    ).to_dict()
    worker_main("w0", str(tmp_path / "q"), cfg)
    reports = store.query("wk")
    assert len(reports) == 1
    assert reports[0].parameter["kernel"] == "flash_attention"
    assert reports[0].parameter["blocks"]["block_q"] == 16
    assert reports[0].data[-1].metrics["kernel_latency_s"] > 0


# ---------------------------------------------------------------------------
# autotune cache semantics
# ---------------------------------------------------------------------------

def test_autotune_cache_hit_miss_and_fingerprint_drift(tmp_path):
    path = tmp_path / "cache.json"
    cache = AutotuneCache(path)
    fp = fingerprint.key(fingerprint.capture())
    cache.put("flash_attention", "B1.H2.T32.D8", "float32", fp,
              {"block_q": 16, "block_k": 32}, latency_s=1e-4)

    hit = cache.lookup("flash_attention", "B1.H2.T32.D8", "float32", fp)
    assert hit is not None and hit["config"] == {"block_q": 16, "block_k": 32}
    # Different shape / dtype: miss.
    assert cache.lookup("flash_attention", "B1.H2.T64.D8", "float32", fp) is None
    assert cache.lookup("flash_attention", "B1.H2.T32.D8", "bfloat16", fp) is None
    # Fingerprint drift (entry tuned on other hardware): invisible.
    drifted = fp.replace("{", '{"governor":"other",', 1)
    assert cache.lookup("flash_attention", "B1.H2.T32.D8", "float32",
                        drifted) is None

    # put() on the same key replaces and counts updates.
    entry = cache.put("flash_attention", "B1.H2.T32.D8", "float32", fp,
                      {"block_q": 64, "block_k": 64})
    assert entry["updates"] == 2


def test_cached_blocks_env_and_drift(tmp_path, monkeypatch):
    path = tmp_path / "cache.json"
    fp = fingerprint.key(fingerprint.capture())
    AutotuneCache(path).put("rglru", "B1.T64.W32", "float32", fp,
                            {"chunk": 32, "block_w": 16})
    reset_runtime_caches()
    # Env unset: the cache is off regardless of what is on disk.
    monkeypatch.delenv(CACHE_ENV, raising=False)
    assert cached_blocks("rglru", "B1.T64.W32", "float32") is None
    monkeypatch.setenv(CACHE_ENV, str(path))
    assert cached_blocks("rglru", "B1.T64.W32", "float32") == {
        "chunk": 32, "block_w": 16}
    # An entry stamped with a drifted fingerprint stops resolving even when
    # its (kernel, shape, dtype) match — re-keyed via a hand-edited file.
    data = json.loads(path.read_text())
    for e in data["entries"].values():
        e["fingerprint_key"] = e["fingerprint_key"] + "x"
    path.write_text(json.dumps(data))
    reset_runtime_caches()
    assert cached_blocks("rglru", "B1.T64.W32", "float32") is None
    reset_runtime_caches()


def test_flash_attention_consults_cache(tmp_path, monkeypatch):
    import jax.numpy as jnp

    from repro.kernels.flash_attention import ops

    path = tmp_path / "cache.json"
    fp = fingerprint.key(fingerprint.capture())
    AutotuneCache(path).put("flash_attention", "B1.H2.T32.D8", "float32", fp,
                            {"block_q": 16, "block_k": 16})
    monkeypatch.setenv(CACHE_ENV, str(path))
    reset_runtime_caches()
    q = jnp.asarray(np.random.default_rng(0).standard_normal((1, 2, 32, 8)),
                    jnp.float32)
    assert ops._autotuned_blocks(q.shape, q.dtype) == {
        "block_q": 16, "block_k": 16}
    tuned = ops.flash_attention(q, q, q, interpret=True)
    explicit = ops.flash_attention(q, q, q, interpret=True,
                                   block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(tuned), np.asarray(explicit),
                               atol=1e-6)
    reset_runtime_caches()


def test_kernel_harness_uses_cache_for_defaults(tmp_path):
    path = tmp_path / "cache.json"
    fp = fingerprint.key(fingerprint.capture())
    AutotuneCache(path).put("flash_attention", "B1.H2.T32.D8", "float32", fp,
                            {"block_q": 16, "block_k": 16})
    h = _kernel_harness(use_cache=True, cache_path=str(path))
    rep = h.run(KSPEC)
    assert rep.parameter["blocks_source"] == "cache"
    assert rep.parameter["blocks"] == {"block_q": 16, "block_k": 16}


# ---------------------------------------------------------------------------
# autotune@v1 component
# ---------------------------------------------------------------------------

def test_autotune_component_sweep_promote_and_noop(tmp_path):
    store = ResultStore(tmp_path / "store")
    ctx = ComponentContext(store=store)
    resolved = REGISTRY.resolve("autotune", 1)
    inputs = {"kernel": "flash_attention", "prefix": "autotune.t",
              "seq": 32, "head_dim": 8, "heads": 2, "batch": 1,
              "block_q": [16, 32], "block_k": [16], "calls": 1, "warmup": 1,
              "confirm": 1, "interpret": True}

    out = resolved.run(inputs, ctx)
    assert len(out["points"]) == 2
    assert out["winner"]["config"]["block_q"] in (16, 32)
    assert out["points"][0]["dominant"] in ("compute", "memory")
    assert (tmp_path / "store" / "autotune_cache.json").exists()

    from repro.core.regression import BaselineManager
    cur = BaselineManager(store).current("autotune.t", "kernel_latency_s")
    assert cur is not None and cur.pinned

    # Unchanged key: incremental no-op.
    again = resolved.run(inputs, ctx)
    assert again.get("skipped") == "cache-hit"
    assert again["cache"]["hit"] is True
    # force re-sweeps.
    forced = resolved.run({**inputs, "force": True}, ctx)
    assert forced.get("skipped") is None and len(forced["points"]) == 2


def test_autotune_requires_sweep_values(tmp_path):
    ctx = ComponentContext(store=ResultStore(tmp_path / "store"))
    with pytest.raises(PipelineError, match="no block values"):
        REGISTRY.resolve("autotune", 1).run(
            {"kernel": "flash_attention"}, ctx)


# ---------------------------------------------------------------------------
# serve load generation
# ---------------------------------------------------------------------------

def test_poisson_arrivals_deterministic_under_seed():
    a = poisson_arrivals(64, 20.0, seed=7)
    b = poisson_arrivals(64, 20.0, seed=7)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, poisson_arrivals(64, 20.0, seed=8))
    assert a.shape == (64,)
    assert np.all(np.diff(a) >= 0) and np.all(a >= 0)
    # Mean inter-arrival ~ 1/rate.
    assert 1 / 20.0 == pytest.approx(float(np.mean(np.diff(a))), rel=0.5)
    with pytest.raises(ValueError):
        poisson_arrivals(4, 0.0, seed=0)


def test_serve_harness_reports_tail_latencies():
    h = ServeHarness(batch=2, max_len=16, requests=4, prompt_len=3,
                     max_new_tokens=2, rate_rps=200.0)
    rep = h.run(BenchmarkSpec(arch="starcoder2-3b", shape="serve_smoke",
                              system="local"))
    m = rep.data[-1].metrics
    assert 0 < m["p50_latency_s"] <= m["p95_latency_s"] <= m["p99_latency_s"]
    assert m["tokens_per_s"] > 0 and m["requests_per_s"] > 0
    assert rep.data[-1].success


def test_serve_harness_rejects_embedding_archs():
    h = ServeHarness(requests=2)
    with pytest.raises(ValueError, match="input_mode"):
        h.run(BenchmarkSpec(arch="musicgen-medium", shape="serve_smoke",
                            system="local"))


def test_train_harness_step_times():
    h = TrainHarness(steps=2, seq_len=16, global_batch=2)
    rep = h.run(BenchmarkSpec(arch="starcoder2-3b", shape="train_4k",
                              system="local"))
    m = rep.data[-1].metrics
    assert m["step_time_s"] > 0
    assert np.isfinite(m["final_loss"])
